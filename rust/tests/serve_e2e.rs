//! End-to-end integration: the full three-layer stack.
//!
//! Requires `make artifacts` (skips cleanly otherwise). Verifies:
//! * the PJRT runtime reproduces the python oracle's numbers (the AOT
//!   round-trip is numerically faithful);
//! * the serving coordinator produces identical hidden states under all
//!   three strategies (duplication must never change results);
//! * Distribution-Only prediction reduces slot imbalance vs the baseline.

use std::path::PathBuf;

use moe_gps::coordinator::{Coordinator, Request, ServeStrategy};
use moe_gps::runtime::tensor::IntTensor;
use moe_gps::runtime::{Engine, HostTensor, In};
use moe_gps::util::json::Value;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn artifacts_ready() -> bool {
    let ok = artifacts_dir().join("oracle.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn oracle() -> Value {
    let text = std::fs::read_to_string(artifacts_dir().join("oracle.json")).unwrap();
    Value::parse(&text).unwrap()
}

fn prefix_f64(v: &Value, key: &str) -> Vec<f64> {
    v.get(key)
        .and_then(Value::as_arr)
        .unwrap_or_else(|| panic!("oracle missing {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect()
}

fn assert_close(actual: &[f32], expected: &[f64], tol: f64, what: &str) {
    for (i, (&a, &e)) in actual.iter().zip(expected).enumerate() {
        assert!(
            (a as f64 - e).abs() <= tol * (1.0 + e.abs()),
            "{what}[{i}]: {a} vs {e}"
        );
    }
}

/// The exact embed→attention→router→expert-FFN→predictor chain the python
/// oracle recorded, replayed through rust PJRT.
#[test]
fn runtime_matches_python_oracle() {
    if !artifacts_ready() {
        return;
    }
    let oracle = oracle();
    let ids: Vec<i32> = oracle
        .get("ids")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    let s = ids.len();

    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let ids_t = IntTensor::new(ids, vec![1, s]);
    let x0 = engine
        .call("embed", &[In::I(&ids_t), In::W("embed")])
        .unwrap()
        .remove(0);
    assert_close(
        &x0.data[..16],
        &prefix_f64(&oracle, "embed_prefix"),
        1e-5,
        "embed",
    );

    let h = engine
        .call(
            "attention",
            &[
                In::T(&x0),
                In::W("layers.0.attn.ln"),
                In::W("layers.0.attn.wq"),
                In::W("layers.0.attn.wk"),
                In::W("layers.0.attn.wv"),
                In::W("layers.0.attn.wo"),
            ],
        )
        .unwrap()
        .remove(0);
    assert_close(
        &h.data[..16],
        &prefix_f64(&oracle, "attention_prefix"),
        1e-4,
        "attention",
    );

    let mut router_out = engine
        .call(
            "router",
            &[In::T(&h), In::W("layers.0.moe.ln"), In::W("layers.0.moe.router")],
        )
        .unwrap();
    let logits = router_out.remove(1);
    let xn = router_out.remove(0);
    assert_close(
        &xn.data[..16],
        &prefix_f64(&oracle, "router_xn_prefix"),
        1e-4,
        "router.xn",
    );
    assert_close(
        &logits.data[..16],
        &prefix_f64(&oracle, "router_logits_prefix"),
        1e-4,
        "router.logits",
    );

    // Expert FFN over the first bucket (the Pallas kernel's artifact).
    let bucket = engine.manifest().ffn_buckets()[0];
    let slice = xn.gather_rows(&(0..bucket).collect::<Vec<_>>());
    let ffn = engine
        .call(
            &format!("expert_ffn_b{bucket}"),
            &[
                In::T(&slice),
                In::W("layers.0.experts.0.w_gate"),
                In::W("layers.0.experts.0.w_up"),
                In::W("layers.0.experts.0.w_down"),
            ],
        )
        .unwrap()
        .remove(0);
    assert_close(
        &ffn.data[..16],
        &prefix_f64(&oracle, &format!("expert_ffn_b{bucket}_prefix")),
        1e-4,
        "expert_ffn",
    );

    // Predictor artifact.
    let n_layers = engine.manifest().config.req_usize("n_layers").unwrap();
    let mut ins: Vec<In<'_>> = vec![In::T(&x0), In::W("predictor.w1"), In::W("predictor.b1")];
    let head_names: Vec<String> = (0..n_layers)
        .map(|l| format!("predictor.head.{l}"))
        .collect();
    for name in &head_names {
        ins.push(In::W(name));
    }
    let pred = engine.call("predictor", &ins).unwrap().remove(0);
    assert_close(
        &pred.data[..16],
        &prefix_f64(&oracle, "predictor_prefix"),
        1e-4,
        "predictor",
    );
}

/// Routing decisions through the rust top-k must match the python oracle.
#[test]
fn routing_matches_oracle_layer0() {
    if !artifacts_ready() {
        return;
    }
    let oracle = oracle();
    let ids: Vec<i32> = oracle
        .get("ids")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    let expected: Vec<usize> = oracle
        .get("routes_layer0_first32")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();

    let mut engine = Engine::new(&artifacts_dir()).unwrap();
    let s = ids.len();
    let ids_t = IntTensor::new(ids, vec![1, s]);
    let x0 = engine
        .call("embed", &[In::I(&ids_t), In::W("embed")])
        .unwrap()
        .remove(0);
    let h = engine
        .call(
            "attention",
            &[
                In::T(&x0),
                In::W("layers.0.attn.ln"),
                In::W("layers.0.attn.wq"),
                In::W("layers.0.attn.wk"),
                In::W("layers.0.attn.wv"),
                In::W("layers.0.attn.wo"),
            ],
        )
        .unwrap()
        .remove(0);
    let logits = engine
        .call(
            "router",
            &[In::T(&h), In::W("layers.0.moe.ln"), In::W("layers.0.moe.router")],
        )
        .unwrap()
        .remove(1);
    let slots = moe_gps::coordinator::router::route_sequence(0, &logits.data, 8, 32, 2);
    // slots alternate top1/top2 per token; take top-1 per token.
    let top1: Vec<usize> = (0..32).map(|t| slots[t * 2].expert as usize).collect();
    assert_eq!(top1, expected);
}

/// All strategies must produce the same final hidden states — duplication
/// and dispatch are performance mechanisms, never numerics changes.
#[test]
fn strategies_agree_on_outputs_and_dop_balances() {
    if !artifacts_ready() {
        return;
    }
    let mk_requests = || {
        let mut gen = moe_gps::coordinator::request::RequestGen::new(99, 4096);
        // Two warmup rounds (teach the DOP estimator) + one measured round.
        (0..3)
            .map(|_| (0..2).map(|_| gen.request_varlen(48, 200)).collect::<Vec<Request>>())
            .collect::<Vec<_>>()
    };

    let run = |strategy: ServeStrategy| -> (Vec<HostTensor>, f64, f64) {
        let mut coord = Coordinator::new(&artifacts_dir(), 4, strategy).unwrap();
        let rounds = mk_requests();
        let mut last_outputs = Vec::new();
        let mut last_metrics = None;
        for round in rounds {
            let (m, out) = coord.serve_round(&round).unwrap();
            last_outputs = out;
            last_metrics = Some(m);
        }
        let m = last_metrics.unwrap();
        (last_outputs, m.slot_imbalance(), m.routing_skew)
    };

    let (base_out, base_imb, skew) = run(ServeStrategy::NoPrediction);
    let (dop_out, dop_imb, _) = run(ServeStrategy::DistributionOnly);
    let (tep_out, _tep_imb, _) = run(ServeStrategy::TokenToExpert);

    // Numerics identical across strategies.
    for (a, b) in base_out.iter().zip(&dop_out) {
        assert_eq!(a.shape, b.shape);
        for (&x, &y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-4, "dop numerics diverged: {x} vs {y}");
        }
    }
    for (a, b) in base_out.iter().zip(&tep_out) {
        for (&x, &y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-4, "tep numerics diverged: {x} vs {y}");
        }
    }

    // The tiny model routes skewed (that's the point)...
    assert!(skew > 1.2, "routing skew {skew}");
    // ...and DOP duplication must reduce dispatch imbalance vs baseline.
    assert!(
        dop_imb < base_imb,
        "DOP should balance: baseline {base_imb} vs dop {dop_imb}"
    );
}

/// The worker-offloaded (TP-analogue) attention path must be numerically
/// identical to leader attention.
#[test]
fn parallel_attention_matches_leader_attention() {
    if !artifacts_ready() {
        return;
    }
    let mk_requests = || {
        let mut gen = moe_gps::coordinator::request::RequestGen::new(5, 4096);
        (0..3)
            .map(|_| gen.request_varlen(40, 180))
            .collect::<Vec<Request>>()
    };
    let run = |parallel: bool| -> Vec<HostTensor> {
        let mut coord =
            Coordinator::new(&artifacts_dir(), 4, ServeStrategy::NoPrediction).unwrap();
        coord.parallel_attention = parallel;
        let (_, out) = coord.serve_round(&mk_requests()).unwrap();
        out
    };
    let leader = run(false);
    let parallel = run(true);
    for (a, b) in leader.iter().zip(&parallel) {
        assert_eq!(a.shape, b.shape);
        for (&x, &y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
}
