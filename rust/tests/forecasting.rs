//! Forecasting test harness (ADR 006): the properties the load-trajectory
//! forecaster must hold through the unified `Predictor` surface.
//!
//! * **Exact recovery on linear ramps** — Holt's two-observation
//!   initialization makes a linear per-expert signal a fixed point of the
//!   recurrence, so the `h`-step forecast equals the true future load
//!   exactly, at every horizon.
//! * **Convergence on constant loads** — the level converges to the
//!   stationary load and the trend vanishes, so every horizon predicts
//!   the stationary distribution.
//! * **Horizon 0 ≡ `predict_distribution`, bitwise** — the degradation
//!   contract every proactive-serving parity claim rests on, for the
//!   forecaster and for the trait's default implementation alike.

use moe_gps::predictor::distribution::DistributionEstimator;
use moe_gps::predictor::forecast::LoadForecaster;
use moe_gps::predictor::Predictor;

/// Per-expert loads of the two-sided test ramp at step `t`: expert 0
/// heats up linearly, expert 2 cools, the rest hold.
fn ramp(t: usize) -> [usize; 4] {
    [100 + 20 * t, 150, 400 - 10 * t, 150]
}

fn normalize(counts: &[usize]) -> Vec<f64> {
    let total: f64 = counts.iter().map(|&c| c as f64).sum();
    counts.iter().map(|&c| c as f64 / total).collect()
}

#[test]
fn linear_ramp_is_recovered_exactly_at_every_horizon() {
    let mut p = LoadForecaster::new(4);
    let last = 9usize;
    for t in 0..=last {
        p.observe(&ramp(t));
    }
    for h in [1usize, 2, 4, 8] {
        let want = normalize(&ramp(last + h));
        let got = p.predict_horizon(h);
        assert!((got.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for (e, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-9,
                "horizon {h} expert {e}: forecast {g} vs true future share {w}"
            );
        }
    }
}

#[test]
fn constant_load_converges_with_horizon_invariant_forecast() {
    let mut p = LoadForecaster::new(3);
    for _ in 0..50 {
        p.observe(&[300, 150, 50]);
    }
    let stationary = [0.6, 0.3, 0.1];
    for h in [0usize, 1, 5, 20] {
        let got = p.predict_horizon(h);
        for (e, (&g, &w)) in got.iter().zip(&stationary).enumerate() {
            assert!(
                (g - w).abs() < 1e-9,
                "horizon {h} expert {e}: {g} vs stationary {w}"
            );
        }
    }
    for &t in p.trend() {
        assert!(t.abs() < 1e-9, "trend must vanish on constant load: {t}");
    }
}

#[test]
fn horizon_zero_is_predict_distribution_bitwise() {
    let mut p = LoadForecaster::new(4);
    for t in 0..7usize {
        p.observe(&ramp(t));
    }
    let reactive = p.predict_distribution();
    let zero = p.predict_horizon(0);
    assert_eq!(reactive.len(), zero.len());
    for (e, (a, b)) in reactive.iter().zip(&zero).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "expert {e}: horizon 0 must be the reactive estimate bitwise"
        );
    }
}

#[test]
fn default_trait_horizon_is_the_stationary_estimate_bitwise() {
    // Predictors without trend state fall back to the trait default:
    // predict_horizon(h) == predict_distribution() for every h, bitwise.
    let mut p = DistributionEstimator::new(4);
    for t in 0..7usize {
        p.observe(&ramp(t));
    }
    let now = p.predict_distribution();
    for h in [0usize, 3, 11] {
        for (a, b) in now.iter().zip(&p.predict_horizon(h)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn forecaster_extrapolates_where_the_estimator_averages() {
    // On the same ramp the forecaster's horizon-h share of the heating
    // expert must exceed the stationary estimator's (which lags the ramp
    // by averaging over history) — the property that makes proactive
    // replanning land replicas before the spike.
    let mut forecaster = LoadForecaster::new(4);
    let mut estimator = DistributionEstimator::new(4);
    for t in 0..10usize {
        forecaster.observe(&ramp(t));
        estimator.observe(&ramp(t));
    }
    let ahead = forecaster.predict_horizon(4)[0];
    let lagging = estimator.predict_horizon(4)[0];
    let current = normalize(&ramp(9))[0];
    assert!(
        ahead > current && current > lagging,
        "forecast {ahead} must lead the current share {current}, which must \
         lead the history-averaged estimate {lagging}"
    );
}
