//! ADR 009 zero-copy data plane, end to end: `Arc`-shared attention
//! fan-out, coalesced slab-backed FFN batches (`WorkerMsg::RunBatch`) and
//! the copy-accounting counters that gate both. The acceptance claims
//! pinned here:
//!
//! * steady-state FFN dispatch sends **exactly one** message per (layer,
//!   assigned worker) — O(alive workers), not O(groups) — and every byte
//!   it copies is the slab gather: `bytes_copied == n_slots × d_model × 4`;
//! * the parallel-attention fan-out deep-copies **nothing**: toggling it
//!   moves bytes into `bytes_shared` only, leaves `bytes_copied` at the
//!   exact slab-gather figure, and the outputs stay bitwise identical;
//! * a worker killed mid-run fails over with bitwise-identical output
//!   while the accounting stays exact: redispatched slots re-gather once
//!   each, so `bytes_copied == (n_slots + redispatched_slots) × d × 4`;
//! * slab-backed decode under a total worker loss still loses no
//!   sequences (`lost_seqs == 0` — the chaos CI gate holds under RunBatch).

mod common;
use common::{assert_bitwise_eq, decode_requests, greedy_decode_opts, mk_rounds, small_source};
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::{
    Coordinator, CopyStats, DecodeReport, FaultPlan, RoundMetrics, ServeReport, ServeStrategy,
};
use moe_gps::runtime::{HostTensor, SyntheticSpec};

/// Hidden width of the 2-layer synthetic test model — the unit every
/// exact copy-accounting assertion below is denominated in.
fn d_model() -> usize {
    SyntheticSpec::small_test().d_model
}

fn n_layers() -> usize {
    SyntheticSpec::small_test().n_layers
}

/// Drive prefill rounds with optional fault injection and the
/// parallel-attention fan-out toggled.
fn serve_prefill(
    strategy: ServeStrategy,
    workers: usize,
    parallel_attention: bool,
    faults: Option<&str>,
    timeout_s: Option<f64>,
    rounds: Vec<Vec<Request>>,
) -> (Vec<Vec<HostTensor>>, Vec<RoundMetrics>) {
    let mut coord = Coordinator::with_source(&small_source(), workers, strategy).unwrap();
    coord.parallel_attention = parallel_attention;
    if let Some(spec) = faults {
        coord.set_fault_plan(&FaultPlan::parse(spec).unwrap());
    }
    coord.set_worker_timeout(timeout_s);
    let mut outputs = Vec::new();
    let mut metrics = Vec::new();
    for round in rounds {
        let (m, out) = coord.serve_round(&round).unwrap();
        outputs.push(out);
        metrics.push(m);
    }
    (outputs, metrics)
}

/// Aggregate per-round copy counters the way a serve report does.
fn copy_stats(rounds: &[RoundMetrics]) -> CopyStats {
    ServeReport {
        rounds: rounds.to_vec(),
        ..Default::default()
    }
    .copy_stats()
}

/// Every copied byte on the healthy prefill path is the FFN slab gather:
/// one row per routed slot, re-read from the normed hidden state into the
/// contiguous arena slab. Bucket padding is `resize` (zero-fill, not a
/// copy) and attention fan-out is `Arc`-shared, so the figure is exact.
fn exact_slab_bytes(m: &RoundMetrics) -> u64 {
    ((m.n_slots + m.redispatched_slots) * d_model() * 4) as u64
}

#[test]
fn steady_state_sends_one_ffn_message_per_layer_per_worker() {
    let workers = 2;
    let (_, metrics) = serve_prefill(
        ServeStrategy::DistributionOnly,
        workers,
        false,
        None,
        None,
        mk_rounds(101, 3, 6),
    );
    for (i, m) in metrics.iter().enumerate() {
        // Six variable-length sequences × top-k routing put well over a
        // hundred slots per layer onto eight experts split across two
        // workers, so every worker owns routed groups in every layer —
        // the coalesced plane must send exactly one RunBatch per (layer,
        // worker), where the per-group plane sent one message per
        // (expert, bucket chunk).
        assert_eq!(
            m.ffn_messages,
            (n_layers() * workers) as u64,
            "round {i}: one coalesced batch per (layer, assigned worker), \
             got {} messages for {} slots",
            m.ffn_messages,
            m.n_slots
        );
        assert_eq!(m.redispatched_slots, 0, "round {i}: healthy run");
        assert_eq!(
            m.bytes_copied,
            exact_slab_bytes(m),
            "round {i}: every copied byte must be the slab gather \
             (n_slots={} × d={} × 4)",
            m.n_slots,
            d_model()
        );
        assert_eq!(
            m.bytes_shared, 0,
            "round {i}: leader attention shares nothing"
        );
    }
    let s = copy_stats(&metrics);
    assert!(
        s.copied_frac() > 0.999,
        "with the fan-out off, all accounted traffic is the gather: {s:?}"
    );
}

#[test]
fn arc_attention_fanout_is_bitwise_identical_and_copies_nothing() {
    let leader = serve_prefill(
        ServeStrategy::DistributionOnly,
        2,
        false,
        None,
        None,
        mk_rounds(7, 3, 4),
    );
    let fanned = serve_prefill(
        ServeStrategy::DistributionOnly,
        2,
        true,
        None,
        None,
        mk_rounds(7, 3, 4),
    );
    assert_bitwise_eq(&leader.0, &fanned.0, "Arc-shared attention fan-out");
    for (i, (lm, fm)) in leader.1.iter().zip(&fanned.1).enumerate() {
        assert_eq!(lm.n_slots, fm.n_slots, "round {i}: identical routing");
        // The fan-out ships every per-sequence hidden batch to a worker —
        // but as a read-shared Arc view, so the bytes land in
        // `bytes_shared` while `bytes_copied` stays at the exact FFN
        // slab-gather figure. That equality *is* the zero-copy claim: if
        // the attention path deep-copied even one tensor, `bytes_copied`
        // would exceed n_slots × d × 4.
        assert_eq!(lm.bytes_shared, 0, "round {i}: leader attention");
        assert!(
            fm.bytes_shared > 0,
            "round {i}: the fan-out must account its shared batches"
        );
        assert_eq!(
            lm.bytes_copied,
            exact_slab_bytes(lm),
            "round {i}: leader-attention copies are the gather only"
        );
        assert_eq!(
            fm.bytes_copied,
            exact_slab_bytes(fm),
            "round {i}: fanned-out attention adds zero copied bytes"
        );
    }
    let s = copy_stats(&fanned.1);
    assert!(
        s.copied_frac() < 1.0,
        "shared traffic must pull the copied fraction below 1: {s:?}"
    );
}

#[test]
fn slab_batches_fail_over_bitwise_with_exact_accounting() {
    let healthy = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        false,
        None,
        None,
        mk_rounds(53, 4, 3),
    );
    // Worker 1 crashes on its first op: its coalesced batches time out as
    // single countable ops, every slot they carried regroups onto
    // survivors and re-gathers into fresh slabs exactly once.
    let faulted = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        false,
        Some("kill:1@1"),
        Some(0.25),
        mk_rounds(53, 4, 3),
    );
    assert_bitwise_eq(&healthy.0, &faulted.0, "failover with slab batches");
    let deaths: usize = faulted.1.iter().map(|m| m.worker_deaths).sum();
    assert_eq!(deaths, 1, "exactly one injected death");
    let redispatched: usize = faulted.1.iter().map(|m| m.redispatched_slots).sum();
    assert!(redispatched > 0, "the dead worker's slots must redispatch");
    for (i, m) in faulted.1.iter().enumerate() {
        assert_eq!(
            m.bytes_copied,
            exact_slab_bytes(m),
            "round {i}: failover re-gathers each redispatched slot once \
             (n_slots={} redispatched={})",
            m.n_slots,
            m.redispatched_slots
        );
    }
}

#[test]
fn decode_with_slab_batches_loses_no_sequences_under_total_loss() {
    let mut coord =
        Coordinator::with_source(&small_source(), 1, ServeStrategy::NoPrediction).unwrap();
    coord.set_fault_plan(&FaultPlan::parse("kill@3").unwrap());
    coord.set_worker_timeout(Some(0.2));
    let requests = decode_requests(19, coord.vocab(), 3, 4, 4);
    let report: DecodeReport = coord
        .serve_decode(requests, &greedy_decode_opts(3, 16, 19))
        .unwrap();
    let s = report.fault_summary();
    assert_eq!(s.worker_deaths, 1, "the only worker died: {s:?}");
    assert_eq!(
        s.lost_seqs, 0,
        "coalesced slab batches must not weaken the chaos gate — every \
         admitted sequence finishes, requeues or is explicitly evicted: {s:?}"
    );
    let c = report.copy_stats();
    assert!(
        c.ffn_messages > 0,
        "decode dispatch goes through RunBatch: {c:?}"
    );
    assert!(
        c.bytes_copied > 0,
        "decode gathers account their slab bytes: {c:?}"
    );
}
