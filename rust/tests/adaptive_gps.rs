//! Closed-loop GPS acceptance suite (ADR 005).
//!
//! Pins the three contracts the online controller + calibrator must hold:
//!
//! 1. **Parity** — adaptive serving whose decisions are pinned is bitwise
//!    identical to fixed-strategy serving (the controller observes and
//!    records but the engine regime never moves, so numerics cannot).
//! 2. **Drift flip** — a synthetic skew-ramp measurement trace provably
//!    flips DOP→TEP at a replan boundary, the decision trace records the
//!    flip, and hysteresis delays it by the configured streak.
//! 3. **Calibration fidelity** — constants calibrated from an undrifted
//!    measurement window reproduce the static sim's savings exactly, and
//!    the `advise --from-serve` guideline map equals the static map when
//!    the measured error matches the offline prior (the ratio-anchoring
//!    identity).

mod common;
use std::sync::OnceLock;

use common::{
    assert_bitwise_eq, decode_requests, greedy_decode_opts, mk_rounds,
    small_source as source,
};
use moe_gps::coordinator::{
    Coordinator, ControllerConfig, ServeStrategy, StrategyController,
};
use moe_gps::gps::calibrate::{calibrate_all, interpolate_for_skew, WorkloadCalibration};
use moe_gps::gps::guidelines::decision_map_in;
use moe_gps::gps::select::{recommend, Recommendation, Regime, ServePhase};
use moe_gps::gps::{parse_serve_report, MeasuredConstants, OnlineCalibrator, WindowSample};
use moe_gps::model::ModelConfig;
use moe_gps::runtime::HostTensor;
use moe_gps::sim::SystemSpec;

/// Fast offline calibration priors, computed once for the whole binary
/// (every controller in these tests shares them).
fn priors() -> &'static Vec<WorkloadCalibration> {
    static PRIORS: OnceLock<Vec<WorkloadCalibration>> = OnceLock::new();
    PRIORS.get_or_init(|| {
        calibrate_all(
            &ModelConfig::mixtral_8x7b(),
            &SystemSpec::four_a100_nvlink(),
            true,
            7,
        )
    })
}

fn controller(cfg: ControllerConfig) -> StrategyController {
    StrategyController::with_cals(cfg, priors().clone())
}

// ---------------------------------------------------------------- parity

fn serve_prefill_outputs(
    strategy: ServeStrategy,
    adaptive_pinned: bool,
) -> (Vec<Vec<HostTensor>>, Option<usize>) {
    let mut coord = Coordinator::with_source(&source(), 4, strategy).unwrap();
    coord.lookahead = 1;
    if adaptive_pinned {
        coord.controller = Some(controller(ControllerConfig {
            pinned: true,
            min_window: 1,
            hysteresis: 1,
            margin_frac: 0.0,
            phase: ServePhase::Prefill,
            ..Default::default()
        }));
    }
    let rounds = mk_rounds(71, 4, 3);
    let mut outputs = Vec::new();
    // Mirror `Coordinator::serve`'s boundary protocol by hand so per-round
    // outputs can be captured: consult the controller before each round
    // past the first, observe the real metrics after.
    for (i, round) in rounds.iter().enumerate() {
        if i > 0 {
            if let Some(mut ctrl) = coord.controller.take() {
                let regime = coord.current_regime();
                if let Some(d) = ctrl.decide(
                    i,
                    coord.strategy,
                    coord.speculative,
                    coord.lookahead,
                    regime,
                ) {
                    coord.apply_decision(&d);
                }
                coord.controller = Some(ctrl);
            }
        }
        let (m, out) = coord.serve_round(round).unwrap();
        if let Some(ctrl) = coord.controller.as_mut() {
            ctrl.observe_round(&m);
        }
        outputs.push(out);
    }
    let decisions = coord.controller.as_ref().map(|c| c.decisions().len());
    (outputs, decisions)
}

#[test]
fn adaptive_pinned_is_bitwise_identical_to_fixed() {
    for strategy in [
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let (fixed, _) = serve_prefill_outputs(strategy, false);
        let (adaptive, _) = serve_prefill_outputs(strategy, true);
        assert_bitwise_eq(
            &fixed,
            &adaptive,
            &format!("adaptive-pinned vs fixed ({})", strategy.name()),
        );
    }
}

#[test]
fn adaptive_pinned_decode_is_bitwise_identical_to_fixed() {
    let run = |adaptive_pinned: bool| {
        let mut coord =
            Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
        coord.placement.replan_interval = 2;
        if adaptive_pinned {
            coord.controller = Some(controller(ControllerConfig {
                pinned: true,
                min_window: 1,
                hysteresis: 1,
                margin_frac: 0.0,
                phase: ServePhase::Decode,
                ..Default::default()
            }));
        }
        let requests = decode_requests(73, coord.vocab(), 4, 6, 8);
        coord
            .serve_decode(requests, &greedy_decode_opts(4, 24, 73))
            .unwrap()
    };
    let fixed = run(false);
    let adaptive = run(true);
    assert_eq!(fixed.steps.len(), adaptive.steps.len(), "step count");
    for (a, b) in fixed.steps.iter().zip(&adaptive.steps) {
        assert_eq!(a.n_slots, b.n_slots, "step {} slots", a.step);
        assert_eq!(
            a.n_decode_tokens, b.n_decode_tokens,
            "step {} decode rows",
            a.step
        );
    }
    // The pinned controller recorded its evaluations without switching.
    let ctrl = adaptive.controller.expect("controller report present");
    assert!(ctrl.switch_count() == 0, "pinned must never switch");
    assert!(
        !ctrl.decisions.is_empty(),
        "boundaries past min_window must be recorded"
    );
    assert_eq!(adaptive.strategy, fixed.strategy, "strategy never moved");
}

// ------------------------------------------------------------ drift flip

/// A measurement window sample shaped like healthy low-skew DOP serving
/// (tight share error) or drifted high-skew serving (estimator lagging,
/// share error blown out).
fn measured_sample(skew: f64, share_l1: f64) -> WindowSample {
    WindowSample {
        tokens: 128.0,
        total_s: 0.25,
        routing_skew: skew,
        pred_share_l1: share_l1,
        pred_share_layers: 2.0,
        ..Default::default()
    }
}

/// Find a bandwidth where the calibrated decision is DOP at the calm
/// operating point and TEP at the drifted one — the crossover the
/// guideline map promises exists (paper §4: TEP gains as communication
/// gets expensive and skew rises).
fn crossover_bandwidth(
    calm: &MeasuredConstants,
    drifted: &MeasuredConstants,
    model: &ModelConfig,
) -> Option<f64> {
    for bw in [600.0, 300.0, 128.0, 64.0, 32.0, 16.0, 8.0] {
        let sys = SystemSpec::four_a100_custom_bw(bw);
        let calm_cmp =
            calm.savings(ServePhase::Prefill, model, &sys, priors(), 1, 512, Regime::default());
        let drift_cmp = drifted.savings(
            ServePhase::Prefill,
            model,
            &sys,
            priors(),
            1,
            512,
            Regime::default(),
        );
        if recommend(&calm_cmp) == Recommendation::DistributionOnly
            && recommend(&drift_cmp) == Recommendation::TokenToExpert
        {
            return Some(bw);
        }
    }
    None
}

#[test]
fn skew_ramp_flips_dop_to_tep_at_a_replan_boundary() {
    let model = ModelConfig::mixtral_8x7b();
    // Calibrate the scenario: a calm window (low skew, tight share error)
    // and a drifted one (high skew, estimator lagging 6x worse).
    let mk_constants = |skew: f64, l1: f64| {
        let mut cal = OnlineCalibrator::new(8);
        for _ in 0..8 {
            cal.push(measured_sample(skew, l1));
        }
        cal.constants().unwrap()
    };
    let calm = mk_constants(1.3, 0.02);
    let drifted = mk_constants(4.5, 0.30);
    let bw = crossover_bandwidth(&calm, &drifted, &model).expect(
        "some bandwidth must put DOP ahead when calm and TEP ahead when \
         drifted — the paper's crossover",
    );

    // Drive the controller across the ramp: 4 calm boundaries, then the
    // measured window drifts. Hysteresis 2 ⇒ the flip lands on the second
    // drifted boundary, not the first.
    let mut ctrl = controller(ControllerConfig {
        hysteresis: 2,
        margin_frac: 0.0,
        min_window: 4,
        window: 4,
        phase: ServePhase::Prefill,
        system: SystemSpec::four_a100_custom_bw(bw),
        model: model.clone(),
        ..Default::default()
    });
    let mut strategy = ServeStrategy::DistributionOnly;
    let mut speculative = false;
    let mut lookahead = 1;
    let mut switch_boundary = None;
    for boundary in 1..=12 {
        // Skew ramp: calm for 4 windows, then drifted.
        let (skew, l1) = if boundary <= 4 { (1.3, 0.02) } else { (4.5, 0.30) };
        ctrl.observe_sample(measured_sample(skew, l1));
        if let Some(d) = ctrl.decide(
            boundary,
            strategy,
            speculative,
            lookahead,
            Regime { overlap: lookahead > 0, speculative, ..Regime::default() },
        ) {
            if d.strategy != strategy && switch_boundary.is_none() {
                switch_boundary = Some(boundary);
            }
            strategy = d.strategy;
            speculative = d.speculative;
            lookahead = d.lookahead;
        }
    }
    assert_eq!(
        strategy,
        ServeStrategy::TokenToExpert,
        "the drifted regime must end on TEP"
    );
    let flip = switch_boundary.expect("a switch must have landed");
    // The window is 4 samples; drift starts landing at boundary 5. With
    // hysteresis 2 the earliest legal flip is boundary 6 (challenger at
    // 5 and 6), and it must land while the ramp is in force.
    assert!(flip >= 6, "hysteresis must delay the flip: flipped at {flip}");
    assert!(flip <= 10, "flip must land during the drift: {flip}");

    // The decision trace records the flip at that boundary.
    let trace = ctrl.decisions();
    let flip_rec = trace
        .iter()
        .find(|d| d.switched)
        .expect("decision trace records the switch");
    assert_eq!(flip_rec.boundary, flip);
    assert_eq!(flip_rec.from, ServeStrategy::DistributionOnly);
    assert_eq!(flip_rec.to, ServeStrategy::TokenToExpert);
    assert!(flip_rec.measured.mean_skew > 3.0, "priced on drifted window");
    // Boundaries before the hysteresis streak completed did not switch.
    assert!(trace
        .iter()
        .filter(|d| d.boundary < flip)
        .all(|d| !d.switched));
    // The report block replays the trace.
    let rep = ctrl.report(strategy);
    assert_eq!(rep.switch_count(), 1);
    assert_eq!(rep.final_strategy, "token-to-expert");
}

// --------------------------------------------- calibration fidelity + map

#[test]
fn undrifted_calibration_reproduces_static_sim_costs() {
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let cals = priors();
    let skew = 2.0;
    // The static prior's error at this skew is what an undrifted run
    // would measure live.
    let (static_err, _) = interpolate_for_skew(cals, skew);
    let mut cal = OnlineCalibrator::new(8);
    for _ in 0..8 {
        cal.push(measured_sample(skew, static_err));
    }
    let measured = cal.constants().unwrap();
    assert!((measured.mean_skew - skew).abs() < 1e-12);
    assert!((measured.dop_error.unwrap() - static_err).abs() < 1e-12);

    let static_cmp = moe_gps::gps::strategy_savings_in(
        &model,
        &system,
        cals,
        skew,
        1,
        512,
        Regime::default(),
    );
    let calibrated_cmp = measured.savings(
        ServePhase::Prefill,
        &model,
        &system,
        cals,
        1,
        512,
        Regime::default(),
    );
    let tol = 1e-9 * static_cmp.baseline_s.max(1.0);
    assert!((calibrated_cmp.baseline_s - static_cmp.baseline_s).abs() < tol);
    assert!((calibrated_cmp.dop_saving_s - static_cmp.dop_saving_s).abs() < tol);
    assert!(
        (calibrated_cmp.tep_best_saving_s - static_cmp.tep_best_saving_s).abs() < tol
    );
    assert_eq!(recommend(&calibrated_cmp), recommend(&static_cmp));
}

#[test]
fn from_serve_map_matches_static_map_on_undrifted_constants() {
    let model = ModelConfig::mixtral_8x7b();
    let cals = priors();
    let skews = [1.0, 1.4, 2.0, 3.0, 4.0];
    let bandwidths = [600.0, 300.0, 128.0, 64.0];
    let skew = 2.0;
    let (static_err, _) = interpolate_for_skew(cals, skew);
    let mut cal = OnlineCalibrator::new(8);
    for _ in 0..8 {
        cal.push(measured_sample(skew, static_err));
    }
    let measured = cal.constants().unwrap();
    // Undrifted measurement ⇒ ratio anchoring is the identity ⇒ the
    // calibrated map IS the static map, cell for cell.
    let adjusted = measured.apply_to_cals(cals);
    for (a, b) in cals.iter().zip(&adjusted) {
        assert!((a.dop_error - b.dop_error).abs() < 1e-12);
    }
    let static_map =
        decision_map_in(&model, cals, &skews, &bandwidths, 1, 512, Regime::default());
    let measured_map =
        decision_map_in(&model, &adjusted, &skews, &bandwidths, 1, 512, Regime::default());
    assert_eq!(static_map.len(), measured_map.len());
    for (s, m) in static_map.iter().zip(&measured_map) {
        assert_eq!(
            s.recommendation, m.recommendation,
            "cell (skew {}, bw {}) must not move on undrifted constants",
            s.skewness, s.bandwidth_gbs
        );
        assert!((s.saving_frac - m.saving_frac).abs() < 1e-9);
    }
    // A drifted measurement (worse live error) does move the calibration.
    let mut drifted_cal = OnlineCalibrator::new(8);
    for _ in 0..8 {
        drifted_cal.push(measured_sample(skew, static_err * 3.0));
    }
    let drifted = drifted_cal.constants().unwrap().apply_to_cals(cals);
    assert!(drifted[0].dop_error > cals[0].dop_error * 2.0);
}

// ------------------------------------------------- report JSON round trip

#[test]
fn serve_report_json_parses_back_with_measured_constants() {
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::TokenToExpert).unwrap();
    coord.lookahead = 1;
    let rounds = mk_rounds(77, 6, 3);
    let report = coord.serve(rounds).unwrap();
    let json = report.to_json().to_string_pretty();
    let served = parse_serve_report(&json).expect("report round-trips");
    assert_eq!(served.phase, ServePhase::Prefill);
    assert_eq!(served.strategy, "token-to-expert");
    assert!(served.regime.overlap, "lookahead recorded as overlap regime");
    assert!(!served.adaptive);
    assert!(served.measured.samples >= 6);
    assert!(served.measured.mean_skew >= 1.0);
    assert!(
        served.measured.tep_topk_hit.is_some(),
        "TEP runs must measure a realized top-k hit rate"
    );
    assert!(
        served.measured.dop_error.is_some(),
        "predicted-vs-routed share error must be measured"
    );
    let check = served.check.expect("6 rounds is enough for the check");
    assert!(check.delta_frac.is_finite());
    // Realized accuracy flows into the aggregate report too. Top-k is a
    // per-slot rate, top-1 a per-token rate (the offline definition), so
    // both live in [0, 1] but neither bounds the other.
    let hit = report.realized_topk_hit_rate().expect("TEP slots were scored");
    assert!((0.0..=1.0).contains(&hit));
    let top1 = report.realized_top1_rate().expect("TEP tokens were scored");
    assert!((0.0..=1.0).contains(&top1));
    assert!(report.mean_pred_share_l1().unwrap() >= 0.0);
}

#[test]
fn adaptive_decode_serve_records_decisions_at_replan_boundaries() {
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
    coord.placement.replan_interval = 4;
    coord.controller = Some(controller(ControllerConfig {
        min_window: 2,
        hysteresis: 1,
        margin_frac: 0.0,
        phase: ServePhase::Decode,
        batch: 4,
        seq_or_ctx: 64,
        ..Default::default()
    }));
    let requests = decode_requests(79, coord.vocab(), 4, 6, 12);
    let report = coord
        .serve_decode(requests, &greedy_decode_opts(4, 32, 79))
        .unwrap();
    let ctrl = report.controller.as_ref().expect("controller report");
    assert!(
        !ctrl.decisions.is_empty(),
        "boundaries past min_window must be evaluated"
    );
    let mut prev = 0usize;
    for d in &ctrl.decisions {
        assert!(d.boundary > prev, "boundaries strictly increase");
        assert_eq!(
            d.boundary % 4,
            0,
            "consultation follows the replan cadence uniformly"
        );
        prev = d.boundary;
    }
    assert!(ctrl.calibrated.is_some(), "final constants recorded");
    // The JSON report round-trips with the decision trace attached.
    let served = parse_serve_report(&report.to_json().to_string_pretty()).unwrap();
    assert!(served.adaptive);
    assert_eq!(served.decisions, ctrl.decisions.len());
    assert_eq!(served.switches, ctrl.switch_count());
}
