//! Pool placement tests (ADR 007): pinning helpers to cores must never
//! change numerics. This lives in its own integration-test binary so it
//! can enable pinning *before* the process-wide pool first spins up —
//! `tests/tiled_backend.rs` runs the identical oracles unpinned, so the
//! two binaries together pin down "pinned == unpinned, bitwise": both
//! compare the pool kernels against the same serial references.
//!
//! On machines where `sched_setaffinity` is refused (non-linux, seccomp
//! sandboxes) pinning degrades to a no-op; the numeric assertions still
//! run, and the degraded placement is reported to stderr rather than
//! failing the suite.

use std::sync::Once;

use moe_gps::runtime::pool;
use moe_gps::runtime::reference::matmul;
use moe_gps::runtime::{Engine, HostTensor, In, SyntheticSpec};
use moe_gps::util::rng::Rng;

/// Request pinning exactly once, before any test touches the pool.
fn setup_pinned() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        pool::configure_pinning(true);
        if !pool::pinning() {
            eprintln!(
                "note: pinning requested but inactive (non-linux or sandboxed \
                 sched_setaffinity) — numeric assertions still apply"
            );
        } else {
            assert!(
                pool::pin_leader(),
                "pool reports pinned but the leader pin failed"
            );
        }
    });
}

fn naive_matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            moe_gps::runtime::simd::axpy_portable(av, brow, orow);
        }
    }
    out
}

#[test]
fn pinned_matmul_bitwise_matches_serial_reference() {
    setup_pinned();
    let mut rng = Rng::new(0xF1A7);
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (128, 256, 64), (257, 130, 67)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = matmul(&a, m, k, &b, n);
        let want = naive_matmul(&a, m, k, &b, n);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "({m},{k},{n}) elem {i}: pinned pool {x} vs serial {y}"
            );
        }
    }
}

#[test]
fn pinned_attention_is_run_stable() {
    setup_pinned();
    let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
    let s = 24usize;
    let d = 64usize;
    let x = HostTensor::new(
        (0..s * d).map(|i| ((i % 19) as f32 - 9.0) * 0.05).collect(),
        vec![s, d],
    );
    let runs: Vec<HostTensor> = (0..3)
        .map(|_| {
            let args = vec![
                In::T(&x),
                In::W("layers.0.attn.ln"),
                In::W("layers.0.attn.wq"),
                In::W("layers.0.attn.wk"),
                In::W("layers.0.attn.wv"),
                In::W("layers.0.attn.wo"),
            ];
            engine.call("attention", &args).unwrap().remove(0)
        })
        .collect();
    for run in &runs[1..] {
        for (a, b) in runs[0].data.iter().zip(&run.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "pinned attention must be run-stable");
        }
    }
}

#[test]
fn chunk_floor_keeps_small_ops_cheap_and_correct() {
    setup_pinned();
    // A matvec-sized op lands under the bytes-per-task floor: it must
    // still be correct (and identical to the serial kernel) even though
    // chunking collapses it to at most a task or two.
    let mut rng = Rng::new(42);
    let (m, k, n) = (1usize, 512usize, 512usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let got = matmul(&a, m, k, &b, n);
    let want = naive_matmul(&a, m, k, &b, n);
    for (x, y) in got.iter().zip(&want) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
