//! The ADR-004 residency subsystem, end to end: serving under a
//! `--memory-cap` must evict real weights (engine-side, via
//! `WorkerMsg::Evict`), keep the per-worker resident high-water mark
//! under the cap, pay the refetch transfer it traded memory for — and
//! change **nothing** about the numerics: capped serving is bitwise
//! identical to unbounded serving across strategies, lookahead depths and
//! prewarm budgets.

mod common;
use common::{
    assert_bitwise_eq, decode_fingerprint, decode_requests, greedy_decode_opts, mk_rounds,
    small_source as small, tiny_source as tiny,
};
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::{Coordinator, DecodeReport, ServeStrategy};
use moe_gps::runtime::{EngineSource, HostTensor};

struct PrefillRun {
    outputs: Vec<Vec<HostTensor>>,
    evictions: u64,
    refetch_bytes: u64,
    high_water: u64,
    upload_bytes: u64,
    hidden_bytes: u64,
    exposed_bytes: u64,
}

fn serve_prefill(
    source: &EngineSource,
    strategy: ServeStrategy,
    lookahead: usize,
    cap_replicas: Option<u64>,
    prewarm_budget: Option<u64>,
    rounds: Vec<Vec<Request>>,
) -> PrefillRun {
    let mut coord = Coordinator::with_source(source, 4, strategy).unwrap();
    coord.lookahead = lookahead;
    coord.prewarm_budget_bytes = prewarm_budget;
    let replica = coord.residency().replica_bytes();
    coord.set_memory_cap(cap_replicas.map(|n| n * replica));
    let mut run = PrefillRun {
        outputs: Vec::new(),
        evictions: 0,
        refetch_bytes: 0,
        high_water: 0,
        upload_bytes: 0,
        hidden_bytes: 0,
        exposed_bytes: 0,
    };
    for round in rounds {
        let (m, out) = coord.serve_round(&round).unwrap();
        assert_eq!(
            m.hidden_upload_bytes + m.exposed_upload_bytes,
            m.upload_bytes,
            "hidden + exposed must equal total under any cap"
        );
        run.evictions += m.evictions;
        run.refetch_bytes += m.refetch_upload_bytes;
        run.high_water = run.high_water.max(m.resident_high_water_bytes);
        run.upload_bytes += m.upload_bytes;
        run.hidden_bytes += m.hidden_upload_bytes;
        run.exposed_bytes += m.exposed_upload_bytes;
        run.outputs.push(out);
    }
    run
}

/// The acceptance triple: evictions > 0, high-water ≤ cap, outputs
/// bitwise identical to the unbounded run. Baseline strategy on the
/// 2-layer model without lookahead: the pin window is one layer (2
/// replicas per worker), the per-worker working set is 4, and the cap of
/// 3 forces the LRU to cycle layers in and out every round.
#[test]
fn capped_prefill_is_bitwise_identical_with_real_evictions() {
    let rounds = mk_rounds(101, 3, 3);
    let unbounded = serve_prefill(
        &small(),
        ServeStrategy::NoPrediction,
        0,
        None,
        None,
        rounds.clone(),
    );
    assert_eq!(unbounded.evictions, 0, "no cap, no evictions");
    assert_eq!(unbounded.refetch_bytes, 0);
    assert!(unbounded.high_water > 0, "residency must be tracked");

    let cap_replicas = 3u64;
    let capped = serve_prefill(
        &small(),
        ServeStrategy::NoPrediction,
        0,
        Some(cap_replicas),
        None,
        rounds,
    );
    assert_bitwise_eq(&unbounded.outputs, &capped.outputs, "capped vs unbounded");
    assert!(capped.evictions > 0, "the cap must evict");
    assert!(capped.refetch_bytes > 0, "round 2+ must refetch evicted layers");
    let coord = Coordinator::with_source(&small(), 4, ServeStrategy::NoPrediction).unwrap();
    let replica = coord.residency().replica_bytes();
    assert!(
        capped.high_water <= cap_replicas * replica,
        "high-water {} must stay under the cap {}",
        capped.high_water,
        cap_replicas * replica
    );
    assert!(
        capped.high_water < unbounded.high_water,
        "the cap must actually bound memory below the unbounded peak"
    );
    // The memory the cap saved was paid for in refetch transfer.
    assert!(capped.upload_bytes > unbounded.upload_bytes);
    assert_eq!(
        capped.upload_bytes - unbounded.upload_bytes,
        capped.refetch_bytes,
        "every extra uploaded byte must be an accounted refetch"
    );
}

/// Same acceptance under budgeted multi-step lookahead on the 4-layer
/// model: the pin window spans two layers, the cap spans six replicas,
/// and prewarm + dispatch admissions both hit the LRU. DOP replication
/// exercises plan-driven placements; numerics must not move.
#[test]
fn capped_lookahead_prefill_matches_unbounded_bitwise() {
    let rounds = mk_rounds(77, 3, 3);
    let unbounded = serve_prefill(
        &tiny(),
        ServeStrategy::DistributionOnly,
        1,
        None,
        None,
        rounds.clone(),
    );
    let capped = serve_prefill(
        &tiny(),
        ServeStrategy::DistributionOnly,
        1,
        Some(6),
        None,
        rounds.clone(),
    );
    assert_bitwise_eq(&unbounded.outputs, &capped.outputs, "capped DOP lookahead");
    assert!(capped.evictions > 0, "8 replicas/worker vs cap 6 must evict");
    assert!(capped.upload_bytes >= unbounded.upload_bytes);

    // Baseline strategy (fixed 2 replicas/worker/layer, pinned window of
    // 2 layers = 4 < cap 6): the strict high-water guarantee holds.
    let base_unbounded = serve_prefill(
        &tiny(),
        ServeStrategy::NoPrediction,
        1,
        None,
        None,
        rounds.clone(),
    );
    let base_capped = serve_prefill(
        &tiny(),
        ServeStrategy::NoPrediction,
        1,
        Some(6),
        None,
        rounds,
    );
    assert_bitwise_eq(
        &base_unbounded.outputs,
        &base_capped.outputs,
        "capped baseline lookahead",
    );
    assert!(base_capped.evictions > 0);
    let coord = Coordinator::with_source(&tiny(), 4, ServeStrategy::NoPrediction).unwrap();
    let replica = coord.residency().replica_bytes();
    assert!(
        base_capped.high_water <= 6 * replica,
        "lookahead high-water {} over cap {}",
        base_capped.high_water,
        6 * replica
    );
}

/// A zero prewarm budget silences the prewarm stream entirely (nothing
/// hides) without touching numerics; an unbudgeted run hides > 0.
#[test]
fn prewarm_budget_gates_hidden_transfer_not_numerics() {
    let rounds = mk_rounds(55, 2, 3);
    let free = serve_prefill(
        &small(),
        ServeStrategy::DistributionOnly,
        1,
        None,
        None,
        rounds.clone(),
    );
    assert!(free.hidden_bytes > 0, "unbudgeted lookahead must hide bytes");
    let starved = serve_prefill(
        &small(),
        ServeStrategy::DistributionOnly,
        1,
        None,
        Some(0),
        rounds.clone(),
    );
    assert_eq!(starved.hidden_bytes, 0, "budget 0 must issue no prewarms");
    assert_bitwise_eq(&free.outputs, &starved.outputs, "budget 0 vs unbudgeted");
    // A one-replica-per-step budget lands in between: some prewarms issue
    // (hidden > 0), and numerics still hold.
    let coord =
        Coordinator::with_source(&small(), 4, ServeStrategy::DistributionOnly).unwrap();
    let replica = coord.residency().replica_bytes();
    let trickle = serve_prefill(
        &small(),
        ServeStrategy::DistributionOnly,
        1,
        None,
        Some(replica),
        rounds,
    );
    assert!(trickle.hidden_bytes > 0);
    // A starved budget can only skip prewarms, never add transfers — and
    // unbudgeted lookahead may warm plan pairs dispatch never touches.
    assert!(trickle.upload_bytes <= free.upload_bytes);
    assert!(starved.upload_bytes <= trickle.upload_bytes);
    assert_bitwise_eq(&free.outputs, &trickle.outputs, "trickle budget");
}

fn decode_run(cap_replicas: Option<u64>) -> (DecodeReport, u64) {
    let mut coord =
        Coordinator::with_source(&small(), 4, ServeStrategy::NoPrediction).unwrap();
    let replica = coord.residency().replica_bytes();
    coord.set_memory_cap(cap_replicas.map(|n| n * replica));
    let requests = decode_requests(23, 512, 4, 6, 5);
    let report = coord
        .serve_decode(requests, &greedy_decode_opts(3, 64, 5))
        .unwrap();
    (report, replica)
}

/// Greedy decode under a tight cap: identical token trajectory (the
/// sampled tokens feed back into every later step, so any numeric drift
/// would diverge it), evictions every revisit, high-water ≤ cap.
#[test]
fn capped_decode_trajectory_is_identical_and_bounded() {
    let (free, replica) = decode_run(None);
    let (capped, _) = decode_run(Some(3));
    assert!(!free.steps.is_empty());
    assert_eq!(
        decode_fingerprint(&free),
        decode_fingerprint(&capped),
        "trajectory moved"
    );
    assert_eq!(free.total_evictions(), 0);
    assert!(capped.total_evictions() > 0, "every step cycles the 2 layers");
    assert!(capped.total_refetch_upload_bytes() > 0);
    assert!(capped.resident_high_water_bytes() <= 3 * replica);
    assert!(
        capped.resident_high_water_bytes() < free.resident_high_water_bytes(),
        "cap must bound decode residency below the unbounded peak"
    );
}

/// Counter conservation at the report level: evictions and refetches are
/// flows that reconcile with the upload accounting (a refetched byte is
/// an uploaded byte), and an unbounded run reports strict zeros.
#[test]
fn residency_counters_conserve_across_a_run() {
    let rounds = mk_rounds(31, 4, 2);
    let capped = serve_prefill(
        &small(),
        ServeStrategy::NoPrediction,
        0,
        Some(3),
        None,
        rounds.clone(),
    );
    // Refetch bytes are a subset of all uploaded bytes…
    assert!(capped.refetch_bytes <= capped.upload_bytes);
    // …and each refetch re-uploads exactly one replica's bytes, so the
    // flow is replica-granular.
    let coord = Coordinator::with_source(&small(), 4, ServeStrategy::NoPrediction).unwrap();
    let replica = coord.residency().replica_bytes();
    assert_eq!(capped.refetch_bytes % replica, 0);
    // Evictions outnumber (or equal) refetches: nothing is refetched that
    // was not first evicted.
    assert!(capped.evictions * replica >= capped.refetch_bytes);
    let unbounded = serve_prefill(
        &small(),
        ServeStrategy::NoPrediction,
        0,
        None,
        None,
        rounds,
    );
    assert_eq!(unbounded.evictions, 0);
    assert_eq!(unbounded.refetch_bytes, 0);
}
