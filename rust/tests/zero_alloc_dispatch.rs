//! Tile-pool reuse invariants for the zero-alloc FFN dispatch path
//! (ADR 003).
//!
//! Two contracts:
//! 1. **Steady state is zero-alloc**: with a stable workload (identical
//!    rounds, static placement) every tile buffer after the first round
//!    comes from the pool — `tile_allocs == 0`, `tile_reuses > 0`.
//! 2. **Pooled ≡ fresh**: the first round runs entirely on fresh
//!    allocations, later rounds entirely on recycled buffers; identical
//!    requests must produce bitwise-identical outputs either way.

use moe_gps::coordinator::request::{Request, RequestGen};
use moe_gps::coordinator::{Coordinator, DecodeOptions, ServeStrategy};
use moe_gps::runtime::{EngineSource, HostTensor, SyntheticSpec};

fn source() -> EngineSource {
    EngineSource::Synthetic(SyntheticSpec::small_test())
}

fn requests(seed: u64, n: usize) -> Vec<Request> {
    let mut gen = RequestGen::new(seed, 512);
    (0..n).map(|_| gen.request_varlen(8, 24)).collect()
}

fn assert_bitwise(a: &[HostTensor], b: &[HostTensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: seq count");
    for (seq, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape, tb.shape, "{what}: seq {seq} shape");
        for (i, (&x, &y)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: seq {seq} elem {i}");
        }
    }
}

#[test]
fn steady_state_rounds_allocate_nothing_and_match_the_fresh_path() {
    // Static placement + identical requests → identical routing every
    // round, so the bucket mix repeats and the pool must fully absorb it.
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::NoPrediction).unwrap();
    let reqs = requests(31, 3);

    let (m1, out1) = coord.serve_round(&reqs).unwrap();
    assert!(m1.tile_allocs > 0, "first round must allocate its tiles");
    assert!(m1.n_slots > 0);

    for round in 2..=4 {
        let (m, out) = coord.serve_round(&reqs).unwrap();
        assert_eq!(
            m.tile_allocs, 0,
            "round {round} must be zero-alloc (reuses={})",
            m.tile_reuses
        );
        assert!(m.tile_reuses > 0, "round {round} must recycle tiles");
        assert_eq!(m.n_slots, m1.n_slots, "routing must repeat");
        // Pooled path ≡ fresh-alloc path, bitwise.
        assert_bitwise(&out1, &out, &format!("fresh round vs pooled round {round}"));
    }
}

#[test]
fn dop_rounds_reach_reuse_quickly_even_as_plans_evolve() {
    // DOP replans as its estimators learn; the bucket mix can drift, so
    // the invariant is weaker — reuse dominates after warmup rather than
    // allocs being exactly zero.
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
    let mut gen = RequestGen::new(47, 512);
    let mut warm_allocs = 0u64;
    let mut warm_reuses = 0u64;
    for round in 0..6 {
        let reqs: Vec<Request> = (0..3).map(|_| gen.request_varlen(8, 24)).collect();
        let (m, _) = coord.serve_round(&reqs).unwrap();
        if round >= 2 {
            warm_allocs += m.tile_allocs;
            warm_reuses += m.tile_reuses;
        }
    }
    assert!(warm_reuses > 0, "warm rounds must recycle tiles");
    assert!(
        warm_reuses >= warm_allocs * 4,
        "reuse must dominate once the pool is warm: reuses={warm_reuses} allocs={warm_allocs}"
    );
}

#[test]
fn decode_steps_recycle_tiles_in_steady_state() {
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::NoPrediction).unwrap();
    let mut gen = RequestGen::new(5, 512);
    let reqs: Vec<Request> = (0..3).map(|_| gen.decode_request(6, 8)).collect();
    let report = coord
        .serve_decode(
            reqs,
            &DecodeOptions {
                max_active: 3,
                max_steps: 32,
                temperature: 0.0,
                seed: 9,
                arrival_interval: 0,
            },
        )
        .unwrap();
    assert!(report.steps.len() > 4);
    // Steady-state decode: one token per sequence per step → identical
    // bucket mix every step → zero allocation after warmup.
    let steady: Vec<_> = report.steps.iter().filter(|s| s.is_steady_state()).collect();
    assert!(steady.len() >= 2, "need steady steps to assert on");
    for s in &steady[1..] {
        assert_eq!(
            s.tile_allocs, 0,
            "steady decode step {} must be zero-alloc",
            s.step
        );
        assert!(s.tile_reuses > 0, "steady decode step {} must reuse", s.step);
    }
    assert!(report.total_tile_allocs() + report.total_tile_reuses() > 0);
}
