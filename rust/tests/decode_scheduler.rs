//! Integration tests for the decode-phase continuous-batching subsystem:
//! scheduler admission/eviction ordering, batch-size invariants under
//! bucket padding on the real coordinator (synthetic small model), and
//! Distribution-Only estimator convergence over ≥64 decode steps
//! (DESIGN.md §7).

use moe_gps::coordinator::placement_mgr::PlacementManager;
use moe_gps::coordinator::request::{Request, RequestGen};
use moe_gps::coordinator::{Coordinator, DecodeOptions, Scheduler, ServeStrategy};
use moe_gps::runtime::{EngineSource, SyntheticSpec};
use moe_gps::util::rng::Rng;

// ---------------------------------------------------------------------
// Scheduler: admission / eviction ordering
// ---------------------------------------------------------------------

#[test]
fn admission_and_eviction_preserve_fifo_order() {
    let mut sched = Scheduler::new(3);
    for id in 0..10u64 {
        // Mixed budgets so sequences finish at different steps.
        let budget = 1 + (id % 3) as usize;
        sched.push(Request::new(id, vec![7; 4]).with_max_new_tokens(budget));
    }
    let mut step = 0usize;
    while !sched.is_idle() {
        sched.admit(step);
        assert!(sched.active_len() <= 3, "batch-size invariant violated");
        let ids: Vec<u64> = sched.active().iter().map(|s| s.id).collect();
        for id in ids {
            sched.record_token(id);
        }
        sched.evict_finished();
        step += 1;
        assert!(step < 100, "scheduler failed to drain");
    }
    // Admission must be FIFO over arrival order.
    assert_eq!(sched.admitted_order(), &(0..10).collect::<Vec<u64>>()[..]);
    // Every request finished exactly once.
    let mut finished = sched.finished_order().to_vec();
    finished.sort_unstable();
    assert_eq!(finished, (0..10).collect::<Vec<u64>>());
}

#[test]
fn waiting_requests_enter_only_when_capacity_frees() {
    let mut sched = Scheduler::new(2);
    for id in 0..4u64 {
        sched.push(Request::new(id, vec![1; 2]).with_max_new_tokens(2));
    }
    sched.admit(0);
    assert_eq!(sched.active_len(), 2);
    assert_eq!(sched.waiting_len(), 2);
    // Step 1: neither finishes (budget 2) → no admission possible.
    sched.record_token(0);
    sched.record_token(1);
    sched.evict_finished();
    assert!(sched.admit(1).is_empty());
    // Step 2: both finish → both waiting requests admitted.
    sched.record_token(0);
    sched.record_token(1);
    sched.evict_finished();
    let admitted = sched.admit(2);
    assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
}

// ---------------------------------------------------------------------
// Coordinator end-to-end on the synthetic small model
// ---------------------------------------------------------------------

fn small_coordinator(strategy: ServeStrategy, workers: usize) -> Coordinator {
    let source = EngineSource::Synthetic(SyntheticSpec::small_test());
    Coordinator::with_source(&source, workers, strategy).expect("synthetic coordinator")
}

#[test]
fn decode_run_respects_batch_and_slot_invariants() {
    let mut coord = small_coordinator(ServeStrategy::DistributionOnly, 2);
    coord.placement.replan_interval = 2;
    let mut gen = RequestGen::new(5, 512);
    let requests: Vec<Request> = (0..5).map(|_| gen.decode_request(6, 4)).collect();
    let opts = DecodeOptions {
        max_active: 3,
        max_steps: 64,
        temperature: 1.0,
        seed: 9,
        arrival_interval: 0,
    };
    let report = coord.serve_decode(requests, &opts).unwrap();
    assert!(!report.steps.is_empty());
    // 5 requests × budget 4: the first token of each is sampled at the end
    // of its prefill step, so decode rows = 5 × (4 − 1).
    assert_eq!(report.total_decode_tokens(), 15);
    assert_eq!(report.total_prefill_tokens(), 5 * 6);
    for step in &report.steps {
        // Batch-size invariant: never more than max_active sequences.
        assert!(step.n_seqs <= 3, "step {} ran {} seqs", step.step, step.n_seqs);
        // Slot conservation under bucket padding: every routed slot is
        // dispatched to exactly one worker, per layer.
        let expected_slots = (step.n_prefill_tokens + step.n_decode_tokens) * 2 * 2; // top_k × n_layers
        assert_eq!(step.n_slots, expected_slots, "step {}", step.step);
        let dispatched: usize = step.worker_slots.iter().sum();
        assert_eq!(dispatched, step.n_slots, "slots lost in dispatch");
    }
    // The replan cadence must actually skip replans between boundaries.
    assert!(report.replan_count() < report.steps.len());
}

#[test]
fn strategies_complete_and_generate_identical_token_budgets() {
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = small_coordinator(strategy, 2);
        let mut gen = RequestGen::new(7, 512);
        let requests: Vec<Request> = (0..4).map(|_| gen.decode_request(4, 3)).collect();
        let report = coord
            .serve_decode(requests, &DecodeOptions {
                max_active: 4,
                max_steps: 32,
                temperature: 0.0, // greedy: fully deterministic
                seed: 1,
                arrival_interval: 0,
            })
            .unwrap();
        // 4 sequences × 3 tokens each; the prefill step's sampled token
        // counts toward the budget, so decode rows = total − first tokens.
        let total_generated: usize = 4 * 3;
        let first_tokens = 4; // sampled at the end of each prefill step
        assert_eq!(report.total_decode_tokens(), total_generated - first_tokens);
        assert_eq!(report.total_prefill_tokens(), 4 * 4);
    }
}

#[test]
fn mixed_arrivals_interleave_prefill_with_decode() {
    let mut coord = small_coordinator(ServeStrategy::DistributionOnly, 2);
    let mut gen = RequestGen::new(13, 512);
    let requests: Vec<Request> = (0..3).map(|_| gen.decode_request(5, 6)).collect();
    let report = coord
        .serve_decode(requests, &DecodeOptions {
            max_active: 4,
            max_steps: 64,
            temperature: 1.0,
            seed: 3,
            arrival_interval: 3,
        })
        .unwrap();
    // Some step after the first must carry BOTH prefill and decode rows —
    // that is what continuous batching means.
    assert!(
        report
            .steps
            .iter()
            .any(|s| s.n_prefill_tokens > 0 && s.n_decode_tokens > 0),
        "no step mixed prefill and decode work"
    );
    assert_eq!(report.total_decode_tokens(), 3 * 6 - 3);
}

// ---------------------------------------------------------------------
// DOP estimator convergence over ≥ 64 decode steps
// ---------------------------------------------------------------------

#[test]
fn dop_estimator_converges_over_64_decode_steps() {
    // Feed the per-step observe() path a stationary skewed routing
    // distribution (what decode traffic looks like per arXiv 2404.16914)
    // and check the estimator's plan converges to it.
    let mut mgr = PlacementManager::new(8, 4, 2, 8, 4);
    mgr.replan_interval = 8;
    let true_p = [0.40, 0.20, 0.10, 0.08, 0.08, 0.06, 0.05, 0.03];
    let mut rng = Rng::new(42);
    for step in 0..64 {
        // A decode step's observation: 16 slots multinomially routed.
        let counts = rng.multinomial(16, &true_p);
        for layer in 0..2 {
            mgr.observe(layer, &counts);
        }
        mgr.decode_plans(step, 16);
    }
    let est = mgr.estimators[0].mle();
    let l1: f64 = est
        .iter()
        .zip(&true_p)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(l1 < 0.12, "estimator did not converge: L1={l1}, est={est:?}");
    // And the final plan must replicate the hot expert.
    let plan = mgr.decode_plans(64, 64);
    assert!(plan[0].placement.copies(0) > 1, "hot expert not replicated");
}

// ---------------------------------------------------------------------
// Load balance: DOP vs baseline on the real decode path
// ---------------------------------------------------------------------

#[test]
fn dop_improves_steady_state_slot_balance_over_baseline() {
    let run = |strategy: ServeStrategy| -> f64 {
        let mut coord = small_coordinator(strategy, 4);
        coord.placement.replan_interval = 2;
        let mut gen = RequestGen::new(21, 512);
        let requests: Vec<Request> = (0..8).map(|_| gen.decode_request(8, 10)).collect();
        let report = coord
            .serve_decode(requests, &DecodeOptions {
                max_active: 8,
                max_steps: 64,
                temperature: 1.0,
                seed: 2,
                arrival_interval: 0,
            })
            .unwrap();
        let steady: Vec<f64> = report
            .steps
            .iter()
            .filter(|s| s.is_steady_state())
            .map(|s| s.slot_imbalance())
            .collect();
        assert!(!steady.is_empty());
        steady.iter().sum::<f64>() / steady.len() as f64
    };
    let baseline = run(ServeStrategy::NoPrediction);
    let dop = run(ServeStrategy::DistributionOnly);
    // Small deterministic workload: allow exact ties (+ float noise), but
    // DOP must never be meaningfully worse than the static placement.
    assert!(
        dop <= baseline + 0.02,
        "DOP should not worsen slot balance: baseline={baseline:.3} dop={dop:.3}"
    );
}
