//! Simulator validation: closed-form checks and paper-shape invariants
//! over broad parameter grids (DESIGN.md §7 "integration").

use moe_gps::model::ModelConfig;
use moe_gps::sim::collective::{ep_all_to_all_time, ring_allreduce_time};
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{ErrorModel, LayerSim, SystemSpec};
use moe_gps::testing;
use moe_gps::util::rng::Rng;

fn all_models() -> Vec<ModelConfig> {
    vec![
        ModelConfig::mixtral_8x7b(),
        ModelConfig::mixtral_8x22b(),
        ModelConfig::llama_moe(),
        ModelConfig::switch_transformer(),
        ModelConfig::deepseek_like(),
    ]
}

#[test]
fn baseline_latency_is_monotone_in_skew_for_all_models() {
    for model in all_models() {
        for system in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
            let sim = LayerSim::new(model.clone(), system);
            let mut prev = 0.0;
            for &skew in &[1.0, 1.5, 2.0, 3.0, 4.0, 6.0] {
                let total = sim.baseline_total(skew);
                assert!(
                    total > prev,
                    "{}: baseline must grow with skew ({total} !> {prev})",
                    model.name
                );
                prev = total;
            }
        }
    }
}

#[test]
fn dop_never_loses_to_baseline_under_typical_errors() {
    // With the measured (small) error rates, DOP must never be slower than
    // no-prediction for skew > 1 — it has zero overhead by construction.
    for model in all_models() {
        let sim = LayerSim::new(model.clone(), SystemSpec::four_a100_nvlink());
        for &skew in &[1.1, 1.4, 2.0, 4.0] {
            for &err in &[0.0, 0.02, 0.1] {
                let dop = sim
                    .breakdown(skew, Strategy::DistributionOnly { error_rate: err })
                    .total();
                let base = sim.baseline_total(skew);
                assert!(
                    dop <= base + 1e-12,
                    "{} skew {skew} err {err}: dop {dop} > baseline {base}",
                    model.name
                );
            }
        }
    }
}

#[test]
fn perfect_tep_with_zero_overhead_dominates_everything() {
    for model in all_models() {
        let sim = LayerSim::new(model.clone(), SystemSpec::four_a100_pcie());
        for &skew in &[1.0, 2.0, 4.0] {
            let perfect = sim
                .breakdown(
                    skew,
                    Strategy::TokenToExpert {
                        accuracy: 1.0,
                        overhead_s: 0.0,
                    },
                )
                .total();
            let base = sim.baseline_total(skew);
            let dop = sim
                .breakdown(skew, Strategy::DistributionOnly { error_rate: 0.0 })
                .total();
            assert!(perfect <= base && perfect <= dop, "{}", model.name);
        }
    }
}

#[test]
fn property_breakdowns_are_finite_positive_and_consistent() {
    testing::forall_config(
        testing::Config {
            cases: 128,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let models = all_models();
            let model = models[rng.range(0, models.len())].clone();
            let bw = 16.0 + rng.f64() * 1000.0;
            let skew = 1.0 + rng.f64() * (model.n_experts as f64 - 1.0) * 0.9;
            let batch = 1 << rng.range(0, 5);
            let seq = 128 << rng.range(0, 4);
            let acc = rng.f64();
            let overhead = rng.f64() * 5e-3;
            (model, bw, skew, batch, seq, acc, overhead)
        },
        |(model, bw, skew, batch, seq, acc, overhead)| {
            let sim = LayerSim::new(
                model.clone(),
                SystemSpec::four_a100_custom_bw(*bw),
            )
            .with_workload(*batch, *seq);
            for strategy in [
                Strategy::NoPrediction,
                Strategy::DistributionOnly { error_rate: 1.0 - acc },
                Strategy::TokenToExpert {
                    accuracy: *acc,
                    overhead_s: *overhead,
                },
            ] {
                let b = sim.breakdown(*skew, strategy);
                let total = b.total();
                if !total.is_finite() || total <= 0.0 {
                    return Err(format!("bad total {total} for {strategy:?}"));
                }
                let sum = b.attention_s
                    + b.allreduce_s
                    + b.router_s
                    + b.ffn_s
                    + b.scatter_s
                    + b.gather_s
                    + b.overhead_s
                    + b.movement_s;
                if (sum - total).abs() > 1e-12 {
                    return Err("breakdown does not sum to total".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn collectives_scale_linearly_in_volume() {
    let ic = SystemSpec::four_a100_nvlink().interconnect;
    let base_ar = ring_allreduce_time(&ic, 4, 1e6) - 6.0 * ic.latency_s;
    let double_ar = ring_allreduce_time(&ic, 4, 2e6) - 6.0 * ic.latency_s;
    assert!((double_ar / base_ar - 2.0).abs() < 1e-9);
    let base_a2a = ep_all_to_all_time(&ic, 4, 1000.0, 8192.0, 1.5) - 3.0 * ic.latency_s;
    let double_a2a =
        ep_all_to_all_time(&ic, 4, 2000.0, 8192.0, 1.5) - 3.0 * ic.latency_s;
    assert!((double_a2a / base_a2a - 2.0).abs() < 1e-9);
}

#[test]
fn error_model_orderings_hold_across_grid() {
    let model = ModelConfig::mixtral_8x7b();
    for &skew in &[1.2, 2.0, 3.5] {
        for &eps in &[0.01, 0.1, 0.4] {
            let total_for = |em: ErrorModel| {
                let mut sim =
                    LayerSim::new(model.clone(), SystemSpec::four_a100_nvlink());
                sim.error_model = em;
                sim.breakdown(skew, Strategy::DistributionOnly { error_rate: eps })
                    .total()
            };
            let o = total_for(ErrorModel::Optimistic);
            let t = total_for(ErrorModel::Typical);
            let p = total_for(ErrorModel::Pessimistic);
            assert!(o <= t && t <= p, "skew {skew} eps {eps}: {o} {t} {p}");
        }
    }
}

#[test]
fn switch_transformer_layer_is_much_cheaper_than_mixtral() {
    // Absolute-scale sanity: switch-base (d=768, ReLU, top-1) is a far
    // smaller layer than Mixtral 8x7B.
    let nv = SystemSpec::four_a100_nvlink();
    let mixtral = LayerSim::new(ModelConfig::mixtral_8x7b(), nv.clone());
    let switch = LayerSim::new(ModelConfig::switch_transformer(), nv);
    assert!(switch.baseline_total(1.4) < mixtral.baseline_total(1.4) * 0.3);
}

#[test]
fn mixtral_8x22b_scales_up_but_preserves_dop_win() {
    // Paper §5: scaling model size changes absolute latency, not the
    // qualitative trend.
    let nv = SystemSpec::four_a100_nvlink();
    let small = LayerSim::new(ModelConfig::mixtral_8x7b(), nv.clone());
    let large = LayerSim::new(ModelConfig::mixtral_8x22b(), nv);
    assert!(large.baseline_total(1.4) > small.baseline_total(1.4));
    for sim in [small, large] {
        let perf = sim.normalized_performance(
            1.4,
            Strategy::DistributionOnly { error_rate: 0.018 },
        );
        assert!(perf > 1.0);
    }
}
