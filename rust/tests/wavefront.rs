//! ADR 010 micro-batch wavefront pipelining, end to end. The acceptance
//! claims pinned here:
//!
//! * serving with `--microbatch K` is **bitwise identical** to serial
//!   serving for K ∈ {1, 2, 4}, across prefill rounds and greedy decode —
//!   the per-layer combine accumulates in global slot order regardless of
//!   how the wavefront chunks the slot set;
//! * K = 1 is literally the pre-ADR-010 path: the coalesced-dispatch and
//!   copy-accounting pins from the data-plane suite hold unchanged
//!   (`ffn_messages == layers × workers`, `bytes_copied == slots × d × 4`);
//! * at K > 1 the slab-gather accounting stays exact (the wavefront adds
//!   zero copied bytes) while dispatch grows at most K-fold;
//! * a worker killed mid-wave fails over **bitwise identically**, each
//!   micro-batch slab counting as one op on the fault clock;
//! * the wavefront measurably cuts the workers' idle fraction vs serial
//!   on the same trace — the throughput mechanism the regime exists for.

mod common;
use common::{
    assert_bitwise_eq, decode_fingerprint, decode_requests, greedy_decode_opts, mk_rounds,
    small_source,
};
use moe_gps::coordinator::pipeline::microbatch_ranges;
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::{
    Coordinator, FaultPlan, RoundMetrics, ServeReport, ServeStrategy, WavefrontStats,
};
use moe_gps::runtime::{HostTensor, SyntheticSpec};

fn d_model() -> usize {
    SyntheticSpec::small_test().d_model
}

fn n_layers() -> usize {
    SyntheticSpec::small_test().n_layers
}

/// Drive prefill rounds at a given wavefront depth, with optional fault
/// injection.
fn serve_prefill(
    strategy: ServeStrategy,
    workers: usize,
    microbatch: usize,
    faults: Option<&str>,
    timeout_s: Option<f64>,
    rounds: Vec<Vec<Request>>,
) -> (Vec<Vec<HostTensor>>, Vec<RoundMetrics>) {
    let mut coord = Coordinator::with_source(&small_source(), workers, strategy).unwrap();
    coord.microbatch = microbatch;
    if let Some(spec) = faults {
        coord.set_fault_plan(&FaultPlan::parse(spec).unwrap());
    }
    coord.set_worker_timeout(timeout_s);
    let mut outputs = Vec::new();
    let mut metrics = Vec::new();
    for round in rounds {
        let (m, out) = coord.serve_round(&round).unwrap();
        outputs.push(out);
        metrics.push(m);
    }
    (outputs, metrics)
}

/// Aggregate per-round wavefront counters the way a serve report does.
fn wavefront_stats(rounds: &[RoundMetrics]) -> WavefrontStats {
    ServeReport {
        rounds: rounds.to_vec(),
        ..Default::default()
    }
    .wavefront_stats()
}

/// Every copied byte on the prefill path is the FFN slab gather — at any
/// wavefront depth (chunk gathers partition the slot set exactly).
fn exact_slab_bytes(m: &RoundMetrics) -> u64 {
    ((m.n_slots + m.redispatched_slots) * d_model() * 4) as u64
}

#[test]
fn microbatch_split_is_deterministic_and_contiguous() {
    for n in [1usize, 2, 3, 7, 16, 33] {
        for k in [1usize, 2, 4, 5, 64] {
            let ranges = microbatch_ranges(n, k);
            // Contiguous cover of 0..n in order, no empty chunks.
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "n={n} k={k}: contiguous");
                assert!(r.end > r.start, "n={n} k={k}: no empty chunk");
                next = r.end;
            }
            assert_eq!(next, n, "n={n} k={k}: covers the slot set");
            assert_eq!(ranges.len(), k.min(n).max(1), "n={n} k={k}: chunk count");
        }
    }
}

#[test]
fn wavefront_prefill_is_bitwise_identical_across_depths() {
    let serial = serve_prefill(
        ServeStrategy::DistributionOnly,
        2,
        1,
        None,
        None,
        mk_rounds(71, 3, 6),
    );
    for k in [2usize, 4] {
        let wave = serve_prefill(
            ServeStrategy::DistributionOnly,
            2,
            k,
            None,
            None,
            mk_rounds(71, 3, 6),
        );
        assert_bitwise_eq(&serial.0, &wave.0, &format!("wavefront K={k} vs serial"));
        for (i, (sm, wm)) in serial.1.iter().zip(&wave.1).enumerate() {
            assert_eq!(sm.n_slots, wm.n_slots, "K={k} round {i}: identical routing");
            // The wavefront re-chunks dispatch but never re-copies: every
            // copied byte is still the slab gather, exactly.
            assert_eq!(
                wm.bytes_copied,
                exact_slab_bytes(wm),
                "K={k} round {i}: chunk gathers partition the slot set"
            );
            assert_eq!(sm.bytes_copied, wm.bytes_copied, "K={k} round {i}");
            // Dispatch grows at most K-fold (one batch per chunk × layer ×
            // assigned worker) and never shrinks below the serial floor.
            assert!(
                wm.ffn_messages >= sm.ffn_messages
                    && wm.ffn_messages <= sm.ffn_messages * k as u64,
                "K={k} round {i}: {} messages vs serial {}",
                wm.ffn_messages,
                sm.ffn_messages
            );
        }
    }
}

#[test]
fn microbatch_one_is_the_serial_path_with_its_exact_pins() {
    let workers = 2;
    let (_, metrics) = serve_prefill(
        ServeStrategy::DistributionOnly,
        workers,
        1,
        None,
        None,
        mk_rounds(101, 3, 6),
    );
    // The same pins the data-plane suite holds on the pre-ADR-010 path:
    // K = 1 must not change a single counter.
    for (i, m) in metrics.iter().enumerate() {
        assert_eq!(
            m.ffn_messages,
            (n_layers() * workers) as u64,
            "round {i}: K=1 keeps one coalesced batch per (layer, worker)"
        );
        assert_eq!(m.redispatched_slots, 0, "round {i}: healthy run");
        assert_eq!(
            m.bytes_copied,
            exact_slab_bytes(m),
            "round {i}: K=1 copies exactly the slab gather"
        );
    }
}

#[test]
fn wavefront_decode_trajectory_matches_serial() {
    let run = |k: usize| {
        let mut coord =
            Coordinator::with_source(&small_source(), 2, ServeStrategy::DistributionOnly)
                .unwrap();
        coord.microbatch = k;
        let requests = decode_requests(23, coord.vocab(), 4, 4, 6);
        coord.serve_decode(requests, &greedy_decode_opts(4, 24, 23)).unwrap()
    };
    let serial = run(1);
    for k in [2usize, 4] {
        let wave = run(k);
        // Greedy decode feeds every sampled token back into later steps,
        // so fingerprint equality pins the numerics of the whole run.
        assert_eq!(
            decode_fingerprint(&serial),
            decode_fingerprint(&wave),
            "decode wavefront K={k} must not perturb the trajectory"
        );
        assert_eq!(
            serial.tokens_per_s.is_finite(),
            wave.tokens_per_s.is_finite(),
            "K={k}"
        );
    }
}

#[test]
fn wavefront_fails_over_bitwise_under_a_mid_wave_kill() {
    let healthy = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        1,
        None,
        None,
        mk_rounds(53, 4, 4),
    );
    // Worker 1 dies on its third op — mid-wave at K=4, with other chunks'
    // slabs still in flight. Each micro-batch slab is one countable op,
    // its slots regroup onto survivors and re-gather exactly once.
    let faulted = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        4,
        Some("kill:1@3"),
        Some(0.25),
        mk_rounds(53, 4, 4),
    );
    assert_bitwise_eq(&healthy.0, &faulted.0, "mid-wave failover");
    let deaths: usize = faulted.1.iter().map(|m| m.worker_deaths).sum();
    assert_eq!(deaths, 1, "exactly one injected death");
    let redispatched: usize = faulted.1.iter().map(|m| m.redispatched_slots).sum();
    assert!(redispatched > 0, "the dead worker's chunk slots redispatch");
    for (i, m) in faulted.1.iter().enumerate() {
        assert_eq!(
            m.bytes_copied,
            exact_slab_bytes(m),
            "round {i}: failover under the wavefront re-gathers each \
             redispatched slot once (n_slots={} redispatched={})",
            m.n_slots,
            m.redispatched_slots
        );
    }
}

#[test]
fn wavefront_cuts_the_worker_idle_fraction() {
    // Same trace, same fleet — only the wavefront depth differs. Serial
    // serving leaves the workers idle while the leader routes and
    // combines; at K=4 those stalls overlap in-flight FFN slabs. This is
    // a wall-clock claim, so it aggregates over enough rounds for the
    // idle gap to dominate scheduler noise.
    let serial = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        1,
        None,
        None,
        mk_rounds(97, 6, 10),
    );
    let wave = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        4,
        None,
        None,
        mk_rounds(97, 6, 10),
    );
    assert_bitwise_eq(&serial.0, &wave.0, "idle-fraction trace");
    let s = wavefront_stats(&serial.1);
    let w = wavefront_stats(&wave.1);
    assert!(
        serial.1.iter().all(|m| m.wavefront_window_s > 0.0),
        "serial rounds record the router→combine window too"
    );
    assert!(
        s.worker_idle_frac > 0.0,
        "serial serving must leave idle time to reclaim: {s:?}"
    );
    assert!(
        w.worker_idle_frac < s.worker_idle_frac,
        "K=4 must keep workers busier than serial: wavefront {:.4} vs \
         serial {:.4}",
        w.worker_idle_frac,
        s.worker_idle_frac
    );
    assert!(
        serial.1.iter().chain(&wave.1).all(|m| m.tile_peak > 0),
        "both regimes account their peak outstanding tiles"
    );
}
