//! ADR 008 fault-tolerant serving, end to end: deterministic fault
//! injection (`--inject-faults` / `MOE_GPS_FAULTS`) inside the virtual-GPU
//! workers, deadline-based detection, failover onto surviving replicas of
//! the duplication plan, and degraded-mode replanning. The acceptance
//! claims pinned here:
//!
//! * injection disabled (or never firing) → serving output **bitwise
//!   identical** to a fault-free run, zero fault metrics;
//! * kill one of several workers mid-run → the run completes with the
//!   same bitwise output (expert weights are name-derived, so any alive
//!   host computes identical FFN results) and exactly one recorded death;
//! * a straggler within the backoff window → retries, never a death;
//! * every worker dead mid-decode → active sequences are requeued, not
//!   lost (`lost_seqs == 0` — the chaos CI gate);
//! * a death under `--memory-cap` re-homes experts while the resident
//!   high-water mark stays under the cap.

mod common;
use common::{assert_bitwise_eq, decode_requests, greedy_decode_opts, mk_rounds, small_source};
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::{
    Coordinator, DecodeReport, FaultPlan, RoundMetrics, ServeReport, ServeStrategy,
};
use moe_gps::runtime::HostTensor;

/// Drive prefill rounds through a coordinator with optional fault
/// injection, reply-deadline override and residency cap (in replicas).
fn serve_prefill(
    strategy: ServeStrategy,
    workers: usize,
    faults: Option<&str>,
    timeout_s: Option<f64>,
    cap_replicas: Option<u64>,
    rounds: Vec<Vec<Request>>,
) -> (Vec<Vec<HostTensor>>, Vec<RoundMetrics>) {
    let mut coord = Coordinator::with_source(&small_source(), workers, strategy).unwrap();
    if let Some(spec) = faults {
        coord.set_fault_plan(&FaultPlan::parse(spec).unwrap());
    }
    coord.set_worker_timeout(timeout_s);
    let replica = coord.residency().replica_bytes();
    coord.set_memory_cap(cap_replicas.map(|n| n * replica));
    let mut outputs = Vec::new();
    let mut metrics = Vec::new();
    for round in rounds {
        let (m, out) = coord.serve_round(&round).unwrap();
        outputs.push(out);
        metrics.push(m);
    }
    (outputs, metrics)
}

/// Aggregate round metrics the way a serve report does.
fn summary(rounds: &[RoundMetrics]) -> moe_gps::coordinator::metrics::FaultSummary {
    ServeReport {
        rounds: rounds.to_vec(),
        ..Default::default()
    }
    .fault_summary()
}

#[test]
fn disabled_or_never_firing_injection_is_bitwise_identical() {
    let healthy = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        None,
        None,
        None,
        mk_rounds(31, 3, 3),
    );
    // A plan whose trigger op is far beyond the run installs the fault
    // machinery on every worker but never fires; a generous timeout
    // override exercises the deadline plumbing without ever expiring.
    let armed = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        Some("kill:1@100000, drop:2@100000"),
        Some(30.0),
        None,
        mk_rounds(31, 3, 3),
    );
    assert_bitwise_eq(&healthy.0, &armed.0, "armed-but-unfired injection");
    let s = summary(&armed.1);
    assert!(!s.any(), "no fault may be recorded when none fired: {s:?}");
}

#[test]
fn worker_death_mid_prefill_fails_over_with_identical_output() {
    let healthy = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        None,
        None,
        None,
        mk_rounds(47, 4, 3),
    );
    // Worker 1 crashes on its first op: every group it owned must time
    // out, fail over to a surviving replica (or any alive worker) and
    // recompute to the same bits — expert weights are name-derived, so
    // host identity never touches numerics.
    let faulted = serve_prefill(
        ServeStrategy::DistributionOnly,
        4,
        Some("kill:1@1"),
        Some(0.25),
        None,
        mk_rounds(47, 4, 3),
    );
    assert_bitwise_eq(&healthy.0, &faulted.0, "failover after worker death");
    let s = summary(&faulted.1);
    assert_eq!(s.worker_deaths, 1, "exactly one injected death: {s:?}");
    assert!(s.redispatched_slots > 0, "lost groups must redispatch: {s:?}");
    assert!(s.retries > 0, "detection goes through timeout retries: {s:?}");
    assert!(s.degraded_samples >= 1, "short-handed rounds are degraded: {s:?}");
    // Every round after the death serves short-handed and stays degraded.
    let death_round = faulted.1.iter().position(|m| m.worker_deaths > 0).unwrap();
    for m in &faulted.1[death_round..] {
        assert!(m.degraded, "rounds at/after the death must be degraded");
    }
}

#[test]
fn straggler_within_backoff_window_retries_without_death() {
    let healthy = serve_prefill(
        ServeStrategy::NoPrediction,
        4,
        None,
        None,
        None,
        mk_rounds(63, 3, 3),
    );
    // Worker 0 sleeps 400 ms before its 2nd op; the 150 ms deadline
    // expires (a retry) but the exponential backoff window (150 + 300 +
    // 600 ms) comfortably outlives the straggler, so no death and no
    // redispatch — and the late reply is consumed, not double-counted.
    let delayed = serve_prefill(
        ServeStrategy::NoPrediction,
        4,
        Some("delay:0@2x400"),
        Some(0.15),
        None,
        mk_rounds(63, 3, 3),
    );
    assert_bitwise_eq(&healthy.0, &delayed.0, "straggler run");
    let s = summary(&delayed.1);
    assert_eq!(s.worker_deaths, 0, "a straggler is not a death: {s:?}");
    assert!(s.retries >= 1, "the expired deadline must count as a retry: {s:?}");
    assert_eq!(s.degraded_samples, 0, "no window served short-handed: {s:?}");
}

#[test]
fn decode_requeues_active_sequences_when_all_workers_die() {
    let mut coord =
        Coordinator::with_source(&small_source(), 1, ServeStrategy::NoPrediction).unwrap();
    coord.set_fault_plan(&FaultPlan::parse("kill@3").unwrap());
    coord.set_worker_timeout(Some(0.2));
    let requests = decode_requests(91, coord.vocab(), 3, 4, 4);
    let report: DecodeReport = coord
        .serve_decode(requests, &greedy_decode_opts(3, 16, 91))
        .unwrap();
    let s = report.fault_summary();
    assert_eq!(s.worker_deaths, 1, "the only worker died: {s:?}");
    assert_eq!(
        s.lost_seqs, 0,
        "every admitted sequence must be finished, requeued or explicitly \
         evicted — never silently lost: {s:?}"
    );
    assert!(
        s.requeued_seqs >= 1,
        "in-flight sequences requeue when nothing can serve them: {s:?}"
    );
    let last = report.steps.last().expect("the failing step is recorded");
    assert!(last.degraded, "the terminal step reports degraded");
    assert_eq!(last.worker_deaths, 1);
}

#[test]
fn worker_death_under_memory_cap_replans_within_cap() {
    let cap_replicas = 3u64;
    let healthy = serve_prefill(
        ServeStrategy::NoPrediction,
        4,
        None,
        None,
        None,
        mk_rounds(77, 4, 3),
    );
    let (outputs, metrics) = serve_prefill(
        ServeStrategy::NoPrediction,
        4,
        Some("kill:1@2"),
        Some(0.25),
        Some(cap_replicas),
        mk_rounds(77, 4, 3),
    );
    assert_bitwise_eq(&healthy.0, &outputs, "capped run with a death");
    let s = summary(&metrics);
    assert_eq!(s.worker_deaths, 1, "{s:?}");
    // Orphaned experts re-home onto survivors, but the per-worker LRU cap
    // still bounds what any survivor holds resident.
    let mut coord =
        Coordinator::with_source(&small_source(), 4, ServeStrategy::NoPrediction).unwrap();
    let cap_bytes = cap_replicas * coord.residency().replica_bytes();
    coord.set_memory_cap(Some(cap_bytes));
    drop(coord);
    for (i, m) in metrics.iter().enumerate() {
        assert!(
            m.resident_high_water_bytes <= cap_bytes,
            "round {i}: high water {} exceeds cap {cap_bytes} after failover",
            m.resident_high_water_bytes
        );
    }
}
