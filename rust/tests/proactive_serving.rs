//! Proactive (forecast-driven) serving, end to end (ADR 006).
//!
//! The contracts:
//!
//! 1. **Bitwise neutrality** — a forecast horizon changes *which replicas
//!    the plan carries and when they move*, never the numerics: serving
//!    at any horizon is bitwise identical to reactive serving, and
//!    horizon 0 doesn't even take a different code path.
//! 2. **Prewarm before the spike** — on a skew ramp the proactive plan
//!    replicates the heating expert at least one replan interval before
//!    the reactive plan does (the whole point of forecasting).
//! 3. **Realized-error feedback** — forecasts are scored against reality,
//!    the error lands in the serve report (`forecast_l1`), gates in CI
//!    via `bench-validate --forecast-report`, and trips the controller's
//!    reactive fallback on an adversarial trace.

mod common;
use common::{
    assert_bitwise_eq, decode_requests, greedy_decode_opts, mk_rounds,
    small_source as source,
};
use moe_gps::coordinator::placement_mgr::PlacementManager;
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::{
    ControllerConfig, Coordinator, ServeStrategy, StrategyController,
};
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::select::{Regime, ServePhase};
use moe_gps::gps::WindowSample;
use moe_gps::model::ModelConfig;
use moe_gps::runtime::HostTensor;
use moe_gps::sim::SystemSpec;

fn serve_prefill_at_horizon(
    horizon: usize,
    rounds: Vec<Vec<Request>>,
) -> (Vec<Vec<HostTensor>>, Option<f64>) {
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
    coord.lookahead = 1;
    coord.placement.horizon = horizon;
    let mut outputs = Vec::new();
    let mut metrics = Vec::new();
    for round in &rounds {
        let (m, out) = coord.serve_round(round).unwrap();
        metrics.push(m);
        outputs.push(out);
    }
    let report = moe_gps::coordinator::ServeReport {
        strategy: ServeStrategy::DistributionOnly.name().to_string(),
        rounds: metrics,
        ..Default::default()
    };
    (outputs, report.mean_forecast_l1())
}

#[test]
fn forecast_serving_is_bitwise_identical_to_reactive_at_every_horizon() {
    let rounds = mk_rounds(131, 5, 3);
    let (reactive, reactive_l1) = serve_prefill_at_horizon(0, rounds.clone());
    assert!(
        reactive_l1.is_none(),
        "horizon 0 must mature no forecasts: {reactive_l1:?}"
    );
    for horizon in [1usize, 2, 4] {
        let (proactive, _) = serve_prefill_at_horizon(horizon, rounds.clone());
        assert_bitwise_eq(
            &reactive,
            &proactive,
            &format!("horizon {horizon} vs reactive"),
        );
    }
    // Forecasts planned for round t are scored when round t+h's routing
    // arrives, so a long enough run realizes an error measurement.
    let (_, proactive_l1) = serve_prefill_at_horizon(2, rounds);
    let l1 = proactive_l1.expect("horizon-2 forecasts must mature and score");
    assert!((0.0..=2.0).contains(&l1), "L1 out of range: {l1}");
}

/// The paper-facing acceptance scenario: a skew ramp (one expert heating
/// linearly) must see the proactive plan carry the hot expert's replica
/// at least one replan interval before the reactive plan does.
#[test]
fn skew_ramp_prewarms_the_hot_expert_before_the_reactive_plan() {
    let horizon = 4usize;
    let ramp = |t: usize| -> [usize; 8] {
        let mut counts = [40usize; 8];
        counts[0] = 40 + 14 * t;
        counts
    };
    let first_replication = |horizon: usize| -> Option<usize> {
        let mut mgr = PlacementManager::new(8, 4, 2, 8, 4);
        mgr.horizon = horizon;
        for t in 0..24usize {
            mgr.observe(0, &ramp(t));
            let plan = mgr.plan_distribution_only(0, 512);
            if plan.placement.copies(0) > 1 {
                return Some(t);
            }
        }
        None
    };
    let proactive = first_replication(horizon).expect("proactive plan must replicate");
    let reactive = first_replication(0).expect("reactive plan must replicate eventually");
    assert!(
        proactive + 1 <= reactive,
        "proactive replication at step {proactive} must land at least one \
         replan interval before reactive at step {reactive}"
    );
}

#[test]
fn realized_forecast_error_lands_in_the_decode_report_and_gates() {
    let serve = |horizon: usize| {
        let mut coord =
            Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
        coord.placement.replan_interval = 2;
        coord.placement.horizon = horizon;
        let requests = decode_requests(23, 512, 4, 6, 5);
        coord
            .serve_decode(requests, &greedy_decode_opts(3, 64, 5))
            .unwrap()
    };
    let proactive = serve(2);
    let l1 = proactive
        .mean_forecast_l1()
        .expect("horizon-2 decode forecasts must mature");
    assert!(l1 >= 0.0 && l1.is_finite());
    // The report JSON carries it at the top level, where the CI gate
    // (`bench-validate --forecast-report`) reads it.
    let json = proactive.to_json();
    let in_json = json.get("forecast_l1").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(in_json.to_bits(), l1.to_bits());
    let path = std::env::temp_dir().join(format!(
        "moe_gps_proactive_report_{}.json",
        std::process::id()
    ));
    std::fs::write(&path, json.to_string_pretty()).unwrap();
    let gated = moe_gps::bench::emit::validate_forecast_error(&path, 2.0).unwrap();
    assert!((gated - l1).abs() < 1e-12);
    assert!(
        moe_gps::bench::emit::validate_forecast_error(&path, l1 / 2.0 - 1e-9).is_err(),
        "a tighter bound than the measured error must fail the gate"
    );

    // Reactive run: no forecast matures, the field is null, the gate
    // refuses to pass vacuously.
    let reactive = serve(0);
    assert!(reactive.mean_forecast_l1().is_none());
    std::fs::write(&path, reactive.to_json().to_string_pretty()).unwrap();
    assert!(moe_gps::bench::emit::validate_forecast_error(&path, 2.0).is_err());
    let _ = std::fs::remove_file(&path);

    // And the decode trajectory itself never moved: forecasting is plans
    // and scheduling, not numerics.
    assert_eq!(
        common::decode_fingerprint(&serve(2)),
        common::decode_fingerprint(&reactive),
        "forecast horizon must not move the greedy decode trajectory"
    );
}

#[test]
fn adversarial_trace_trips_the_controller_fallback_into_the_coordinator() {
    let cals = calibrate_all(
        &ModelConfig::mixtral_8x7b(),
        &SystemSpec::four_a100_nvlink(),
        true,
        7,
    );
    let mut ctrl = StrategyController::with_cals(
        ControllerConfig {
            min_window: 1,
            hysteresis: 1,
            margin_frac: 0.0,
            phase: ServePhase::Prefill,
            horizon: 4,
            forecast_error_max: 0.5,
            ..Default::default()
        },
        cals,
    );
    // An alternating hot-expert trace realizes a forecast L1 far above
    // the threshold (the forecaster extrapolates the flip it just saw,
    // reality flips back).
    for _ in 0..4 {
        ctrl.observe_sample(WindowSample {
            tokens: 128.0,
            total_s: 0.25,
            routing_skew: 2.0,
            pred_share_l1: 0.05,
            pred_share_layers: 2.0,
            forecast_l1: 1.3,
            forecast_layers: 2.0,
            ..Default::default()
        });
    }
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
    coord.placement.horizon = 4;
    let regime = Regime {
        horizon: 4,
        ..coord.current_regime()
    };
    let d = ctrl
        .decide(1, coord.strategy, coord.speculative, coord.lookahead, regime)
        .expect("the breach must produce a decision");
    assert_eq!(d.horizon, 0, "fallback must drop to reactive replanning");
    coord.apply_decision(&d);
    assert_eq!(
        coord.placement.horizon, 0,
        "the coordinator must serve reactively after the fallback"
    );
    let rec = ctrl.decisions().last().unwrap();
    assert_eq!(rec.horizon, 0);
    assert!(
        rec.reason.contains("falling back to reactive"),
        "the fallback must be logged in the decision trace: {}",
        rec.reason
    );
}
