//! Golden-output parity for the unified layer pipeline (ADR 002).
//!
//! The refactor's contract: extracting `serve_round`/`decode_step` into
//! the shared stage engine changes *nothing* about the numerics. The
//! pre-refactor path computed, per layer, attention → router → top-k →
//! per-slot expert FFN → `h += gate · out`; the oracle below replays that
//! straight-line computation on the leader engine alone (no workers, no
//! dispatch, no buckets beyond a single tile) and the pipeline must match
//! it **bitwise** — possible because the combine stage accumulates in
//! global slot order and every expert-FFN output row depends only on its
//! own activation row.
//!
//! On top of the oracle, parity must hold across every axis the pipeline
//! refactor introduced: prediction strategy (duplication is a performance
//! mechanism, never a numerics change), `lookahead` on/off (prewarm moves
//! bytes, not values), and repeated runs (determinism). Token counts in
//! the metrics must agree everywhere too.

use std::collections::BTreeMap;

mod common;
use common::{
    assert_bitwise_eq, decode_fingerprint, decode_requests, greedy_decode_opts, mk_rounds,
    small_source as source,
};
use moe_gps::coordinator::request::Request;
use moe_gps::coordinator::router::route_sequence;
use moe_gps::coordinator::{Coordinator, DecodeReport, ServeStrategy};
use moe_gps::runtime::tensor::IntTensor;
use moe_gps::runtime::{Engine, HostTensor, In, SyntheticSpec};

/// Serve the given rounds, returning the last round's metrics token
/// counts and every round's outputs.
fn serve_prefill(
    strategy: ServeStrategy,
    lookahead: usize,
    rounds: Vec<Vec<Request>>,
) -> (Vec<(usize, usize)>, Vec<Vec<HostTensor>>) {
    serve_prefill_spec(strategy, lookahead, false, rounds)
}

/// [`serve_prefill`] with the ADR-003 speculative TEP scatter toggled.
fn serve_prefill_spec(
    strategy: ServeStrategy,
    lookahead: usize,
    speculative: bool,
    rounds: Vec<Vec<Request>>,
) -> (Vec<(usize, usize)>, Vec<Vec<HostTensor>>) {
    let mut coord = Coordinator::with_source(&source(), 4, strategy).unwrap();
    coord.lookahead = lookahead;
    coord.speculative = speculative;
    let mut counts = Vec::new();
    let mut outputs = Vec::new();
    for round in rounds {
        let (m, out) = coord.serve_round(&round).unwrap();
        counts.push((m.n_tokens, m.n_slots));
        outputs.push(out);
    }
    (counts, outputs)
}

/// Straight-line single-engine replay of the pre-refactor forward: embed
/// the padded prompt, then per layer attention → router → top-k → per-slot
/// expert FFN (single padded tile each) → combine in slot order.
fn oracle_outputs(rounds: &[Vec<Request>]) -> Vec<Vec<HostTensor>> {
    let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
    let cfg = engine.manifest().config.clone();
    let d = cfg.req_usize("d_model").unwrap();
    let e = cfg.req_usize("n_experts").unwrap();
    let n_layers = cfg.req_usize("n_layers").unwrap();
    let top_k = cfg.req_usize("top_k").unwrap();
    let s_max = cfg.req_usize("seq_len").unwrap();
    let tile = engine.manifest().ffn_buckets()[0];

    let mut all = Vec::new();
    for round in rounds {
        let mut outputs = Vec::new();
        for req in round {
            let n = req.tokens.len().min(s_max);
            let mut ids: Vec<i32> = req.tokens[..n].iter().map(|&t| t as i32).collect();
            ids.resize(s_max, 0);
            let ids = IntTensor::new(ids, vec![1, s_max]);
            let mut h = engine
                .call("embed", &[In::I(&ids), In::W("embed")])
                .unwrap()
                .remove(0);
            for layer in 0..n_layers {
                let names = [
                    format!("layers.{layer}.attn.ln"),
                    format!("layers.{layer}.attn.wq"),
                    format!("layers.{layer}.attn.wk"),
                    format!("layers.{layer}.attn.wv"),
                    format!("layers.{layer}.attn.wo"),
                ];
                h = engine
                    .call(
                        "attention",
                        &[
                            In::T(&h),
                            In::W(&names[0]),
                            In::W(&names[1]),
                            In::W(&names[2]),
                            In::W(&names[3]),
                            In::W(&names[4]),
                        ],
                    )
                    .unwrap()
                    .remove(0);
                let ln = format!("layers.{layer}.moe.ln");
                let wr = format!("layers.{layer}.moe.router");
                let mut out = engine
                    .call("router", &[In::T(&h), In::W(&ln), In::W(&wr)])
                    .unwrap();
                let logits = out.remove(1);
                let xn = out.remove(0);
                let slots = route_sequence(0, &logits.data, e, n, top_k);
                for slot in &slots {
                    let row = HostTensor::new(xn.row(slot.token_idx).to_vec(), vec![1, d])
                        .pad_rows_to(tile);
                    let ew = [
                        format!("layers.{layer}.experts.{}.w_gate", slot.expert),
                        format!("layers.{layer}.experts.{}.w_up", slot.expert),
                        format!("layers.{layer}.experts.{}.w_down", slot.expert),
                    ];
                    let ffn = engine
                        .call(
                            &format!("expert_ffn_b{tile}"),
                            &[In::T(&row), In::W(&ew[0]), In::W(&ew[1]), In::W(&ew[2])],
                        )
                        .unwrap()
                        .remove(0);
                    let dst =
                        &mut h.data[slot.token_idx * d..(slot.token_idx + 1) * d];
                    for (a, &b) in dst.iter_mut().zip(ffn.row(0)) {
                        *a += slot.gate * b;
                    }
                }
            }
            outputs.push(h.gather_rows(&(0..n).collect::<Vec<_>>()));
        }
        all.push(outputs);
    }
    all
}

#[test]
fn pipeline_matches_serial_oracle_bitwise() {
    let rounds = mk_rounds(41, 2, 3);
    let oracle = oracle_outputs(&rounds);
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        for lookahead in [0usize, 1, 2] {
            let (_, got) = serve_prefill(strategy, lookahead, rounds.clone());
            assert_bitwise_eq(
                &oracle,
                &got,
                &format!("oracle vs {strategy:?} lookahead={lookahead}"),
            );
        }
    }
}

#[test]
fn prefill_strategies_and_lookahead_depths_agree_bitwise_with_equal_token_counts() {
    let rounds = mk_rounds(7, 3, 4);
    let (base_counts, base_out) =
        serve_prefill(ServeStrategy::NoPrediction, 0, rounds.clone());
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        for lookahead in [0usize, 1, 2] {
            let (counts, out) = serve_prefill(strategy, lookahead, rounds.clone());
            assert_eq!(
                counts, base_counts,
                "token/slot counts diverged: {strategy:?} lookahead={lookahead}"
            );
            assert_bitwise_eq(
                &base_out,
                &out,
                &format!("{strategy:?} lookahead={lookahead}"),
            );
        }
    }
}

fn serve_decode(strategy: ServeStrategy, lookahead: usize) -> DecodeReport {
    serve_decode_spec(strategy, lookahead, false)
}

fn serve_decode_spec(
    strategy: ServeStrategy,
    lookahead: usize,
    speculative: bool,
) -> DecodeReport {
    let mut coord = Coordinator::with_source(&source(), 4, strategy).unwrap();
    coord.lookahead = lookahead;
    coord.speculative = speculative;
    coord.placement.replan_interval = 2;
    let requests = decode_requests(23, 512, 4, 6, 5);
    coord
        .serve_decode(requests, &greedy_decode_opts(3, 64, 5))
        .unwrap()
}

/// ADR 003: the speculative fast path + misprediction-repair pass must be
/// a pure scheduling change — bitwise identical to the serial oracle, with
/// every slot accounted either speculative or repaired.
#[test]
fn speculative_scatter_matches_oracle_bitwise_and_accounts_slots() {
    let rounds = mk_rounds(59, 2, 3);
    let oracle = oracle_outputs(&rounds);
    let (_, got) = serve_prefill_spec(ServeStrategy::TokenToExpert, 1, true, rounds.clone());
    assert_bitwise_eq(&oracle, &got, "oracle vs TEP speculative");

    // Slot accounting: with speculation on, every routed slot is either
    // dispatched speculatively or repaired; and across a skew-taught run
    // at least one slot takes each path (predictions are argmax of a real
    // predictor — neither perfect nor useless on top-2 routing).
    let mut coord =
        Coordinator::with_source(&source(), 4, ServeStrategy::TokenToExpert).unwrap();
    coord.lookahead = 1;
    coord.speculative = true;
    let (mut spec, mut repair, mut slots) = (0usize, 0usize, 0usize);
    for round in mk_rounds(59, 3, 3) {
        let (m, _) = coord.serve_round(&round).unwrap();
        assert_eq!(
            m.spec_dispatch_slots + m.spec_repair_slots,
            m.n_slots,
            "speculation must partition the slot set"
        );
        spec += m.spec_dispatch_slots;
        repair += m.spec_repair_slots;
        slots += m.n_slots;
    }
    assert!(slots > 0);
    assert!(spec > 0, "no slot ever confirmed its prediction");
    assert!(repair > 0, "top-2 routing must leave unpredicted slots");

    // Speculation off: the counters stay zero.
    let (m_off, _) = {
        let mut c =
            Coordinator::with_source(&source(), 4, ServeStrategy::TokenToExpert).unwrap();
        c.lookahead = 1;
        let round = mk_rounds(59, 1, 3).pop().unwrap();
        c.serve_round(&round).unwrap()
    };
    assert_eq!(m_off.spec_dispatch_slots, 0);
    assert_eq!(m_off.spec_repair_slots, 0);
}

#[test]
fn decode_strategies_and_lookahead_agree_on_the_whole_trajectory() {
    let base = decode_fingerprint(&serve_decode(ServeStrategy::NoPrediction, 0));
    assert!(!base.is_empty());
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        for lookahead in [0usize, 1, 2] {
            let got = decode_fingerprint(&serve_decode(strategy, lookahead));
            assert_eq!(
                got, base,
                "decode trajectory diverged: {strategy:?} lookahead={lookahead}"
            );
        }
    }
    // ADR 003: speculative scatter is a scheduling change only — the whole
    // greedy decode trajectory (hence every sampled token) is unchanged.
    let spec = decode_fingerprint(&serve_decode_spec(ServeStrategy::TokenToExpert, 1, true));
    assert_eq!(spec, base, "speculative decode trajectory diverged");
}

#[test]
fn lookahead_accounts_transfers_and_never_invents_bytes() {
    // With lookahead on, the cold start must report hidden transfer bytes
    // (the acceptance check behind `serve --lookahead 1`), and the total
    // must stay consistent: hidden + exposed = total.
    let mut totals: BTreeMap<usize, u64> = BTreeMap::new();
    for lookahead in [0usize, 1, 2] {
        let mut coord =
            Coordinator::with_source(&source(), 4, ServeStrategy::DistributionOnly).unwrap();
        coord.lookahead = lookahead;
        let rounds = mk_rounds(77, 3, 4);
        let mut hidden = 0u64;
        let mut total = 0u64;
        for round in rounds {
            let (m, _) = coord.serve_round(&round).unwrap();
            assert_eq!(
                m.hidden_upload_bytes + m.exposed_upload_bytes,
                m.upload_bytes,
                "hidden + exposed must equal total"
            );
            hidden += m.hidden_upload_bytes;
            total += m.upload_bytes;
        }
        if lookahead > 0 {
            assert!(hidden > 0, "lookahead must hide > 0 transfer bytes");
        } else {
            assert_eq!(hidden, 0, "without lookahead nothing is prewarmed");
        }
        totals.insert(lookahead, total);
    }
    // The same weights move either way — lookahead changes *when*, not
    // *whether*. (Lookahead may prewarm replicas a later plan never uses,
    // so its total is allowed to be >= the lazy path's.)
    assert!(totals[&1] >= totals[&0]);
    assert!(totals[&2] >= totals[&0]);
}
