//! Helpers shared by the serving integration-test binaries
//! (`pipeline_parity.rs`, `residency.rs`): deterministic request rounds
//! and the load-bearing bitwise output comparison every parity claim in
//! the suite rests on.

use moe_gps::coordinator::request::{Request, RequestGen};
use moe_gps::runtime::HostTensor;

/// Deterministic prefill rounds: `n_rounds` batches of `n_seqs`
/// variable-length requests from a seeded generator.
pub fn mk_rounds(seed: u64, n_rounds: usize, n_seqs: usize) -> Vec<Vec<Request>> {
    let mut gen = RequestGen::new(seed, 512);
    (0..n_rounds)
        .map(|_| (0..n_seqs).map(|_| gen.request_varlen(8, 24)).collect())
        .collect()
}

/// Assert two runs' per-round outputs are bitwise identical (shape and
/// every f32 bit pattern), with a path to the first divergence.
pub fn assert_bitwise_eq(a: &[Vec<HostTensor>], b: &[Vec<HostTensor>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (round, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what}: round {round} seq count");
        for (seq, (ta, tb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(ta.shape, tb.shape, "{what}: round {round} seq {seq} shape");
            for (i, (&x, &y)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: round {round} seq {seq} elem {i}: {x} vs {y}"
                );
            }
        }
    }
}
