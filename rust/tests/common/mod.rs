//! Helpers shared by the serving integration-test binaries
//! (`pipeline_parity.rs`, `residency.rs`, `adaptive_gps.rs`,
//! `proactive_serving.rs`): deterministic request rounds, the synthetic
//! engine sources, greedy decode fixtures, and the load-bearing bitwise
//! output comparison every parity claim in the suite rests on.

// Each test binary compiles this module independently and uses its own
// subset of the helpers.
#![allow(dead_code)]

use moe_gps::coordinator::request::{Request, RequestGen};
use moe_gps::coordinator::{DecodeOptions, DecodeReport};
use moe_gps::runtime::{EngineSource, HostTensor, SyntheticSpec};

/// The 2-layer synthetic test model every serving parity suite runs on.
pub fn small_source() -> EngineSource {
    EngineSource::Synthetic(SyntheticSpec::small_test())
}

/// The 4-layer synthetic model (deeper pin windows for residency tests).
pub fn tiny_source() -> EngineSource {
    EngineSource::Synthetic(SyntheticSpec::tiny())
}

/// Deterministic prefill rounds: `n_rounds` batches of `n_seqs`
/// variable-length requests from a seeded generator.
pub fn mk_rounds(seed: u64, n_rounds: usize, n_seqs: usize) -> Vec<Vec<Request>> {
    let mut gen = RequestGen::new(seed, 512);
    (0..n_rounds)
        .map(|_| (0..n_seqs).map(|_| gen.request_varlen(8, 24)).collect())
        .collect()
}

/// Deterministic decode requests from a seeded generator.
pub fn decode_requests(
    seed: u64,
    vocab: usize,
    n: usize,
    prompt: usize,
    max_new: usize,
) -> Vec<Request> {
    let mut gen = RequestGen::new(seed, vocab);
    (0..n).map(|_| gen.decode_request(prompt, max_new)).collect()
}

/// Greedy (temperature 0, fully deterministic) decode options — the
/// setting every trajectory-parity claim relies on: sampled tokens feed
/// back into later steps, so any numeric drift diverges the whole run.
pub fn greedy_decode_opts(max_active: usize, max_steps: usize, seed: u64) -> DecodeOptions {
    DecodeOptions {
        max_active,
        max_steps,
        temperature: 0.0,
        seed,
        arrival_interval: 0,
    }
}

/// Per-step routing fingerprint of a decode run: identical hidden states
/// imply identical routing imply identical slot counts — and greedy
/// sampling feeds the same tokens into every subsequent step, so the
/// whole trajectory pins the numerics across serving regimes.
pub fn decode_fingerprint(report: &DecodeReport) -> Vec<(usize, usize, usize, usize)> {
    report
        .steps
        .iter()
        .map(|s| (s.step, s.n_prefill_tokens, s.n_decode_tokens, s.n_slots))
        .collect()
}

/// Assert two runs' per-round outputs are bitwise identical (shape and
/// every f32 bit pattern), with a path to the first divergence.
pub fn assert_bitwise_eq(a: &[Vec<HostTensor>], b: &[Vec<HostTensor>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (round, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what}: round {round} seq count");
        for (seq, (ta, tb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(ta.shape, tb.shape, "{what}: round {round} seq {seq} shape");
            for (i, (&x, &y)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: round {round} seq {seq} elem {i}: {x} vs {y}"
                );
            }
        }
    }
}
