//! Integration: the full MoE-GPS pipeline (trace → predictors → calibration
//! → sweep → selection → guidelines) and its paper-shape assertions.

use moe_gps::gps::calibrate::{calibrate, calibrate_all, CalibrationOptions};
use moe_gps::gps::select::{recommend, strategy_savings, Recommendation};
use moe_gps::gps::sweep::{figure6_skews, skew_sweep};
use moe_gps::gps::{guidelines, report};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;
use moe_gps::trace::datasets;

fn fast() -> CalibrationOptions {
    CalibrationOptions {
        fast: true,
        ..Default::default()
    }
}

#[test]
fn full_pipeline_produces_consistent_reports() {
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let cals = calibrate_all(&model, &system, true, 7);
    assert_eq!(cals.len(), 3);

    // Table 1 shape: SST2-like error rate far above MMLU/Alpaca-like.
    let sst2 = cals.iter().find(|c| c.workload == "sst2-like").unwrap();
    let mmlu = cals.iter().find(|c| c.workload == "mmlu-like").unwrap();
    assert!(sst2.skewness > mmlu.skewness);
    assert!(sst2.dop_error > mmlu.dop_error);

    // Sweeps cover every strategy at every skew and keep normalized
    // performance consistent with totals.
    let points = skew_sweep(&model, &system, &cals, &figure6_skews(), 1, 512);
    for p in &points {
        assert!(p.total_s > 0.0);
        let base = points
            .iter()
            .find(|q| q.skewness == p.skewness && q.strategy_name == "baseline")
            .unwrap();
        assert!((p.normalized_perf - base.total_s / p.total_s).abs() < 1e-9);
    }

    // Reports render.
    assert!(report::table1(&cals).contains("sst2-like"));
    assert!(report::figure6(&points, "t").contains("token-to-expert"));
}

#[test]
fn headline_dop_beats_best_tep_on_nvlink_skew14() {
    // The paper's abstract claim, via the full pipeline (fast calibration).
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let cals = calibrate_all(&model, &system, true, 7);
    let cmp = strategy_savings(&model, &system, &cals, 1.4, 1, 512);
    assert_eq!(recommend(&cmp), Recommendation::DistributionOnly);
    let dop_total = cmp.baseline_s - cmp.dop_saving_s;
    let tep_total = cmp.baseline_s - cmp.tep_best_saving_s;
    let advantage = tep_total / dop_total - 1.0;
    assert!(
        advantage > 0.10,
        "DOP advantage should be large on NVLink at skew 1.4, got {advantage}"
    );
}

#[test]
fn guideline_map_matches_paper_figure1_shape() {
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let cals = calibrate_all(&model, &system, true, 7);
    let skews = [1.4, 4.0];
    let bws = [600.0, 32.0];
    let cells = guidelines::decision_map(&model, &cals, &skews, &bws, 1, 512);
    let rec_at = |bw: f64, sk: f64| {
        cells
            .iter()
            .find(|c| c.bandwidth_gbs == bw && c.skewness == sk)
            .unwrap()
            .recommendation
    };
    // Fast interconnect + low skew → Distribution-Only (paper Figure 1).
    assert_eq!(rec_at(600.0, 1.4), Recommendation::DistributionOnly);
    // Slow interconnect + high skew → Token-to-Expert.
    assert_eq!(rec_at(32.0, 4.0), Recommendation::TokenToExpert);
}

#[test]
fn tep_accuracy_is_cheaper_at_higher_skew() {
    // Paper §4: "for scenarios with higher skewness, it costs less for the
    // predictor to acquire higher accuracy" — the probability model alone
    // gets more accurate as skew rises, shifting the whole accuracy range.
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let lo = calibrate(datasets::mmlu_like(7), &model, &system, &fast());
    let hi = calibrate(datasets::sst2_like(9), &model, &system, &fast());
    let min_acc = |c: &moe_gps::gps::WorkloadCalibration| {
        c.points
            .iter()
            .map(|p| p.accuracy)
            .fold(f64::INFINITY, f64::min)
    };
    assert!(
        min_acc(&hi) > min_acc(&lo),
        "accuracy floor should rise with skew: {} vs {}",
        min_acc(&hi),
        min_acc(&lo)
    );
}

#[test]
fn other_architectures_preserve_the_trends() {
    // Paper §5 / Appendix C: LLaMA-MoE and Switch keep the same qualitative
    // behaviour — DOP competitive at NVLink, TEP gaining on PCIe.
    for model in [ModelConfig::llama_moe(), ModelConfig::switch_transformer()] {
        let nv = SystemSpec::four_a100_nvlink();
        let pcie = SystemSpec::four_a100_pcie();
        let cals_nv = calibrate_all(&model, &nv, true, 21);
        let cals_pcie = calibrate_all(&model, &pcie, true, 21);
        let on_nv = strategy_savings(&model, &nv, &cals_nv, 2.0, 1, 512);
        let on_pcie = strategy_savings(&model, &pcie, &cals_pcie, 2.0, 1, 512);
        let rel_nv = on_nv.difference_s / on_nv.baseline_s;
        let rel_pcie = on_pcie.difference_s / on_pcie.baseline_s;
        assert!(
            rel_pcie < rel_nv,
            "{}: TEP must gain ground on PCIe ({rel_pcie} !< {rel_nv})",
            model.name
        );
        // Prediction (some strategy) must help at skew 2 in all cases.
        assert!(on_nv.dop_saving_s > 0.0 || on_nv.tep_best_saving_s > 0.0);
    }
}
