//! Property tests for the FFN-phase group machinery (§Perf iterations 1
//! and 3): dispatch grouping → runt merging → greedy LPT placement over
//! replica hosts, as extracted into `coordinator::pipeline`.
//!
//! Invariants pinned here:
//! * every routed expert call (slot) is assigned to exactly one
//!   (worker, expert) group, before and after merging + LPT;
//! * groups only land on workers that host the expert in the plan;
//! * the pass is a pure function: identical inputs (fixed seed) give
//!   identical placements;
//! * under the static plan (no replicas) the pass is the identity — the
//!   baseline is never perturbed;
//! * no host pays more padded expert-FFN calls for one expert than that
//!   expert's single home host pays under the static plan (the
//!   padded-call bound: `padded_rows` is monotone, and a host's share of
//!   an expert never exceeds the whole).

use std::collections::BTreeMap;

use moe_gps::coordinator::pipeline::{
    group_slots_by_assignment, lpt_place, merge_runt_groups, padded_rows, MIN_GROUP,
};
use moe_gps::coordinator::placement_mgr::{LayerPlan, PlacementManager};
use moe_gps::coordinator::router::Slot;
use moe_gps::duplication::dispatch::{dispatch_tokens, dispatch_with_quota};
use moe_gps::testing;
use moe_gps::util::rng::Rng;

const BUCKETS: [usize; 4] = [8, 16, 32, 64];

struct Case {
    n_experts: usize,
    n_workers: usize,
    slots: Vec<Slot>,
    plan: LayerPlan,
    static_plan: LayerPlan,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case {{ experts: {}, workers: {}, slots: {}, replicas: {:?} }}",
            self.n_experts,
            self.n_workers,
            self.slots.len(),
            self.plan.added
        )
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let n_experts = rng.range(4, 10);
    let n_workers = rng.range(2, 5);
    let mgr = PlacementManager::new(n_experts, n_workers, 1, n_experts, n_workers);
    // Skewed-ish counts so the planner sometimes replicates.
    let hot = rng.range(0, n_experts);
    let n_slots = rng.range(1, 400);
    let slots: Vec<Slot> = (0..n_slots)
        .map(|i| {
            let expert = if rng.range(0, 100) < 60 {
                hot
            } else {
                rng.range(0, n_experts)
            };
            Slot {
                seq_idx: 0,
                token_idx: i,
                expert: expert as u8,
                gate: 1.0,
            }
        })
        .collect();
    let mut counts = vec![0usize; n_experts];
    for s in &slots {
        counts[s.expert as usize] += 1;
    }
    Case {
        n_experts,
        n_workers,
        slots,
        plan: mgr.plan_from_counts(&counts),
        static_plan: mgr.static_plan(),
    }
}

/// Run the full pass (dispatch → group → merge → LPT) under a plan.
fn run_pass(case: &Case, plan: &LayerPlan) -> BTreeMap<(usize, usize), Vec<usize>> {
    let experts: Vec<u8> = case.slots.iter().map(|s| s.expert).collect();
    let (assignment, _) = if plan.share.is_empty() {
        dispatch_tokens(&experts, &plan.placement)
    } else {
        dispatch_with_quota(&experts, &plan.placement, &plan.share)
    };
    let mut groups = group_slots_by_assignment(&assignment, &case.slots);
    merge_runt_groups(&mut groups, MIN_GROUP);
    lpt_place(groups, plan, case.n_workers, &BUCKETS)
}

#[test]
fn property_every_call_assigned_exactly_once_and_respects_placement() {
    testing::forall_config(
        testing::Config {
            cases: 128,
            ..Default::default()
        },
        gen_case,
        |case| {
            let placed = run_pass(case, &case.plan);
            let mut seen: Vec<usize> = placed.values().flatten().copied().collect();
            seen.sort_unstable();
            let expected: Vec<usize> = (0..case.slots.len()).collect();
            if seen != expected {
                return Err(format!(
                    "slots not a partition: {} placed of {}",
                    seen.len(),
                    case.slots.len()
                ));
            }
            for (&(worker, expert), slot_indices) in &placed {
                if !case.plan.placement.hosts(expert, worker) {
                    return Err(format!("group ({worker}, {expert}) on a non-host"));
                }
                for &si in slot_indices {
                    if case.slots[si].expert as usize != expert {
                        return Err(format!("slot {si} in the wrong expert group"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_pass_is_deterministic() {
    testing::forall_config(
        testing::Config {
            cases: 64,
            ..Default::default()
        },
        gen_case,
        |case| {
            if run_pass(case, &case.plan) != run_pass(case, &case.plan) {
                return Err("two identical runs disagreed".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_static_plan_is_identity() {
    testing::forall_config(
        testing::Config {
            cases: 64,
            ..Default::default()
        },
        gen_case,
        |case| {
            // Under the static plan each expert has one host, so dispatch
            // grouping IS the final placement: merging finds nothing to
            // fold and LPT has a single candidate per group.
            let experts: Vec<u8> = case.slots.iter().map(|s| s.expert).collect();
            let (assignment, _) = dispatch_tokens(&experts, &case.static_plan.placement);
            let groups = group_slots_by_assignment(&assignment, &case.slots);
            let placed = run_pass(case, &case.static_plan);
            if placed != groups {
                return Err("static-plan pass must be the identity".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_per_expert_padded_calls_bounded_by_static_home() {
    testing::forall_config(
        testing::Config {
            cases: 128,
            ..Default::default()
        },
        gen_case,
        |case| {
            let placed = run_pass(case, &case.plan);
            let mut totals = vec![0usize; case.n_experts];
            for s in &case.slots {
                totals[s.expert as usize] += 1;
            }
            for (&(worker, expert), slot_indices) in &placed {
                let host_padded = padded_rows(&BUCKETS, slot_indices.len());
                let home_padded = padded_rows(&BUCKETS, totals[expert]);
                if host_padded > home_padded {
                    return Err(format!(
                        "host {worker} pays {host_padded} padded rows for expert \
                         {expert}, but its static home pays only {home_padded}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn padded_rows_monotone_over_wide_range() {
    // The bound above rests on split_into_buckets' padded total being
    // monotone in the slot count; pin that here over a wide range.
    let mut prev = 0usize;
    for n in 0..2000 {
        let p = padded_rows(&BUCKETS, n);
        assert!(p >= prev, "padded_rows not monotone at {n}: {prev} -> {p}");
        assert!(p >= n);
        prev = p;
    }
}

#[test]
fn merged_groups_meet_min_group_or_are_sole_hosts() {
    testing::forall_config(
        testing::Config {
            cases: 64,
            ..Default::default()
        },
        gen_case,
        |case| {
            let experts: Vec<u8> = case.slots.iter().map(|s| s.expert).collect();
            let (assignment, _) = if case.plan.share.is_empty() {
                dispatch_tokens(&experts, &case.plan.placement)
            } else {
                dispatch_with_quota(&experts, &case.plan.placement, &case.plan.share)
            };
            let mut groups = group_slots_by_assignment(&assignment, &case.slots);
            merge_runt_groups(&mut groups, MIN_GROUP);
            // After merging, a runt group may only survive as its expert's
            // sole remaining group.
            for (&(_, expert), slot_indices) in &groups {
                if slot_indices.len() < MIN_GROUP {
                    let siblings = groups.keys().filter(|&&(_, e)| e == expert).count();
                    if siblings != 1 {
                        return Err(format!(
                            "runt group of expert {expert} survived with {siblings} \
                             sibling groups"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
