//! Property tests for the parallel tiled reference backend (ADR 003).
//!
//! Contract: the blocked/tiled, pool-parallel kernels are bitwise
//! identical to a naive serial implementation — per row, independent of
//! shape, tiling boundaries, and thread count. This is what lets
//! `tests/pipeline_parity.rs` keep its bitwise oracle across the backend
//! rewrite.

use moe_gps::runtime::reference::matmul;
use moe_gps::runtime::simd;
use moe_gps::runtime::tensor::IntTensor;
use moe_gps::runtime::{Engine, HostTensor, In, SyntheticSpec};
use moe_gps::util::rng::Rng;

/// The seed implementation: plain untiled single-threaded ikj.
fn naive_matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

fn random_buf(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

#[test]
fn tiled_matmul_bitwise_matches_naive_over_shape_grid() {
    let mut rng = Rng::new(0xA11C);
    // Shapes straddle every regime: serial fallback (tiny), single/multi
    // k-tile (k vs the 64-wide tile), and the parallel row-chunk path
    // (large m·k·n), including non-multiples of every block size.
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 64, 512),
        (2, 3, 5),
        (7, 64, 9),
        (16, 65, 33),
        (17, 129, 65),
        (64, 64, 64),
        (100, 57, 31),
        (128, 256, 64),
        (200, 64, 512),
        (257, 130, 67),
    ];
    for &(m, k, n) in &shapes {
        let a = random_buf(&mut rng, m * k);
        let b = random_buf(&mut rng, k * n);
        let got = matmul(&a, m, k, &b, n);
        let want = naive_matmul(&a, m, k, &b, n);
        assert_eq!(got.len(), want.len());
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "({m},{k},{n}) elem {i}: tiled {x} vs naive {y}"
            );
        }
    }
}

#[test]
fn tiled_matmul_handles_non_finite_inputs_without_panicking() {
    // NaN/Inf activations must flow through (garbage in, garbage out) —
    // never panic, and still bitwise-match the naive kernel.
    let m = 40;
    let k = 70;
    let n = 40;
    let mut rng = Rng::new(7);
    let mut a = random_buf(&mut rng, m * k);
    a[3] = f32::NAN;
    a[k + 1] = f32::INFINITY;
    let b = random_buf(&mut rng, k * n);
    let got = matmul(&a, m, k, &b, n);
    let want = naive_matmul(&a, m, k, &b, n);
    for (x, y) in got.iter().zip(&want) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// Repeated executions of the threaded attention ops must be bitwise
/// stable: thread scheduling may vary run to run, results may not.
#[test]
fn attention_ops_are_bitwise_deterministic_across_runs() {
    let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
    let s = 24usize;
    let d = 64usize;
    let x = HostTensor::new(
        (0..s * d).map(|i| ((i % 23) as f32 - 11.0) * 0.07).collect(),
        vec![s, d],
    );
    let args = |x: &HostTensor| {
        vec![
            In::T(x),
            In::W("layers.0.attn.ln"),
            In::W("layers.0.attn.wq"),
            In::W("layers.0.attn.wk"),
            In::W("layers.0.attn.wv"),
            In::W("layers.0.attn.wo"),
        ]
    };
    let runs: Vec<HostTensor> = (0..3)
        .map(|_| {
            let a = args(&x);
            engine.call("attention", &a).unwrap().remove(0)
        })
        .collect();
    for run in &runs[1..] {
        assert_eq!(run.shape, runs[0].shape);
        for (a, b) in runs[0].data.iter().zip(&run.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "attention must be run-stable");
        }
    }

    // Same for the decode step over a KV cache (head-parallel path).
    let mut prefill_args = vec![In::T(&x)];
    prefill_args.extend([
        In::W("layers.0.attn.ln"),
        In::W("layers.0.attn.wq"),
        In::W("layers.0.attn.wk"),
        In::W("layers.0.attn.wv"),
        In::W("layers.0.attn.wo"),
    ]);
    let mut prefill = engine.call("attention_prefill", &prefill_args).unwrap();
    let v_cache = prefill.remove(2);
    let k_cache = prefill.remove(1);
    let x_last = x.gather_rows(&[s - 1]);
    let step_runs: Vec<HostTensor> = (0..3)
        .map(|_| {
            let step_args = vec![
                In::T(&x_last),
                In::T(&k_cache),
                In::T(&v_cache),
                In::W("layers.0.attn.ln"),
                In::W("layers.0.attn.wq"),
                In::W("layers.0.attn.wk"),
                In::W("layers.0.attn.wv"),
                In::W("layers.0.attn.wo"),
            ];
            engine.call("attention_step", &step_args).unwrap().remove(0)
        })
        .collect();
    for run in &step_runs[1..] {
        for (a, b) in step_runs[0].data.iter().zip(&run.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "attention_step must be run-stable");
        }
    }
}

/// The lm_head vocab-chunked parallel path must agree with a serial dot
/// product against the embedding table.
#[test]
fn lm_head_matches_serial_dot_products() {
    let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
    let d = 64usize;
    let h = HostTensor::new((0..d).map(|i| (i as f32 - 31.0) * 0.03).collect(), vec![1, d]);
    let logits = engine
        .call("lm_head", &[In::T(&h), In::W("final.ln"), In::W("embed")])
        .unwrap()
        .remove(0);
    assert_eq!(logits.shape, vec![1, 512]);
    // Reproduce serially: rmsnorm(h) · embed[v] for a few vocab ids.
    let ws = engine.weight_store();
    let ln = ws.get("final.ln").unwrap();
    let embed = ws.get("embed").unwrap();
    // The backend's dot products use the canonical 8-lane accumulation
    // order (ADR 007), so the serial oracle must too — simd::dot is that
    // order on every dispatch tier.
    let ms: f32 = simd::dot(&h.data, &h.data) / d as f32;
    let scale = 1.0 / (ms + 1e-5).sqrt();
    let xn: Vec<f32> = h
        .data
        .iter()
        .zip(&ln.data)
        .map(|(&v, &g)| v * scale * g)
        .collect();
    for v in [0usize, 17, 255, 511] {
        let want: f32 = simd::dot(&xn, embed.row(v));
        assert_eq!(
            logits.data[v].to_bits(),
            want.to_bits(),
            "vocab {v}: {} vs {want}",
            logits.data[v]
        );
    }
}

/// ADR 007 determinism contract, integration-level: whatever dispatch
/// tier this machine resolved (scalar, avx2+fma, or neon), the dispatched
/// lane kernels must be bitwise identical to the portable implementation
/// over a shape grid that exercises full 8-lane blocks, sub-8 tails, and
/// odd lengths. Run under `MOE_GPS_SIMD=scalar` this trivially compares
/// scalar to itself — CI runs both legs so the vector tiers are pinned
/// wherever the hardware has them.
#[test]
fn simd_dispatch_matches_portable_bitwise_over_length_grid() {
    let mut rng = Rng::new(0x51D);
    let lengths = [
        0usize, 1, 2, 3, 5, 7, 8, 9, 13, 16, 17, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129,
        1000, 4099,
    ];
    let tier = simd::active_tier().name();
    for &n in &lengths {
        let x = random_buf(&mut rng, n);
        let y = random_buf(&mut rng, n);
        assert_eq!(
            simd::dot(&x, &y).to_bits(),
            simd::dot_portable(&x, &y).to_bits(),
            "dot len {n} tier {tier}"
        );
        assert_eq!(
            simd::max_reduce(&x).to_bits(),
            simd::max_reduce_portable(&x).to_bits(),
            "max_reduce len {n} tier {tier}"
        );
        let mut a = y.clone();
        let mut b = y.clone();
        simd::axpy(0.73, &x, &mut a);
        simd::axpy_portable(0.73, &x, &mut b);
        for (i, (p, q)) in a.iter().zip(&b).enumerate() {
            assert_eq!(p.to_bits(), q.to_bits(), "axpy len {n} elem {i} tier {tier}");
        }
    }
}

/// Embedding + a full engine round-trip sanity check under the threaded
/// backend (shapes and determinism of a composite call chain).
#[test]
fn composite_op_chain_is_stable() {
    let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
    let ids = IntTensor::new(vec![4, 9, 2, 2, 100], vec![1, 5]);
    let run = |engine: &mut Engine| -> HostTensor {
        let x0 = engine
            .call("embed", &[In::I(&ids), In::W("embed")])
            .unwrap()
            .remove(0);
        let h = engine
            .call(
                "attention",
                &[
                    In::T(&x0),
                    In::W("layers.1.attn.ln"),
                    In::W("layers.1.attn.wq"),
                    In::W("layers.1.attn.wk"),
                    In::W("layers.1.attn.wv"),
                    In::W("layers.1.attn.wo"),
                ],
            )
            .unwrap()
            .remove(0);
        engine
            .call(
                "router",
                &[In::T(&h), In::W("layers.1.moe.ln"), In::W("layers.1.moe.router")],
            )
            .unwrap()
            .remove(1)
    };
    let a = run(&mut engine);
    let b = run(&mut engine);
    assert_eq!(a.shape, vec![5, 8]);
    for (x, y) in a.data.iter().zip(&b.data) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
