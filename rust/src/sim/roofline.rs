//! Operator-level roofline cost models.
//!
//! LLMCompass prices each operator by simulating its tile mapping; we use the
//! standard analytical form it reduces to for large language model blocks:
//!
//! `time = max(flops / (peak · utilisation), bytes / mem_bw) + launch`
//!
//! with a GEMM utilisation model that penalises small / misaligned
//! dimensions — this is what makes the paper's small-workload observation
//! (§5 "Kernel underutilization at small scale") appear in our numbers too.

use super::hardware::{DeviceSpec, Dtype};

/// Matrix-unit tile edge (tensor-core MMA / MXU systolic tile).
pub const MXU_TILE: usize = 128;

/// Utilisation of the matrix unit for an `m×k · k×n` GEMM.
///
/// Dimensions that are small relative to the hardware tile leave lanes idle;
/// misaligned dimensions waste the remainder tile. The model multiplies a
/// saturating per-dimension efficiency, calibrated so that:
/// * tiny GEMMs (m = 1) run at a few percent of peak (memory/latency bound
///   in practice),
/// * dimensions ≥ 4·tile with perfect alignment approach `max_util` (0.85,
///   a typical measured ceiling for dense fp16 GEMM on A100-class parts).
pub fn gemm_utilization(m: usize, n: usize, k: usize) -> f64 {
    const MAX_UTIL: f64 = 0.85;
    let dim_eff = |d: usize| -> f64 {
        if d == 0 {
            return 0.0;
        }
        // Saturating occupancy: how full is the systolic dimension.
        let occupancy = (d as f64 / MXU_TILE as f64).min(4.0) / 4.0;
        // Alignment: fraction of the padded dimension that is real work.
        let padded = d.div_ceil(MXU_TILE) * MXU_TILE;
        let alignment = d as f64 / padded as f64;
        // Blend: occupancy dominates for small d, alignment for large d.
        (0.35 + 0.65 * occupancy) * alignment.max(0.25)
    };
    MAX_UTIL * dim_eff(m) * dim_eff(n) * dim_eff(k)
}

/// Cost of a dense GEMM `[m,k] x [k,n] -> [m,n]`.
///
/// `weights_resident`: if true the `k×n` operand streams from HBM
/// (weight matrix); activations are assumed cached between fused ops.
pub fn gemm_time(device: &DeviceSpec, m: usize, n: usize, k: usize, dtype: Dtype) -> f64 {
    if m == 0 || n == 0 || k == 0 {
        return 0.0;
    }
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let util = gemm_utilization(m, n, k);
    let compute_s = flops / (device.peak_matrix_tflops * 1e12 * util);
    // Memory traffic: read A (m·k), read B (k·n), write C (m·n).
    let bytes = dtype.bytes() as f64 * (m * k + k * n + m * n) as f64;
    let memory_s = bytes / (device.mem_bw_gbs * 1e9);
    compute_s.max(memory_s) + device.kernel_launch_s
}

/// Cost of an elementwise op over `elements` values with `flops_per_element`
/// arithmetic (e.g. SiLU ≈ 6, add ≈ 1, mul ≈ 1). Reads one or two operands
/// and writes one.
pub fn elementwise_time(
    device: &DeviceSpec,
    elements: usize,
    flops_per_element: f64,
    operands: usize,
    dtype: Dtype,
) -> f64 {
    if elements == 0 {
        return 0.0;
    }
    let flops = elements as f64 * flops_per_element;
    let compute_s = flops / (device.peak_vector_tflops * 1e12);
    let bytes = dtype.bytes() as f64 * elements as f64 * (operands + 1) as f64;
    let memory_s = bytes / (device.mem_bw_gbs * 1e9);
    compute_s.max(memory_s) + device.kernel_launch_s
}

/// Softmax over `rows` rows of length `cols`: ~5 passes worth of arithmetic
/// (max, sub, exp, sum, div) on the vector unit, memory-bound in practice.
pub fn softmax_time(device: &DeviceSpec, rows: usize, cols: usize, dtype: Dtype) -> f64 {
    elementwise_time(device, rows * cols, 5.0, 2, dtype)
}

/// LayerNorm / RMSNorm over `rows` rows of width `width`.
pub fn norm_time(device: &DeviceSpec, rows: usize, width: usize, dtype: Dtype) -> f64 {
    elementwise_time(device, rows * width, 4.0, 1, dtype)
}

/// Rotary position embedding applied to `tokens` tokens of `dim` channels.
pub fn rope_time(device: &DeviceSpec, tokens: usize, dim: usize, dtype: Dtype) -> f64 {
    elementwise_time(device, tokens * dim, 6.0, 1, dtype)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> DeviceSpec {
        DeviceSpec::a100()
    }

    #[test]
    fn utilization_monotone_in_size() {
        let small = gemm_utilization(8, 8, 8);
        let medium = gemm_utilization(128, 128, 128);
        let large = gemm_utilization(4096, 4096, 4096);
        assert!(small < medium, "{small} !< {medium}");
        assert!(medium < large, "{medium} !< {large}");
        assert!(large <= 0.85 + 1e-12);
    }

    #[test]
    fn utilization_penalises_misalignment() {
        let aligned = gemm_utilization(512, 512, 512);
        let misaligned = gemm_utilization(512, 513, 512);
        assert!(misaligned < aligned);
    }

    #[test]
    fn gemm_time_scales_with_flops() {
        let d = a100();
        let t1 = gemm_time(&d, 512, 4096, 4096, Dtype::Fp16);
        let t2 = gemm_time(&d, 1024, 4096, 4096, Dtype::Fp16);
        // Doubling m roughly doubles time (same utilisation regime).
        let ratio = t2 / t1;
        assert!(ratio > 1.6 && ratio < 2.4, "ratio={ratio}");
    }

    #[test]
    fn gemm_large_is_compute_bound_small_is_memory_bound() {
        let d = a100();
        // Large square GEMM: arithmetic intensity is high → compute bound.
        let m = 4096;
        let flops = 2.0 * (m * m) as f64 * m as f64;
        let ideal_compute = flops / (d.peak_matrix_tflops * 1e12 * 0.85);
        let t = gemm_time(&d, m, m, m, Dtype::Fp16);
        assert!(t >= ideal_compute * 0.99);
        assert!(t < ideal_compute * 1.6);
        // Skinny GEMM (m=1): memory bound — time ≈ weight-read time.
        let t_skinny = gemm_time(&d, 1, 4096, 4096, Dtype::Fp16);
        let weight_bytes = 2.0 * (4096 * 4096) as f64;
        let mem_floor = weight_bytes / (d.mem_bw_gbs * 1e9);
        assert!(t_skinny >= mem_floor);
        assert!(t_skinny < mem_floor * 3.0);
    }

    #[test]
    fn zero_sizes_cost_nothing() {
        let d = a100();
        assert_eq!(gemm_time(&d, 0, 10, 10, Dtype::Fp16), 0.0);
        assert_eq!(elementwise_time(&d, 0, 1.0, 1, Dtype::Fp16), 0.0);
    }

    #[test]
    fn mixtral_ffn_gemm_sanity() {
        // One expert GEMM of Mixtral 8x7B at 512 tokens: [512,4096]x[4096,14336].
        // Ideal fp16 time at peak: 2*512*4096*14336 / 312e12 ≈ 0.19 ms.
        // With utilisation < 1 we expect the same order of magnitude.
        let d = a100();
        let t = gemm_time(&d, 512, 14336, 4096, Dtype::Fp16);
        assert!(t > 0.1e-3 && t < 2e-3, "t={t}");
    }
}
