//! Whole-transformer-layer latency simulation — the unit the paper reports
//! (Figure 6/8/9 show "simulated prefill latency for a single layer").
//!
//! A layer is: [predictor] → Attention (TP, incl. ring all-reduce) → router
//! → all-to-all scatter → expert FFN (EP) → all-to-all gather. The
//! breakdown mirrors the paper's stacked bars: attention / FFN /
//! communication / overhead.

use super::attention::{self, AttentionCost};
use super::hardware::SystemSpec;
use super::moe::{self, MoeCost, MoeParams, Strategy};
use super::roofline;
use super::ErrorModel;
use crate::model::ModelConfig;
use crate::util::json::Value;

/// Per-component latency breakdown for one transformer layer. With the
/// overlap model (ADR 002) `overhead_s`/`movement_s` hold only the
/// *exposed* residues; `hidden_s` reports what the lookahead window
/// absorbed (informational — never part of [`LayerBreakdown::total`]).
#[derive(Clone, Debug)]
pub struct LayerBreakdown {
    pub attention_s: f64,
    pub allreduce_s: f64,
    pub router_s: f64,
    pub ffn_s: f64,
    pub scatter_s: f64,
    pub gather_s: f64,
    pub overhead_s: f64,
    pub movement_s: f64,
    pub hidden_s: f64,
    /// Host-memory time for the measured data-plane copy traffic
    /// (ADR 009 — [`MoeParams::copied_bytes_per_token`]).
    pub host_copy_s: f64,
}

impl LayerBreakdown {
    pub fn total(&self) -> f64 {
        self.attention_s
            + self.allreduce_s
            + self.router_s
            + self.ffn_s
            + self.scatter_s
            + self.gather_s
            + self.overhead_s
            + self.movement_s
            + self.host_copy_s
    }

    /// Total communication (all-reduce + both all-to-alls).
    pub fn comm_s(&self) -> f64 {
        self.allreduce_s + self.scatter_s + self.gather_s
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("attention_s", Value::Num(self.attention_s))
            .set("allreduce_s", Value::Num(self.allreduce_s))
            .set("router_s", Value::Num(self.router_s))
            .set("ffn_s", Value::Num(self.ffn_s))
            .set("scatter_s", Value::Num(self.scatter_s))
            .set("gather_s", Value::Num(self.gather_s))
            .set("overhead_s", Value::Num(self.overhead_s))
            .set("movement_s", Value::Num(self.movement_s))
            .set("hidden_s", Value::Num(self.hidden_s))
            .set("host_copy_s", Value::Num(self.host_copy_s))
            .set("total_s", Value::Num(self.total()));
        v
    }
}

/// A configured single-layer simulation.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub model: ModelConfig,
    pub system: SystemSpec,
    pub batch: usize,
    pub seq: usize,
    pub error_model: ErrorModel,
    pub hide_duplication: bool,
    /// Price the lookahead-overlap serving engine (ADR 002).
    pub lookahead_overlap: bool,
    /// Price the speculative TEP scatter on top of overlap (ADR 003).
    pub speculative_scatter: bool,
    /// Price the constrained-HBM regime (ADR 004): per-device byte budget
    /// for expert weights; working-set overflow pays exposed refetch.
    pub memory_cap_bytes: Option<f64>,
    /// ADR 006: proactive-replanning horizon in replan windows (see
    /// [`MoeParams::forecast_horizon`]). 0 = reactive.
    pub forecast_horizon: usize,
    /// ADR 006: per-window forecast drift; `None` = the default constant
    /// (see [`MoeParams::forecast_drift`]).
    pub forecast_drift: Option<f64>,
    /// ADR 010: micro-batch wavefront depth (1 = serial). Leader routing
    /// for micro-batches 2..K hides under the in-flight FFN window.
    pub microbatch: usize,
    /// ADR 009: measured data-plane copy bytes per token (0 = unmeasured).
    pub copied_bytes_per_token: f64,
}

impl LayerSim {
    /// The paper's main setup: batch 1, sequence 512.
    pub fn new(model: ModelConfig, system: SystemSpec) -> LayerSim {
        LayerSim {
            model,
            system,
            batch: 1,
            seq: 512,
            error_model: ErrorModel::Typical,
            hide_duplication: true,
            lookahead_overlap: false,
            speculative_scatter: false,
            memory_cap_bytes: None,
            forecast_horizon: 0,
            forecast_drift: None,
            microbatch: 1,
            copied_bytes_per_token: 0.0,
        }
    }

    pub fn with_workload(mut self, batch: usize, seq: usize) -> LayerSim {
        self.batch = batch;
        self.seq = seq;
        self
    }

    pub fn with_overlap(mut self, on: bool) -> LayerSim {
        self.lookahead_overlap = on;
        self
    }

    pub fn with_speculative(mut self, on: bool) -> LayerSim {
        self.speculative_scatter = on;
        self
    }

    pub fn with_memory_cap(mut self, cap_bytes: Option<f64>) -> LayerSim {
        self.memory_cap_bytes = cap_bytes;
        self
    }

    /// Price proactive replanning at forecast horizon `h` (ADR 006);
    /// `drift` overrides the default per-window forecast drift (`None` =
    /// [`moe::DEFAULT_FORECAST_DRIFT`], or the measured value when the
    /// online calibrator supplies one).
    pub fn with_horizon(mut self, h: usize, drift: Option<f64>) -> LayerSim {
        self.forecast_horizon = h;
        self.forecast_drift = drift;
        self
    }

    /// Price the micro-batch wavefront at depth `k` (ADR 010; 0/1 =
    /// serial — no routing hides).
    pub fn with_microbatch(mut self, k: usize) -> LayerSim {
        self.microbatch = k.max(1);
        self
    }

    /// Price the measured data-plane copy traffic (ADR 009 follow-up):
    /// `bytes` of host copies per token, charged at HBM bandwidth.
    pub fn with_copied_bytes(mut self, bytes: f64) -> LayerSim {
        self.copied_bytes_per_token = bytes.max(0.0);
        self
    }

    pub fn attention(&self) -> AttentionCost {
        attention::attention_cost(&self.model, &self.system, self.batch, self.seq)
    }

    /// Router cost: one `[tokens, d_model] × [d_model, E]` GEMM + top-k
    /// selection (elementwise-ish).
    pub fn router_time(&self) -> f64 {
        let tokens = self.batch * self.seq;
        let gemm = roofline::gemm_time(
            &self.system.device,
            tokens,
            self.model.n_experts,
            self.model.d_model,
            self.model.dtype,
        );
        let topk = roofline::elementwise_time(
            &self.system.device,
            tokens * self.model.n_experts,
            3.0,
            1,
            self.model.dtype,
        );
        gemm + topk
    }

    fn moe(&self, skewness: f64, strategy: Strategy, attention_compute_s: f64) -> MoeCost {
        let mut p = MoeParams::new(self.batch, self.seq, skewness, strategy);
        p.error_model = self.error_model;
        p.hide_duplication = self.hide_duplication;
        p.attention_compute_s = attention_compute_s;
        p.lookahead_overlap = self.lookahead_overlap;
        p.speculative_scatter = self.speculative_scatter;
        p.memory_cap_bytes = self.memory_cap_bytes;
        p.forecast_horizon = self.forecast_horizon;
        p.forecast_drift = self.forecast_drift;
        p.microbatch = self.microbatch;
        p.router_compute_s = self.router_time();
        p.copied_bytes_per_token = self.copied_bytes_per_token;
        moe::moe_cost(&self.model, &self.system, &p)
    }

    /// Full-layer breakdown for a given workload skewness and strategy.
    pub fn breakdown(&self, skewness: f64, strategy: Strategy) -> LayerBreakdown {
        let attn = self.attention();
        let moe = self.moe(skewness, strategy, attn.compute());
        LayerBreakdown {
            attention_s: attn.compute(),
            allreduce_s: attn.allreduce_s,
            // ADR 010: the wavefront hides part of the leader's routing
            // under in-flight FFN micro-batches; charge only the residue.
            router_s: (self.router_time() - moe.router_hidden_s).max(0.0),
            ffn_s: moe.ffn_s,
            scatter_s: moe.scatter_s,
            gather_s: moe.gather_s,
            overhead_s: moe.overhead_s,
            movement_s: moe.movement_s,
            hidden_s: moe.hidden_s,
            host_copy_s: moe.host_copy_s,
        }
    }

    /// Baseline (no prediction) total latency at a skewness.
    pub fn baseline_total(&self, skewness: f64) -> f64 {
        self.breakdown(skewness, Strategy::NoPrediction).total()
    }

    /// Normalised performance as the paper plots it: baseline_time / time
    /// (higher is better; 1.0 = baseline).
    pub fn normalized_performance(&self, skewness: f64, strategy: Strategy) -> f64 {
        self.baseline_total(skewness) / self.breakdown(skewness, strategy).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SystemSpec;

    fn sim() -> LayerSim {
        LayerSim::new(
            ModelConfig::mixtral_8x7b(),
            SystemSpec::four_a100_nvlink(),
        )
    }

    #[test]
    fn breakdown_components_positive() {
        let b = sim().breakdown(1.4, Strategy::NoPrediction);
        assert!(b.attention_s > 0.0);
        assert!(b.allreduce_s > 0.0);
        assert!(b.router_s > 0.0);
        assert!(b.ffn_s > 0.0);
        assert!(b.scatter_s > 0.0);
        assert!(b.gather_s > 0.0);
        assert_eq!(b.overhead_s, 0.0);
        let total = b.total();
        assert!(
            (total
                - (b.attention_s
                    + b.allreduce_s
                    + b.router_s
                    + b.ffn_s
                    + b.scatter_s
                    + b.gather_s))
                .abs()
                < 1e-15
        );
    }

    #[test]
    fn dop_beats_baseline_at_moderate_skew() {
        let s = sim();
        let perf = s.normalized_performance(
            1.4,
            Strategy::DistributionOnly { error_rate: 0.018 },
        );
        assert!(perf > 1.0, "perf={perf}");
    }

    #[test]
    fn tep_u_shape_in_accuracy() {
        // With an overhead that grows steeply in accuracy, total latency is
        // U-shaped: too-low accuracy wastes comm/compute, too-high accuracy
        // pays overhead (paper Figure 4/6).
        let s = sim();
        let overhead = |acc: f64| 15e-6 * (4.0 * acc).exp();
        let total = |acc: f64| {
            s.breakdown(
                1.4,
                Strategy::TokenToExpert {
                    accuracy: acc,
                    overhead_s: overhead(acc),
                },
            )
            .total()
        };
        let lo = total(0.3);
        let mid = total(0.7);
        let hi = total(0.999);
        assert!(mid < lo, "mid={mid} lo={lo}");
        assert!(mid < hi, "mid={mid} hi={hi}");
    }

    #[test]
    fn overlap_improves_tep_and_reports_hidden_time() {
        let s = sim();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-4,
        };
        let plain = s.breakdown(1.4, strategy);
        let over = sim().with_overlap(true).breakdown(1.4, strategy);
        assert!(over.overhead_s <= plain.overhead_s);
        assert!(over.hidden_s > 0.0, "overlap must hide something");
        assert_eq!(plain.hidden_s, 0.0);
        // hidden_s never counts toward total.
        assert!(
            (over.total()
                - (over.attention_s
                    + over.allreduce_s
                    + over.router_s
                    + over.ffn_s
                    + over.scatter_s
                    + over.gather_s
                    + over.overhead_s
                    + over.movement_s))
                .abs()
                < 1e-15
        );
    }

    #[test]
    fn horizon_builder_prices_prewarm_against_staleness() {
        let strategy = Strategy::DistributionOnly { error_rate: 0.02 };
        let mut exposed = sim();
        exposed.hide_duplication = false;
        let reactive = exposed.clone().breakdown(2.0, strategy);
        let proactive = exposed.with_horizon(4, None).breakdown(2.0, strategy);
        // The forecast plan prewarms the replica off the serving step…
        assert_eq!(proactive.movement_s, 0.0);
        assert!(proactive.hidden_s > 0.0);
        assert!(reactive.movement_s > proactive.movement_s);
        // …but runs on a 4-windows-stale distribution.
        assert!(proactive.ffn_s > reactive.ffn_s);
    }

    #[test]
    fn microbatch_builder_shrinks_exposed_router_time() {
        // ADR 010: hidden routing leaves the router_s charge, never the
        // FFN or comm terms, and the total shrinks accordingly. K=1 is an
        // exact no-op.
        let serial = sim().breakdown(2.0, Strategy::NoPrediction);
        let same = sim().with_microbatch(1).breakdown(2.0, Strategy::NoPrediction);
        assert_eq!(serial.total(), same.total());
        assert_eq!(serial.router_s, same.router_s);
        let wave = sim().with_microbatch(4).breakdown(2.0, Strategy::NoPrediction);
        assert!(wave.router_s < serial.router_s, "routing must partly hide");
        assert!(wave.router_s >= 0.0);
        assert_eq!(wave.ffn_s, serial.ffn_s);
        assert_eq!(wave.scatter_s, serial.scatter_s);
        assert!(wave.total() < serial.total());
        // Conservation: exposed + hidden routing = the serial router time.
        let hidden = wave.hidden_s - serial.hidden_s;
        assert!((wave.router_s + hidden - serial.router_s).abs() < 1e-15);
        // Deeper wavefronts hide monotonically more.
        let deeper = sim().with_microbatch(8).breakdown(2.0, Strategy::NoPrediction);
        assert!(deeper.router_s <= wave.router_s + 1e-18);
    }

    #[test]
    fn copied_bytes_builder_adds_a_host_copy_term() {
        let plain = sim().breakdown(2.0, Strategy::NoPrediction);
        assert_eq!(plain.host_copy_s, 0.0);
        let priced = sim()
            .with_copied_bytes(4096.0 * 4.0)
            .breakdown(2.0, Strategy::NoPrediction);
        assert!(priced.host_copy_s > 0.0);
        assert!((priced.total() - plain.total() - priced.host_copy_s).abs() < 1e-15);
        let v = priced.to_json();
        assert!((v.req_f64("host_copy_s").unwrap() - priced.host_copy_s).abs() < 1e-18);
    }

    #[test]
    fn normalized_perf_of_baseline_is_one() {
        let s = sim();
        let p = s.normalized_performance(2.0, Strategy::NoPrediction);
        assert!((p - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pcie_comm_dominates_breakdown() {
        let s = LayerSim::new(
            ModelConfig::mixtral_8x7b(),
            SystemSpec::four_a100_pcie(),
        );
        let b = s.breakdown(1.4, Strategy::NoPrediction);
        assert!(
            b.comm_s() > b.attention_s + b.ffn_s,
            "comm={} compute={}",
            b.comm_s(),
            b.attention_s + b.ffn_s
        );
    }

    #[test]
    fn json_breakdown_has_total() {
        let b = sim().breakdown(1.4, Strategy::NoPrediction);
        let v = b.to_json();
        assert!((v.req_f64("total_s").unwrap() - b.total()).abs() < 1e-15);
    }
}
