//! Hardware descriptions: device (GPU) specs and interconnect specs.
//!
//! Numbers mirror the configurations used by the paper: NVIDIA A100 (SXM)
//! devices, connected either with NVLink 3.0 (high-end) or PCIe 4.0
//! (low-end), plus the two intermediate bandwidth points of Figure 7.
//! These are simulation *parameters* — see DESIGN.md §Hardware-Adaptation.

use crate::util::json::Value;

/// Numeric datatype width used for weights/activations in the cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    Fp16,
    Bf16,
    Fp32,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::Fp16 | Dtype::Bf16 => 2,
            Dtype::Fp32 => 4,
        }
    }
}

/// A single accelerator device (per-GPU peak numbers).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Peak dense matrix TFLOP/s at 16-bit (tensor-core / MXU path).
    pub peak_matrix_tflops: f64,
    /// Peak vector TFLOP/s (CUDA-core / VPU path) for elementwise & softmax.
    pub peak_vector_tflops: f64,
    /// HBM bandwidth in GB/s.
    pub mem_bw_gbs: f64,
    /// HBM capacity in GiB (used by the duplication memory constraint).
    pub mem_capacity_gib: f64,
    /// Fixed kernel-launch overhead per fused op, seconds.
    pub kernel_launch_s: f64,
}

impl DeviceSpec {
    /// NVIDIA A100 SXM 80GB: 312 TFLOP/s fp16 tensor core, 19.5 TFLOP/s
    /// fp32 CUDA core, 2039 GB/s HBM2e.
    pub fn a100() -> DeviceSpec {
        DeviceSpec {
            name: "A100-SXM-80GB".to_string(),
            peak_matrix_tflops: 312.0,
            peak_vector_tflops: 19.5,
            mem_bw_gbs: 2039.0,
            mem_capacity_gib: 80.0,
            kernel_launch_s: 4e-6,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", Value::Str(self.name.clone()))
            .set("peak_matrix_tflops", Value::Num(self.peak_matrix_tflops))
            .set("peak_vector_tflops", Value::Num(self.peak_vector_tflops))
            .set("mem_bw_gbs", Value::Num(self.mem_bw_gbs))
            .set("mem_capacity_gib", Value::Num(self.mem_capacity_gib))
            .set("kernel_launch_s", Value::Num(self.kernel_launch_s));
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<DeviceSpec> {
        Ok(DeviceSpec {
            name: v.req_str("name")?.to_string(),
            peak_matrix_tflops: v.req_f64("peak_matrix_tflops")?,
            peak_vector_tflops: v.req_f64("peak_vector_tflops")?,
            mem_bw_gbs: v.req_f64("mem_bw_gbs")?,
            mem_capacity_gib: v.req_f64("mem_capacity_gib")?,
            kernel_launch_s: v.req_f64("kernel_launch_s")?,
        })
    }
}

/// Interconnect between devices. The paper assumes a fully-connected
/// topology with identical per-link bandwidth; PCIe systems additionally
/// share the host root complex, so concurrent flows contend (`shared`).
#[derive(Clone, Debug, PartialEq)]
pub struct InterconnectSpec {
    pub name: String,
    /// Per-GPU unidirectional link bandwidth, GB/s.
    pub link_bw_gbs: f64,
    /// Point-to-point bandwidth for a single bulk transfer, GB/s (NVLink
    /// can stripe one transfer over all links — the paper's §5 expert-move
    /// arithmetic uses the 2 TB/s aggregate figure).
    pub p2p_bw_gbs: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// True when all devices share one fabric (PCIe through the host):
    /// concurrent collective flows serialise, scaling collective time by N.
    pub shared: bool,
}

impl InterconnectSpec {
    /// NVLink 3.0: 600 GB/s per-GPU link bandwidth for collectives (the
    /// paper's Figure 7 NVLink point), 2 TB/s striped point-to-point
    /// (the paper's §5 expert-movement arithmetic).
    pub fn nvlink3() -> InterconnectSpec {
        InterconnectSpec {
            name: "NVLink-3.0".to_string(),
            link_bw_gbs: 600.0,
            p2p_bw_gbs: 2000.0,
            latency_s: 2e-6,
            shared: false,
        }
    }

    /// PCIe 4.0 x16: 32 GB/s unidirectional per the paper's Figure 6d
    /// (Figure 7's low-end point is 64 GB/s, bidirectional accounting).
    /// All GPUs share the host root complex → `shared`.
    pub fn pcie4() -> InterconnectSpec {
        InterconnectSpec {
            name: "PCIe-4.0".to_string(),
            link_bw_gbs: 32.0,
            p2p_bw_gbs: 32.0,
            latency_s: 5e-6,
            shared: true,
        }
    }

    /// Arbitrary bandwidth point (Figure 7 sweeps 600/300/128/64 GB/s).
    /// Dedicated links, p2p equals link bandwidth.
    pub fn custom(gbs: f64) -> InterconnectSpec {
        InterconnectSpec {
            name: format!("custom-{gbs:.0}GBs"),
            link_bw_gbs: gbs,
            p2p_bw_gbs: gbs,
            latency_s: 3e-6,
            shared: false,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", Value::Str(self.name.clone()))
            .set("link_bw_gbs", Value::Num(self.link_bw_gbs))
            .set("p2p_bw_gbs", Value::Num(self.p2p_bw_gbs))
            .set("latency_s", Value::Num(self.latency_s))
            .set("shared", Value::Bool(self.shared));
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<InterconnectSpec> {
        Ok(InterconnectSpec {
            name: v.req_str("name")?.to_string(),
            link_bw_gbs: v.req_f64("link_bw_gbs")?,
            p2p_bw_gbs: v.req_f64("p2p_bw_gbs")?,
            latency_s: v.req_f64("latency_s")?,
            shared: v
                .get("shared")
                .and_then(Value::as_bool)
                .unwrap_or(false),
        })
    }
}

/// A multi-device system: N identical devices, fully connected.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemSpec {
    pub device: DeviceSpec,
    pub interconnect: InterconnectSpec,
    pub n_devices: usize,
}

impl SystemSpec {
    /// The paper's main testbed: 4×A100 fully connected via NVLink.
    pub fn four_a100_nvlink() -> SystemSpec {
        SystemSpec {
            device: DeviceSpec::a100(),
            interconnect: InterconnectSpec::nvlink3(),
            n_devices: 4,
        }
    }

    /// The paper's low-end testbed: 4×A100 over PCIe 4.0.
    pub fn four_a100_pcie() -> SystemSpec {
        SystemSpec {
            device: DeviceSpec::a100(),
            interconnect: InterconnectSpec::pcie4(),
            n_devices: 4,
        }
    }

    /// Same devices, arbitrary interconnect bandwidth (Figure 7 sweep).
    pub fn four_a100_custom_bw(gbs: f64) -> SystemSpec {
        SystemSpec {
            device: DeviceSpec::a100(),
            interconnect: InterconnectSpec::custom(gbs),
            n_devices: 4,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("device", self.device.to_json())
            .set("interconnect", self.interconnect.to_json())
            .set("n_devices", Value::Num(self.n_devices as f64));
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<SystemSpec> {
        Ok(SystemSpec {
            device: DeviceSpec::from_json(
                v.get("device").ok_or_else(|| anyhow::anyhow!("missing device"))?,
            )?,
            interconnect: InterconnectSpec::from_json(
                v.get("interconnect")
                    .ok_or_else(|| anyhow::anyhow!("missing interconnect"))?,
            )?,
            n_devices: v.req_usize("n_devices")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_constants() {
        let d = DeviceSpec::a100();
        assert_eq!(d.peak_matrix_tflops, 312.0);
        assert_eq!(d.mem_bw_gbs, 2039.0);
    }

    #[test]
    fn interconnect_presets() {
        assert_eq!(InterconnectSpec::nvlink3().link_bw_gbs, 600.0);
        assert_eq!(InterconnectSpec::pcie4().link_bw_gbs, 32.0);
        assert_eq!(InterconnectSpec::custom(128.0).link_bw_gbs, 128.0);
    }

    #[test]
    fn json_round_trip() {
        let sys = SystemSpec::four_a100_nvlink();
        let json = sys.to_json().to_string_pretty();
        let parsed = SystemSpec::from_json(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(sys, parsed);
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(Dtype::Fp16.bytes(), 2);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::Fp32.bytes(), 4);
    }
}
