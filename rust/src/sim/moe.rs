//! EP-MoE stage model: FFN compute + all-to-all communication under
//! skewness, for each prediction strategy (paper §3.2–§3.3).
//!
//! Strategy semantics (paper Figure 3):
//!
//! * **NoPrediction** — baseline. Hot GPU's FFN time and both all-to-all
//!   phases scale by the workload skewness.
//! * **DistributionOnly** — duplication driven by the predicted aggregate
//!   distribution balances *compute* (up to the estimation error ε fed
//!   through the error model), but "communication time remains unchanged"
//!   (§4): tokens are still randomly scattered post-all-reduce, so both
//!   all-to-all phases keep the baseline skew scaling. Zero overhead — the
//!   estimate is a moving average maintained offline.
//! * **TokenToExpert** — tokens are sent directly to their predicted GPU,
//!   eliminating the scatter for correctly-predicted tokens; misrouted
//!   tokens (fraction ε = 1 − accuracy) need a correction transfer, and —
//!   unlike compute — "communication costs always increase with prediction
//!   errors … optimistic cases do not exist in this context" (§3.3), so
//!   the comm term always uses the typical uniform-misroute model. Adds
//!   the predictor's runtime as overhead.

use super::collective;
use super::error_model::ErrorModel;
use super::ffn;
use super::hardware::SystemSpec;
use crate::model::ModelConfig;

/// Prediction strategy with its quality knobs (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    NoPrediction,
    /// `error_rate` is the paper's normalised distribution error
    /// `|p̂ − p| / (1/E)` averaged over layers (Table 1).
    DistributionOnly { error_rate: f64 },
    /// `accuracy` ∈ [0,1]; `overhead_s` is the predictor runtime for this
    /// batch (from `predictor::overhead`).
    TokenToExpert { accuracy: f64, overhead_s: f64 },
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoPrediction => "none",
            Strategy::DistributionOnly { .. } => "distribution-only",
            Strategy::TokenToExpert { .. } => "token-to-expert",
        }
    }
}

/// MoE-stage latency breakdown for the bottleneck device.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MoeCost {
    /// Pre-FFN all-to-all token scatter.
    pub scatter_s: f64,
    /// Expert FFN compute on the bottleneck GPU.
    pub ffn_s: f64,
    /// Post-FFN all-to-all gather.
    pub gather_s: f64,
    /// Prediction overhead (TEP only) *not* hidden by lookahead overlap.
    pub overhead_s: f64,
    /// Expert-movement time *not* hidden under attention (0 by default,
    /// see [`MoeParams::hide_duplication`] / [`MoeParams::lookahead_overlap`]).
    pub movement_s: f64,
    /// Movement + prediction time absorbed by the lookahead window
    /// (informational; never part of [`MoeCost::total`]).
    pub hidden_s: f64,
    /// Leader routing time hidden under in-flight FFN micro-batches
    /// (ADR 010; informational — the caller subtracts it from the
    /// exposed router charge, so it is never part of [`MoeCost::total`]).
    pub router_hidden_s: f64,
    /// Host-memory time moving the measured data-plane copy traffic
    /// (ADR 009: `copied_bytes_per_token` priced at HBM bandwidth).
    pub host_copy_s: f64,
}

impl MoeCost {
    pub fn total(&self) -> f64 {
        self.scatter_s
            + self.ffn_s
            + self.gather_s
            + self.overhead_s
            + self.movement_s
            + self.host_copy_s
    }

    pub fn comm_s(&self) -> f64 {
        self.scatter_s + self.gather_s
    }
}

/// Inputs to the MoE-stage simulation.
#[derive(Clone, Copy, Debug)]
pub struct MoeParams {
    pub batch: usize,
    pub seq: usize,
    /// Workload skewness (≥ 1).
    pub skewness: f64,
    pub strategy: Strategy,
    pub error_model: ErrorModel,
    /// If true (default, paper §5) expert-duplication transfers are hidden
    /// under the attention phase; if false their excess over the attention
    /// compute time is charged (ablation).
    pub hide_duplication: bool,
    /// Attention compute time available for hiding (only read when
    /// `hide_duplication` is false).
    pub attention_compute_s: f64,
    /// Prediction/placement frequency (paper §3.1): predict every
    /// `prediction_interval` batches and amortise the TEP overhead across
    /// them (existing systems range from every batch [8, 34] to every
    /// ~10 min [18]). 1 = the paper's default single-batch setting.
    /// Staleness is not modelled (the paper's simulator doesn't either).
    pub prediction_interval: usize,
    /// Ablation (DESIGN.md §3): the paper states DOP leaves communication
    /// unchanged (skew-scaled); if true, model the alternative where
    /// duplication also balances the all-to-all destinations (skew → 1).
    pub dop_balanced_comm: bool,
    /// ADR 002: model the serving engine's lookahead overlap. Replaces the
    /// paper's blanket "transfers hide under attention" assumption
    /// (`hide_duplication`, which this flag supersedes) with the explicit
    /// `max(compute, exposed_transfer + exposed_predict)` form: the
    /// attention window hides the duplication transfer first, then the
    /// prediction overhead; only the residue is charged.
    pub lookahead_overlap: bool,
    /// ADR 003: price the speculative TEP scatter (only meaningful with
    /// `lookahead_overlap` and Token-to-Expert). Correctly-predicted
    /// tokens ship before the repair dispatch runs, so the misprediction
    /// correction scatter overlaps with the confirmed tiles' FFN compute;
    /// only the residue stays on the critical path. The gather is
    /// unchanged (it waits on every expert's output regardless).
    pub speculative_scatter: bool,
    /// ADR 004: per-device HBM available for expert weights. When the
    /// device's expert working set (home experts, plus the duplicated
    /// replica for prediction strategies) exceeds this budget, the LRU
    /// weight cache evicts between layer visits and the missing fraction
    /// must be re-streamed each layer — demand-fetched at FFN time, after
    /// the prewarm window has passed, so it is pure exposed transfer.
    /// `None` (default) = unbounded, the pre-ADR-004 model.
    pub memory_cap_bytes: Option<f64>,
    /// ADR 006: proactive replanning horizon, in replan windows. With
    /// `h > 0` the Distribution-Only plan is built for the *forecast*
    /// distribution at the next replan boundary, so the duplication
    /// transfer prewarms before the boundary and never lands on the
    /// serving step — but the plan is `h` windows stale by maturity, so
    /// the forecast drift (per-window L1, which equals the paper's
    /// normalised error `mean|p̂ − p| / (1/E)`) inflates the effective
    /// estimation error by `drift × h`. 0 (default) = reactive replanning,
    /// the pre-ADR-006 model. TEP predicts per token, per step — a load
    /// trajectory buys it nothing, so it is unaffected.
    pub forecast_horizon: usize,
    /// ADR 006: forecast drift per horizon window (L1 of the share
    /// distribution). `None` = use [`DEFAULT_FORECAST_DRIFT`]; the online
    /// calibrator substitutes the measured realized-forecast error.
    pub forecast_drift: Option<f64>,
    /// ADR 010: micro-batch wavefront depth. With `K > 1` the layer's
    /// slots split into K micro-batches: while micro-batch `m`'s FFN is
    /// in flight the leader routes micro-batch `m+1`, so routing for
    /// micro-batches 2..K hides under the FFN window — only the first
    /// micro-batch's routing (1/K of `router_compute_s`) stays fully
    /// exposed. 1 (default) = serial, the pre-ADR-010 model.
    pub microbatch: usize,
    /// ADR 010: the leader's per-layer router compute time available for
    /// hiding (the caller passes its router model's output; 0 = none,
    /// making `microbatch` inert).
    pub router_compute_s: f64,
    /// ADR 009: measured data-plane copy traffic in bytes per token
    /// (`bytes_copied / tokens` from a serve report). Priced as a
    /// host-memory-bandwidth charge identical for every strategy —
    /// every strategy packs the same activation rows. 0 = not measured.
    pub copied_bytes_per_token: f64,
}

/// ADR 006: default per-window forecast drift (L1 distance of expert-share
/// distributions) used when no measured value is available. ~2% per replan
/// window is the steady-drift regime of production traces ("Prediction Is
/// All MoE Needs", arXiv 2404.16914 observes decode-phase loads stabilise);
/// adversarial traces run far higher — the `StrategyController` falls back
/// to reactive replanning when the measured error breaches its threshold.
pub const DEFAULT_FORECAST_DRIFT: f64 = 0.02;

impl MoeParams {
    pub fn new(batch: usize, seq: usize, skewness: f64, strategy: Strategy) -> MoeParams {
        MoeParams {
            batch,
            seq,
            skewness,
            strategy,
            error_model: ErrorModel::Typical,
            hide_duplication: true,
            attention_compute_s: 0.0,
            prediction_interval: 1,
            dop_balanced_comm: false,
            lookahead_overlap: false,
            speculative_scatter: false,
            memory_cap_bytes: None,
            forecast_horizon: 0,
            forecast_drift: None,
            microbatch: 1,
            router_compute_s: 0.0,
            copied_bytes_per_token: 0.0,
        }
    }
}

/// Split raw (movement, prediction) costs into exposed residues under the
/// overlap window: the window absorbs the duplication transfer first, then
/// the prediction; the remainder lands on the critical path. Returns
/// `(exposed_movement, exposed_overhead, hidden)`; the sum of all exposed
/// and hidden parts equals `movement_raw + overhead_raw`, making the total
/// layer time `compute + max(0, movement + overhead − window)` — i.e.
/// `max(compute, exposed_transfer + exposed_predict)` when the window is
/// the full compute time (ADR 002).
pub fn overlap_split(movement_raw: f64, overhead_raw: f64, window: f64) -> (f64, f64, f64) {
    let exposed_movement = (movement_raw - window).max(0.0);
    let window_left = (window - movement_raw).max(0.0);
    let exposed_overhead = (overhead_raw - window_left).max(0.0);
    let hidden = (movement_raw - exposed_movement) + (overhead_raw - exposed_overhead);
    (exposed_movement, exposed_overhead, hidden)
}

/// Exposed per-layer refetch charge under a device memory cap (ADR 004).
///
/// Per-device expert working set: `n_experts / n_devices` home experts
/// per layer, plus one duplicated replica per layer for strategies that
/// move experts (the paper's §5 one-expert-per-GPU-per-layer scale) —
/// across all layers. When the cap cannot hold that set, an LRU weight
/// cache thrashes: by the time a layer comes around again, the missing
/// fraction of its weights was evicted and must be re-streamed over the
/// interconnect before the FFN can run. The charge is the miss fraction
/// times the time to move one layer's per-device expert weights — pure
/// exposed transfer (demand-fetched at FFN time; the prewarm window
/// already passed). Returns 0 when `cap` is `None` or the set fits.
pub(crate) fn memory_pressure_refetch_s(
    model: &ModelConfig,
    system: &SystemSpec,
    cap_bytes: Option<f64>,
    duplicated: bool,
) -> f64 {
    let Some(cap) = cap_bytes else { return 0.0 };
    let n = system.n_devices as f64;
    let local_experts = (model.n_experts as f64 / n).max(1.0);
    let replicas = if duplicated { 1.0 } else { 0.0 };
    let per_layer_bytes = (local_experts + replicas) * model.expert_bytes();
    let needed = model.n_layers as f64 * per_layer_bytes;
    if cap.max(0.0) >= needed {
        return 0.0;
    }
    let miss = 1.0 - (cap.max(0.0) / needed).clamp(0.0, 1.0);
    miss * collective::p2p_time(&system.interconnect, per_layer_bytes)
}

/// Simulate the MoE stage (scatter → expert FFN → gather) of one layer.
pub fn moe_cost(model: &ModelConfig, system: &SystemSpec, p: &MoeParams) -> MoeCost {
    let n = system.n_devices;
    let tokens = p.batch * p.seq;
    // Token-slots: each token occupies top_k expert slots.
    let slots = tokens * model.top_k;
    let bytes_per_token = model.d_model as f64 * model.dtype.bytes() as f64;
    let skew = p.skewness.max(1.0);

    // Balanced per-device FFN reference (perfect distribution).
    let balanced_ffn = ffn::balanced_device_ffn_time(model, &system.device, slots, n);
    // Balanced all-to-all reference (skew = 1).
    let balanced_a2a = collective::ep_all_to_all_time(
        &system.interconnect,
        n,
        slots as f64,
        bytes_per_token,
        1.0,
    );
    let skewed_a2a = collective::ep_all_to_all_time(
        &system.interconnect,
        n,
        slots as f64,
        bytes_per_token,
        skew,
    );

    let mut cost = MoeCost::default();
    match p.strategy {
        Strategy::NoPrediction => {
            // Paper §2: bottleneck FFN and both shuffles scale by skewness.
            cost.ffn_s = balanced_ffn * skew;
            cost.scatter_s = skewed_a2a;
            cost.gather_s = skewed_a2a;
        }
        Strategy::DistributionOnly { error_rate } => {
            // ADR 006: a plan built for the forecast distribution serves a
            // window whose realized shares drifted ~drift × horizon in L1
            // by maturity; the L1 share distance *is* the paper's
            // normalised error, so staleness adds to ε directly.
            let stale = if p.forecast_horizon > 0 {
                p.forecast_drift.unwrap_or(DEFAULT_FORECAST_DRIFT).max(0.0)
                    * p.forecast_horizon as f64
            } else {
                0.0
            };
            let mult = p.error_model.load_multiplier(error_rate + stale, n);
            cost.ffn_s = balanced_ffn * mult;
            // Communication unchanged vs baseline (§4) — unless the
            // balanced-destination ablation is enabled.
            let a2a = if p.dop_balanced_comm { balanced_a2a } else { skewed_a2a };
            cost.scatter_s = a2a;
            cost.gather_s = a2a;
            if p.forecast_horizon > 0 {
                // ADR 006: the forecast plan's replicas prewarm during the
                // windows *before* the replan boundary, so the duplication
                // transfer is off the serving step entirely — the staleness
                // term above is what pays for that hiding.
                cost.hidden_s = raw_movement(model, system);
            } else if p.lookahead_overlap {
                let raw = raw_movement(model, system);
                let (mv, _oh, hidden) = overlap_split(raw, 0.0, p.attention_compute_s);
                cost.movement_s = mv;
                cost.hidden_s = hidden;
            } else {
                cost.movement_s = movement_cost(model, system, p);
            }
        }
        Strategy::TokenToExpert { accuracy, overhead_s } => {
            let eps = (1.0 - accuracy).clamp(0.0, 1.0);
            let mult = p.error_model.load_multiplier(eps, n);
            cost.ffn_s = balanced_ffn * mult;
            // Correct predictions skip the shuffle entirely; mispredicted
            // tokens take a correction hop. Always the typical model (§3.3).
            cost.scatter_s = balanced_a2a * eps;
            cost.gather_s = balanced_a2a * eps;
            // §3.1: amortise predictor overhead over the prediction interval.
            let overhead_amortised = overhead_s / p.prediction_interval.max(1) as f64;
            if p.lookahead_overlap {
                // ADR 002: the predictor forecasts layer L+1 while layer L
                // computes, so its runtime hides under the same window as
                // the duplication transfer (transfer first).
                let raw = raw_movement(model, system);
                let (mv, oh, hidden) =
                    overlap_split(raw, overhead_amortised, p.attention_compute_s);
                cost.movement_s = mv;
                cost.overhead_s = oh;
                cost.hidden_s = hidden;
                if p.speculative_scatter {
                    // ADR 003: confirmed tokens (fraction 1 − ε) were
                    // dispatched before the repair pass, so the correction
                    // scatter overlaps with their FFN compute; only the
                    // residue is exposed. Conservation: exposed + hidden
                    // scatter = the plain ε-scatter charge.
                    let window = cost.ffn_s * (1.0 - eps);
                    let hidden_scatter = cost.scatter_s.min(window);
                    cost.scatter_s -= hidden_scatter;
                    cost.hidden_s += hidden_scatter;
                }
            } else {
                cost.overhead_s = overhead_amortised;
                cost.movement_s = movement_cost(model, system, p);
            }
        }
    }
    // ADR 004: memory-pressure refetch is exposed for every strategy; the
    // duplicated replica enlarges the prediction strategies' working set,
    // so under a tight cap they pay more than the baseline.
    cost.movement_s += memory_pressure_refetch_s(
        model,
        system,
        p.memory_cap_bytes,
        !matches!(p.strategy, Strategy::NoPrediction),
    );
    // ADR 010: the wavefront pipelines routing against in-flight FFN
    // micro-batches for every strategy. Each of the K−1 later micro-
    // batches hides its routing slice (router/K) under the previous
    // micro-batch's FFN slice (ffn/K) — the first micro-batch's routing
    // is always exposed, and hiding is capped by the FFN window.
    if p.microbatch > 1 && p.router_compute_s > 0.0 {
        let k = p.microbatch as f64;
        let hidden_per = (p.router_compute_s / k).min(cost.ffn_s / k);
        cost.router_hidden_s = hidden_per * (k - 1.0);
        cost.hidden_s += cost.router_hidden_s;
    }
    // ADR 009 follow-up: the measured host copy traffic (FFN slab gather)
    // is the same activation bytes for every strategy — a flat host-HBM
    // charge, so totals shift but savings differences do not.
    if p.copied_bytes_per_token > 0.0 {
        cost.host_copy_s =
            tokens as f64 * p.copied_bytes_per_token / (system.device.mem_bw_gbs * 1e9);
    }
    cost
}

/// Raw expert-movement (duplication) transfer time: one expert sent +
/// received per GPU per layer (paper §5).
fn raw_movement(model: &ModelConfig, system: &SystemSpec) -> f64 {
    collective::p2p_time(&system.interconnect, model.expert_bytes())
}

/// Expert-movement cost not hidden under attention — the paper's blanket
/// assumption (`hide_duplication`); the overlap model prices it explicitly
/// instead ([`overlap_split`]).
fn movement_cost(model: &ModelConfig, system: &SystemSpec, p: &MoeParams) -> f64 {
    if p.hide_duplication {
        return 0.0;
    }
    (raw_movement(model, system) - p.attention_compute_s).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SystemSpec;

    fn mixtral_nvlink() -> (ModelConfig, SystemSpec) {
        (ModelConfig::mixtral_8x7b(), SystemSpec::four_a100_nvlink())
    }

    #[test]
    fn baseline_scales_with_skew() {
        let (m, s) = mixtral_nvlink();
        let at = |skew| {
            moe_cost(
                &m,
                &s,
                &MoeParams::new(1, 512, skew, Strategy::NoPrediction),
            )
        };
        let c1 = at(1.0);
        let c2 = at(2.0);
        assert!((c2.ffn_s / c1.ffn_s - 2.0).abs() < 1e-9);
        assert!(c2.scatter_s > c1.scatter_s);
        assert!(c2.total() > c1.total());
    }

    #[test]
    fn dop_balances_compute_but_not_comm() {
        let (m, s) = mixtral_nvlink();
        let skew = 2.0;
        let base = moe_cost(&m, &s, &MoeParams::new(1, 512, skew, Strategy::NoPrediction));
        let dop = moe_cost(
            &m,
            &s,
            &MoeParams::new(1, 512, skew, Strategy::DistributionOnly { error_rate: 0.02 }),
        );
        assert!(dop.ffn_s < base.ffn_s * 0.6, "compute should rebalance");
        assert_eq!(dop.scatter_s, base.scatter_s, "comm unchanged (paper §4)");
        assert_eq!(dop.gather_s, base.gather_s);
        assert_eq!(dop.overhead_s, 0.0, "DOP has zero overhead");
    }

    #[test]
    fn tep_perfect_prediction_eliminates_comm() {
        let (m, s) = mixtral_nvlink();
        let tep = moe_cost(
            &m,
            &s,
            &MoeParams::new(
                1,
                512,
                2.0,
                Strategy::TokenToExpert {
                    accuracy: 1.0,
                    overhead_s: 0.0,
                },
            ),
        );
        // Only the latency terms (ε=0 kills the bandwidth terms).
        assert!(tep.scatter_s < 1e-9);
        assert!(tep.gather_s < 1e-9);
        // Compute balanced.
        let balanced = ffn::balanced_device_ffn_time(&m, &s.device, 1024, 4);
        assert!((tep.ffn_s - balanced).abs() / balanced < 1e-9);
    }

    #[test]
    fn tep_comm_grows_with_error() {
        let (m, s) = mixtral_nvlink();
        let at = |acc| {
            moe_cost(
                &m,
                &s,
                &MoeParams::new(
                    1,
                    512,
                    1.4,
                    Strategy::TokenToExpert {
                        accuracy: acc,
                        overhead_s: 0.0,
                    },
                ),
            )
        };
        assert!(at(0.7).comm_s() > at(0.9).comm_s());
        assert!(at(0.9).comm_s() > at(1.0).comm_s());
    }

    #[test]
    fn error_models_order_ffn_time() {
        let (m, s) = mixtral_nvlink();
        let mk = |em| {
            let mut p = MoeParams::new(
                1,
                512,
                1.4,
                Strategy::DistributionOnly { error_rate: 0.1 },
            );
            p.error_model = em;
            moe_cost(&m, &s, &p).ffn_s
        };
        let o = mk(ErrorModel::Optimistic);
        let t = mk(ErrorModel::Typical);
        let pess = mk(ErrorModel::Pessimistic);
        assert!(o < t && t < pess);
    }

    #[test]
    fn movement_hidden_by_default_charged_when_exposed() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(
            1,
            512,
            1.4,
            Strategy::DistributionOnly { error_rate: 0.0 },
        );
        assert_eq!(moe_cost(&m, &s, &p).movement_s, 0.0);
        p.hide_duplication = false;
        p.attention_compute_s = 0.0;
        let exposed = moe_cost(&m, &s, &p).movement_s;
        assert!(exposed > 0.0);
        // With enough attention time it hides again.
        p.attention_compute_s = 1.0;
        assert_eq!(moe_cost(&m, &s, &p).movement_s, 0.0);
    }

    #[test]
    fn prediction_interval_amortises_overhead() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(
            1,
            512,
            1.4,
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: 1e-3,
            },
        );
        let every_batch = moe_cost(&m, &s, &p).overhead_s;
        p.prediction_interval = 10;
        let every_ten = moe_cost(&m, &s, &p).overhead_s;
        assert!((every_batch / every_ten - 10.0).abs() < 1e-9);
    }

    #[test]
    fn dop_balanced_comm_ablation() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(
            1,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        let unchanged = moe_cost(&m, &s, &p);
        p.dop_balanced_comm = true;
        let balanced = moe_cost(&m, &s, &p);
        assert!(balanced.comm_s() < unchanged.comm_s());
        assert_eq!(balanced.ffn_s, unchanged.ffn_s);
    }

    #[test]
    fn overlap_split_arithmetic() {
        // Window absorbs movement first, then prediction.
        let (mv, oh, hidden) = overlap_split(2.0, 3.0, 4.0);
        assert_eq!(mv, 0.0);
        assert_eq!(oh, 1.0);
        assert_eq!(hidden, 4.0);
        // Nothing hides without a window.
        let (mv, oh, hidden) = overlap_split(2.0, 3.0, 0.0);
        assert_eq!((mv, oh, hidden), (2.0, 3.0, 0.0));
        // Everything hides under a big window.
        let (mv, oh, hidden) = overlap_split(2.0, 3.0, 100.0);
        assert_eq!((mv, oh, hidden), (0.0, 0.0, 5.0));
        // Conservation: exposed + hidden = raw.
        for window in [0.0, 0.5, 1.7, 2.0, 4.9, 10.0] {
            let (mv, oh, hidden) = overlap_split(2.0, 3.0, window);
            assert!((mv + oh + hidden - 5.0).abs() < 1e-12, "window={window}");
        }
    }

    #[test]
    fn lookahead_overlap_hides_tep_overhead_under_attention() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-3,
        };
        let mut p = MoeParams::new(1, 512, 1.4, strategy);
        p.attention_compute_s = 10.0; // huge window
        let plain = moe_cost(&m, &s, &p);
        assert_eq!(plain.overhead_s, 1e-3, "no overlap: overhead exposed");
        p.lookahead_overlap = true;
        let overlapped = moe_cost(&m, &s, &p);
        assert_eq!(overlapped.overhead_s, 0.0, "overlap: overhead hidden");
        assert_eq!(overlapped.movement_s, 0.0);
        assert!(overlapped.hidden_s > 1e-3, "hidden must include overhead + transfer");
        assert!(overlapped.total() < plain.total());
        // Zero window: movement + overhead fully exposed (worse than the
        // blanket hide_duplication assumption for DOP-style movement).
        p.attention_compute_s = 0.0;
        let exposed = moe_cost(&m, &s, &p);
        assert_eq!(exposed.hidden_s, 0.0);
        assert_eq!(exposed.overhead_s, 1e-3);
        assert!(exposed.movement_s > 0.0, "transfer exposed without a window");
    }

    #[test]
    fn speculative_scatter_hides_correction_under_ffn() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-4,
        };
        let mut p = MoeParams::new(1, 512, 2.0, strategy);
        p.lookahead_overlap = true;
        p.attention_compute_s = 1e-3;
        let plain = moe_cost(&m, &s, &p);
        p.speculative_scatter = true;
        let spec = moe_cost(&m, &s, &p);
        assert!(spec.scatter_s < plain.scatter_s, "scatter must shrink");
        assert!(spec.scatter_s >= 0.0);
        // Conservation: what left the scatter moved into hidden.
        let moved = plain.scatter_s - spec.scatter_s;
        assert!((spec.hidden_s - plain.hidden_s - moved).abs() < 1e-15);
        assert_eq!(spec.gather_s, plain.gather_s, "gather unchanged");
        assert_eq!(spec.ffn_s, plain.ffn_s);
        assert!(spec.total() < plain.total());
        // Without lookahead the flag is inert.
        p.lookahead_overlap = false;
        let inert = moe_cost(&m, &s, &p);
        p.speculative_scatter = false;
        assert_eq!(inert, moe_cost(&m, &s, &p));
        // DOP is never affected by the TEP-only flag.
        let mut pd = MoeParams::new(
            1,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        pd.lookahead_overlap = true;
        pd.attention_compute_s = 1e-3;
        let dop_plain = moe_cost(&m, &s, &pd);
        pd.speculative_scatter = true;
        assert_eq!(dop_plain, moe_cost(&m, &s, &pd));
    }

    #[test]
    fn lookahead_overlap_leaves_baseline_untouched() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(1, 512, 2.0, Strategy::NoPrediction);
        let plain = moe_cost(&m, &s, &p);
        p.lookahead_overlap = true;
        p.attention_compute_s = 1.0;
        assert_eq!(moe_cost(&m, &s, &p), plain);
    }

    #[test]
    fn memory_cap_charges_refetch_and_penalises_duplication() {
        let (m, s) = mixtral_nvlink();
        let base_needed =
            m.n_layers as f64 * (m.n_experts as f64 / s.n_devices as f64) * m.expert_bytes();
        // Roomy cap: everything fits, nothing changes for any strategy.
        for strategy in [
            Strategy::NoPrediction,
            Strategy::DistributionOnly { error_rate: 0.02 },
            Strategy::TokenToExpert { accuracy: 0.9, overhead_s: 1e-4 },
        ] {
            let mut p = MoeParams::new(1, 512, 2.0, strategy);
            let plain = moe_cost(&m, &s, &p);
            p.memory_cap_bytes = Some(base_needed * 4.0);
            assert_eq!(moe_cost(&m, &s, &p), plain, "{strategy:?}");
        }
        // Cap between the baseline and the duplicated working set: only
        // the duplication strategies pay (their replica overflows).
        let mut pb = MoeParams::new(1, 512, 2.0, Strategy::NoPrediction);
        pb.memory_cap_bytes = Some(base_needed);
        assert_eq!(moe_cost(&m, &s, &pb).movement_s, 0.0, "baseline fits");
        let mut pd = MoeParams::new(
            1,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        let unbounded = moe_cost(&m, &s, &pd);
        pd.memory_cap_bytes = Some(base_needed);
        let capped = moe_cost(&m, &s, &pd);
        assert!(
            capped.movement_s > unbounded.movement_s,
            "duplication must pay exposed refetch under the cap"
        );
        assert!(capped.total() > unbounded.total());
        // A tighter cap charges everyone, duplication still strictly more.
        let tight = Some(base_needed * 0.5);
        pb.memory_cap_bytes = tight;
        pd.memory_cap_bytes = tight;
        let base_refetch = moe_cost(&m, &s, &pb).movement_s;
        let dop_refetch = moe_cost(&m, &s, &pd).movement_s;
        assert!(base_refetch > 0.0);
        assert!(dop_refetch > base_refetch);
        // Refetch monotone in pressure: halving the cap can only cost more.
        pd.memory_cap_bytes = Some(base_needed * 0.25);
        assert!(moe_cost(&m, &s, &pd).movement_s > dop_refetch);
    }

    #[test]
    fn forecast_horizon_hides_dop_movement_but_inflates_staleness() {
        let (m, s) = mixtral_nvlink();
        // Exposed-movement ablation so the hiding is observable.
        let mut p = MoeParams::new(
            1,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        p.hide_duplication = false;
        p.attention_compute_s = 0.0;
        let reactive = moe_cost(&m, &s, &p);
        assert!(reactive.movement_s > 0.0, "ablation exposes the transfer");
        p.forecast_horizon = 2;
        let proactive = moe_cost(&m, &s, &p);
        // Prewarmed before the boundary: transfer off the serving step.
        assert_eq!(proactive.movement_s, 0.0);
        assert!((proactive.hidden_s - reactive.movement_s).abs() < 1e-15);
        // …at the price of a staler plan: ε_eff = ε + drift·h.
        assert!(proactive.ffn_s > reactive.ffn_s);
        // Staleness is monotone in the horizon.
        p.forecast_horizon = 8;
        assert!(moe_cost(&m, &s, &p).ffn_s > proactive.ffn_s);
    }

    #[test]
    fn zero_drift_forecast_is_a_pure_win_for_dop() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(
            1,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        p.hide_duplication = false;
        p.attention_compute_s = 0.0;
        let reactive = moe_cost(&m, &s, &p);
        p.forecast_horizon = 4;
        p.forecast_drift = Some(0.0);
        let perfect = moe_cost(&m, &s, &p);
        // A perfect forecaster keeps DOP's compute and drops the exposed
        // movement: strictly no worse, strictly better under the ablation.
        assert_eq!(perfect.ffn_s, reactive.ffn_s);
        assert!(perfect.total() < reactive.total());
        // Measured drift overrides the default (larger drift, worse plan).
        p.forecast_drift = Some(0.25);
        assert!(moe_cost(&m, &s, &p).ffn_s > perfect.ffn_s);
    }

    #[test]
    fn forecast_horizon_leaves_baseline_and_tep_untouched() {
        let (m, s) = mixtral_nvlink();
        for strategy in [
            Strategy::NoPrediction,
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: 1e-4,
            },
        ] {
            let mut p = MoeParams::new(1, 512, 2.0, strategy);
            let plain = moe_cost(&m, &s, &p);
            p.forecast_horizon = 4;
            p.forecast_drift = Some(0.1);
            assert_eq!(moe_cost(&m, &s, &p), plain, "{strategy:?}");
        }
    }

    #[test]
    fn microbatch_hides_router_compute_under_the_ffn_window() {
        let (m, s) = mixtral_nvlink();
        let mut p = MoeParams::new(1, 512, 2.0, Strategy::NoPrediction);
        p.router_compute_s = 1e-3;
        // K = 1 (and a zero router window) are exact no-ops.
        let serial = moe_cost(&m, &s, &p);
        assert_eq!(serial.router_hidden_s, 0.0);
        let mut inert = p;
        inert.microbatch = 4;
        inert.router_compute_s = 0.0;
        assert_eq!(moe_cost(&m, &s, &inert).router_hidden_s, 0.0);
        // Hiding is monotone in K with asymptote min(router, ffn):
        // hidden(K) = (K−1)/K · min(r, f).
        p.microbatch = 2;
        let k2 = moe_cost(&m, &s, &p);
        p.microbatch = 4;
        let k4 = moe_cost(&m, &s, &p);
        p.microbatch = 64;
        let k64 = moe_cost(&m, &s, &p);
        assert!(k2.router_hidden_s > 0.0);
        assert!(k4.router_hidden_s > k2.router_hidden_s);
        assert!(k64.router_hidden_s > k4.router_hidden_s);
        let cap = p.router_compute_s.min(k64.ffn_s);
        assert!(k64.router_hidden_s <= cap + 1e-15);
        assert!((k2.router_hidden_s - 0.5 * p.router_compute_s.min(k2.ffn_s)).abs() < 1e-15);
        // Informational: the hidden routing never enters the MoE total —
        // the caller subtracts it from its exposed router charge.
        assert_eq!(k4.total(), serial.total());
        assert!((k4.hidden_s - serial.hidden_s - k4.router_hidden_s).abs() < 1e-15);
    }

    #[test]
    fn copied_bytes_charge_host_bandwidth_uniformly() {
        let (m, s) = mixtral_nvlink();
        let per_token = m.d_model as f64 * 4.0;
        let mut totals = Vec::new();
        for strategy in [
            Strategy::NoPrediction,
            Strategy::DistributionOnly { error_rate: 0.02 },
            Strategy::TokenToExpert { accuracy: 0.9, overhead_s: 1e-4 },
        ] {
            let mut p = MoeParams::new(1, 512, 2.0, strategy);
            let plain = moe_cost(&m, &s, &p);
            assert_eq!(plain.host_copy_s, 0.0, "unmeasured plane: no charge");
            p.copied_bytes_per_token = per_token;
            let priced = moe_cost(&m, &s, &p);
            let expect = 512.0 * per_token / (s.device.mem_bw_gbs * 1e9);
            assert!((priced.host_copy_s - expect).abs() < 1e-18, "{strategy:?}");
            assert!((priced.total() - plain.total() - expect).abs() < 1e-15);
            totals.push(priced.host_copy_s);
        }
        // Strategy-independent: every strategy pays the identical charge.
        assert!(totals.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-18));
    }

    #[test]
    fn overhead_passed_through() {
        let (m, s) = mixtral_nvlink();
        let c = moe_cost(
            &m,
            &s,
            &MoeParams::new(
                1,
                512,
                1.4,
                Strategy::TokenToExpert {
                    accuracy: 0.9,
                    overhead_s: 1.5e-3,
                },
            ),
        );
        assert_eq!(c.overhead_s, 1.5e-3);
        assert!(c.total() >= 1.5e-3);
    }
}
