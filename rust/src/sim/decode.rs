//! Decode-phase (autoregressive) cost model — the serving regime the
//! paper's prefill figures do not cover, and where production MoE traffic
//! actually lives (DESIGN.md §5).
//!
//! A decode step routes **one token per active sequence**, so the expert
//! FFN runs in the *memory-bound* regime: with a handful of tokens per
//! expert, each expert GEMM's time is dominated by streaming its weight
//! matrices from HBM, not by arithmetic — so skew barely moves FFN time
//! (compare the compute-bound prefill roofline, where the hot GPU's FFN
//! scales linearly with skew). What skew still scales is the all-to-all,
//! and what hurts Token-to-Expert is that its predictor runs on **every
//! step's brand-new tokens**: the per-step overhead has a launch-bound
//! floor that does not shrink with the tiny decode batch, while the step
//! itself is short. Distribution-Only's estimate is free to read and its
//! replanning amortises across `replan_interval` steps
//! (`docs/adr/001-decode-prediction-cadence.md`), which is why "Prediction
//! Is All MoE Needs" (arXiv 2404.16914) observes decode-phase load
//! stabilise — the regime favours DOP even more than prefill.

use super::attention::AttentionCost;
use super::collective;
use super::error_model::ErrorModel;
use super::ffn;
use super::hardware::SystemSpec;
use super::layer::LayerBreakdown;
use super::moe::{self, MoeCost, Strategy};
use super::roofline;
use crate::model::ModelConfig;

/// Inputs to the decode-step MoE simulation.
#[derive(Clone, Copy, Debug)]
pub struct DecodeParams {
    /// Concurrently decoding sequences (1 new token each per step).
    pub batch: usize,
    /// Mean context length (KV-cache depth) across the batch.
    pub ctx_len: usize,
    /// Workload skewness (≥ 1).
    pub skewness: f64,
    pub strategy: Strategy,
    pub error_model: ErrorModel,
    /// Algorithm-1 replanning cadence in steps (ADR 001): duplication
    /// transfers amortise across it for Distribution-Only. Token-to-Expert
    /// replans per step (its predictions cover only this step's tokens),
    /// so its movement never amortises — and its predictor overhead is
    /// charged in full every step regardless of this knob.
    pub replan_interval: usize,
    /// If true (default) expert-duplication transfers are hidden under
    /// attention; if false their excess is charged (ablation, as prefill).
    pub hide_duplication: bool,
    pub attention_compute_s: f64,
    /// ADR 002: price the serving engine's lookahead overlap (supersedes
    /// `hide_duplication`): the per-step attention window explicitly hides
    /// the (cadence-amortised) duplication transfer first, then the
    /// predictor runtime; only the residue is charged.
    pub lookahead_overlap: bool,
    /// ADR 003: price the speculative TEP scatter (see
    /// [`super::moe::MoeParams::speculative_scatter`]) — confirmed tokens
    /// dispatch ahead of the repair pass, hiding the correction scatter
    /// under their FFN compute. TEP + `lookahead_overlap` only.
    pub speculative_scatter: bool,
    /// ADR 004: per-device HBM available for expert weights (see
    /// [`super::moe::MoeParams::memory_cap_bytes`]). Decode is already
    /// weight-streaming-bound from HBM; under the cap the missing
    /// fraction streams from host/peer instead — exposed, every step.
    pub memory_cap_bytes: Option<f64>,
    /// ADR 006: proactive-replanning horizon in replan windows (see
    /// [`super::moe::MoeParams::forecast_horizon`]). With `h > 0` the DOP
    /// plan is built from the load forecast ahead of the replan boundary:
    /// the duplication transfer prewarms during the preceding windows
    /// (fully hidden, still cadence-amortised in the books) while the
    /// effective estimation error inflates by `drift × h`. TEP predicts
    /// this step's brand-new tokens — a trajectory buys it nothing.
    pub forecast_horizon: usize,
    /// ADR 006: per-window forecast drift; `None` = use
    /// [`super::moe::DEFAULT_FORECAST_DRIFT`].
    pub forecast_drift: Option<f64>,
    /// ADR 010: micro-batch wavefront depth (see
    /// [`super::moe::MoeParams::microbatch`]). 1 = serial.
    pub microbatch: usize,
    /// ADR 010: per-step leader router compute time available for hiding
    /// under in-flight FFN micro-batches (0 = none).
    pub router_compute_s: f64,
    /// ADR 009: measured data-plane copy bytes per token (see
    /// [`super::moe::MoeParams::copied_bytes_per_token`]). 0 = unmeasured.
    pub copied_bytes_per_token: f64,
}

impl DecodeParams {
    pub fn new(batch: usize, ctx_len: usize, skewness: f64, strategy: Strategy) -> DecodeParams {
        DecodeParams {
            batch,
            ctx_len,
            skewness,
            strategy,
            error_model: ErrorModel::Typical,
            replan_interval: 1,
            hide_duplication: true,
            attention_compute_s: 0.0,
            lookahead_overlap: false,
            speculative_scatter: false,
            memory_cap_bytes: None,
            forecast_horizon: 0,
            forecast_drift: None,
            microbatch: 1,
            router_compute_s: 0.0,
            copied_bytes_per_token: 0.0,
        }
    }
}

/// Simulate the MoE stage of one decode step for one layer.
pub fn decode_moe_cost(model: &ModelConfig, system: &SystemSpec, p: &DecodeParams) -> MoeCost {
    let n = system.n_devices;
    // One token per sequence; each occupies top_k expert slots.
    let slots = p.batch * model.top_k;
    let bytes_per_token = model.d_model as f64 * model.dtype.bytes() as f64;
    let skew = p.skewness.max(1.0);

    // Balanced reference: slots spread evenly over experts; every local
    // expert with work streams its full weights (the memory-bound floor).
    let balanced_ffn = ffn::balanced_device_ffn_time(model, &system.device, slots, n);
    // Hot device under skew: its experts hold `skew ×` the balanced token
    // share. In this regime the weight-stream term dominates, so this is
    // nearly flat in skew — the decode-phase contrast with prefill.
    let experts_local = (model.n_experts / n).max(1);
    let per_expert_balanced = slots / model.n_experts.max(1);
    let per_expert_hot =
        ((per_expert_balanced as f64 * skew).ceil() as usize).max(per_expert_balanced);
    let skewed_ffn =
        ffn::device_ffn_time(model, &system.device, &vec![per_expert_hot; experts_local]);

    let balanced_a2a = collective::ep_all_to_all_time(
        &system.interconnect,
        n,
        slots as f64,
        bytes_per_token,
        1.0,
    );
    let skewed_a2a = collective::ep_all_to_all_time(
        &system.interconnect,
        n,
        slots as f64,
        bytes_per_token,
        skew,
    );

    let mut cost = MoeCost::default();
    match p.strategy {
        Strategy::NoPrediction => {
            cost.ffn_s = skewed_ffn;
            cost.scatter_s = skewed_a2a;
            cost.gather_s = skewed_a2a;
        }
        Strategy::DistributionOnly { error_rate } => {
            // ADR 006: a forecast-built plan is `horizon` windows stale by
            // maturity; the drift adds to ε (L1 share distance = the
            // paper's normalised error), as in prefill.
            let stale = if p.forecast_horizon > 0 {
                p.forecast_drift
                    .unwrap_or(moe::DEFAULT_FORECAST_DRIFT)
                    .max(0.0)
                    * p.forecast_horizon as f64
            } else {
                0.0
            };
            let mult = p.error_model.load_multiplier(error_rate + stale, n);
            // Token counts rebalance; residual error inflates the hot
            // expert's token count, but stays on the memory-bound floor.
            let per_expert_dop = ((per_expert_balanced as f64 * mult).ceil() as usize)
                .max(per_expert_balanced.max(1));
            cost.ffn_s =
                ffn::device_ffn_time(model, &system.device, &vec![per_expert_dop; experts_local])
                    .min(skewed_ffn)
                    .max(balanced_ffn);
            // Communication unchanged vs baseline (§4), as in prefill.
            cost.scatter_s = skewed_a2a;
            cost.gather_s = skewed_a2a;
            if p.forecast_horizon > 0 {
                // ADR 006: the replica prewarms during the windows before
                // the replan boundary — off the serving step entirely,
                // still amortised across the cadence in the books.
                let steps = p.replan_interval.max(1) as f64;
                cost.hidden_s = raw_movement(model, system) / steps;
            } else if p.lookahead_overlap {
                // Clip against ONE step's window first, then amortise the
                // exposed remainder over the cadence: the engine moves the
                // whole transfer on the replan step, so only that step's
                // window can hide it (amortise-then-clip would overstate
                // hiding by up to replan_interval×).
                let raw = raw_movement(model, system);
                let (mv, _oh, hidden) =
                    moe::overlap_split(raw, 0.0, p.attention_compute_s);
                let steps = p.replan_interval.max(1) as f64;
                cost.movement_s = mv / steps;
                cost.hidden_s = hidden / steps;
            } else {
                cost.movement_s = movement_cost(model, system, p, p.replan_interval);
            }
        }
        Strategy::TokenToExpert { accuracy, overhead_s } => {
            let eps = (1.0 - accuracy).clamp(0.0, 1.0);
            let mult = p.error_model.load_multiplier(eps, n);
            let per_expert_tep = ((per_expert_balanced as f64 * mult).ceil() as usize)
                .max(per_expert_balanced.max(1));
            cost.ffn_s =
                ffn::device_ffn_time(model, &system.device, &vec![per_expert_tep; experts_local])
                    .min(skewed_ffn)
                    .max(balanced_ffn);
            // Correct predictions skip the shuffle; mispredictions take a
            // correction hop (always the typical model, §3.3).
            cost.scatter_s = balanced_a2a * eps;
            cost.gather_s = balanced_a2a * eps;
            // The decode-phase crux: every step routes brand-new tokens,
            // so the predictor runs — and is paid — every step. Under
            // lookahead overlap the next layer's forecast runs while this
            // layer computes, so the attention window hides the transfer
            // first and then the predictor (ADR 002).
            if p.lookahead_overlap {
                let raw = raw_movement(model, system);
                let (mv, oh, hidden) =
                    moe::overlap_split(raw, overhead_s, p.attention_compute_s);
                cost.movement_s = mv;
                cost.overhead_s = oh;
                cost.hidden_s = hidden;
                if p.speculative_scatter {
                    // ADR 003: the repair scatter for mispredicted tokens
                    // overlaps with the confirmed tiles' FFN compute.
                    let window = cost.ffn_s * (1.0 - eps);
                    let hidden_scatter = cost.scatter_s.min(window);
                    cost.scatter_s -= hidden_scatter;
                    cost.hidden_s += hidden_scatter;
                }
            } else {
                cost.overhead_s = overhead_s;
                // TEP replans per step: movement never amortises.
                cost.movement_s = movement_cost(model, system, p, 1);
            }
        }
    }
    // ADR 004: memory-pressure refetch is exposed for every strategy and
    // every step — the decode working set revisits each layer per token,
    // so a cap below it thrashes the weight cache continuously.
    cost.movement_s += moe::memory_pressure_refetch_s(
        model,
        system,
        p.memory_cap_bytes,
        !matches!(p.strategy, Strategy::NoPrediction),
    );
    // ADR 010: the wavefront hides routing for micro-batches 2..K under
    // the previous micro-batch's FFN slice, for every strategy (see
    // `moe::moe_cost` — same split rule on the decode step's FFN window).
    if p.microbatch > 1 && p.router_compute_s > 0.0 {
        let k = p.microbatch as f64;
        let hidden_per = (p.router_compute_s / k).min(cost.ffn_s / k);
        cost.router_hidden_s = hidden_per * (k - 1.0);
        cost.hidden_s += cost.router_hidden_s;
    }
    // ADR 009 follow-up: measured host copy traffic priced at HBM
    // bandwidth — strategy-independent (one decode row per sequence).
    if p.copied_bytes_per_token > 0.0 {
        cost.host_copy_s =
            p.batch as f64 * p.copied_bytes_per_token / (system.device.mem_bw_gbs * 1e9);
    }
    cost
}

/// Raw expert-movement transfer time (the full once-per-replan move).
fn raw_movement(model: &ModelConfig, system: &SystemSpec) -> f64 {
    collective::p2p_time(&system.interconnect, model.expert_bytes())
}

/// Expert-movement cost not hidden under attention, amortised over the
/// replanning cadence — the blanket assumption; the overlap model prices
/// it explicitly instead (`moe::overlap_split`).
fn movement_cost(
    model: &ModelConfig,
    system: &SystemSpec,
    p: &DecodeParams,
    amortise_steps: usize,
) -> f64 {
    if p.hide_duplication {
        return 0.0;
    }
    let transfer = collective::p2p_time(&system.interconnect, model.expert_bytes());
    (transfer - p.attention_compute_s).max(0.0) / amortise_steps.max(1) as f64
}

/// Decode-step attention for one layer: tiny matvec projections plus a
/// KV-cache sweep that is memory-bandwidth-bound (the decode regime's
/// second memory wall, alongside expert-weight streaming).
pub fn decode_attention_cost(
    model: &ModelConfig,
    system: &SystemSpec,
    batch: usize,
    ctx_len: usize,
) -> AttentionCost {
    let dev = &system.device;
    let n = system.n_devices;
    let dtype = model.dtype;
    let heads_local = (model.n_heads / n).max(1);
    let kv_heads_local = (model.n_kv_heads / n).max(1);
    let q_width = heads_local * model.head_dim;
    let kv_width = 2 * kv_heads_local * model.head_dim;

    let mut cost = AttentionCost::default();
    cost.qkv_proj_s = roofline::gemm_time(dev, batch, q_width + kv_width, model.d_model, dtype);
    cost.rope_s = roofline::rope_time(dev, batch, q_width, dtype);

    // Scores: each new token attends its whole context. Compute is a
    // matvec per head (vector units — no MXU tiles at m=1); memory is the
    // K-cache read. The max of the two is the roofline.
    let score_flops =
        2.0 * batch as f64 * heads_local as f64 * ctx_len as f64 * model.head_dim as f64;
    let k_bytes = batch as f64
        * ctx_len as f64
        * (kv_heads_local * model.head_dim) as f64
        * dtype.bytes() as f64;
    let sweep = |flops: f64, bytes: f64| -> f64 {
        let compute_s = flops / (dev.peak_vector_tflops * 1e12);
        let memory_s = bytes / (dev.mem_bw_gbs * 1e9);
        compute_s.max(memory_s) + dev.kernel_launch_s
    };
    cost.scores_s = sweep(score_flops, k_bytes);
    cost.softmax_s = roofline::softmax_time(dev, batch * heads_local, ctx_len, dtype);
    // PV: identical flop count over the V cache.
    cost.pv_s = sweep(score_flops, k_bytes);
    cost.out_proj_s = roofline::gemm_time(dev, batch, model.d_model, q_width, dtype);

    let bytes = batch as f64 * model.d_model as f64 * dtype.bytes() as f64;
    cost.allreduce_s = super::collective::ring_allreduce_time(&system.interconnect, n, bytes);
    cost
}

/// A configured decode-step simulation (the decode analogue of
/// [`super::LayerSim`]).
#[derive(Clone, Debug)]
pub struct DecodeSim {
    pub model: ModelConfig,
    pub system: SystemSpec,
    /// Concurrently decoding sequences.
    pub batch: usize,
    /// Mean context length.
    pub ctx_len: usize,
    pub error_model: ErrorModel,
    pub hide_duplication: bool,
    pub replan_interval: usize,
    /// Price the lookahead-overlap serving engine (ADR 002).
    pub lookahead_overlap: bool,
    /// Price the speculative TEP scatter on top of overlap (ADR 003).
    pub speculative_scatter: bool,
    /// Price the constrained-HBM regime (ADR 004).
    pub memory_cap_bytes: Option<f64>,
    /// Price proactive replanning at this forecast horizon (ADR 006).
    pub forecast_horizon: usize,
    /// Per-window forecast drift override (ADR 006); `None` = default.
    pub forecast_drift: Option<f64>,
    /// Price the micro-batch wavefront at this depth (ADR 010; 1 = serial).
    pub microbatch: usize,
    /// Measured data-plane copy bytes per token (ADR 009; 0 = unmeasured).
    pub copied_bytes_per_token: f64,
}

impl DecodeSim {
    /// Default decode setting: a 16-sequence continuous batch at context
    /// 512 (the prefill figures' sequence length, now as KV depth).
    pub fn new(model: ModelConfig, system: SystemSpec) -> DecodeSim {
        DecodeSim {
            model,
            system,
            batch: 16,
            ctx_len: 512,
            error_model: ErrorModel::Typical,
            hide_duplication: true,
            replan_interval: 1,
            lookahead_overlap: false,
            speculative_scatter: false,
            memory_cap_bytes: None,
            forecast_horizon: 0,
            forecast_drift: None,
            microbatch: 1,
            copied_bytes_per_token: 0.0,
        }
    }

    pub fn with_workload(mut self, batch: usize, ctx_len: usize) -> DecodeSim {
        self.batch = batch;
        self.ctx_len = ctx_len;
        self
    }

    pub fn with_overlap(mut self, on: bool) -> DecodeSim {
        self.lookahead_overlap = on;
        self
    }

    pub fn with_speculative(mut self, on: bool) -> DecodeSim {
        self.speculative_scatter = on;
        self
    }

    pub fn with_memory_cap(mut self, cap_bytes: Option<f64>) -> DecodeSim {
        self.memory_cap_bytes = cap_bytes;
        self
    }

    /// Price proactive replanning at forecast horizon `h` (ADR 006);
    /// `drift` overrides the default per-window forecast drift.
    pub fn with_horizon(mut self, h: usize, drift: Option<f64>) -> DecodeSim {
        self.forecast_horizon = h;
        self.forecast_drift = drift;
        self
    }

    /// Price the micro-batch wavefront at depth `k` (ADR 010; 0/1 =
    /// serial — no routing hides).
    pub fn with_microbatch(mut self, k: usize) -> DecodeSim {
        self.microbatch = k.max(1);
        self
    }

    /// Price the measured data-plane copy traffic (ADR 009 follow-up).
    pub fn with_copied_bytes(mut self, bytes: f64) -> DecodeSim {
        self.copied_bytes_per_token = bytes.max(0.0);
        self
    }

    pub fn attention(&self) -> AttentionCost {
        decode_attention_cost(&self.model, &self.system, self.batch, self.ctx_len)
    }

    /// Router on the step's new tokens only.
    pub fn router_time(&self) -> f64 {
        let gemm = roofline::gemm_time(
            &self.system.device,
            self.batch,
            self.model.n_experts,
            self.model.d_model,
            self.model.dtype,
        );
        let topk = roofline::elementwise_time(
            &self.system.device,
            self.batch * self.model.n_experts,
            3.0,
            1,
            self.model.dtype,
        );
        gemm + topk
    }

    fn moe(&self, skewness: f64, strategy: Strategy, attention_compute_s: f64) -> MoeCost {
        let mut p = DecodeParams::new(self.batch, self.ctx_len, skewness, strategy);
        p.error_model = self.error_model;
        p.hide_duplication = self.hide_duplication;
        p.attention_compute_s = attention_compute_s;
        p.replan_interval = self.replan_interval;
        p.lookahead_overlap = self.lookahead_overlap;
        p.speculative_scatter = self.speculative_scatter;
        p.memory_cap_bytes = self.memory_cap_bytes;
        p.forecast_horizon = self.forecast_horizon;
        p.forecast_drift = self.forecast_drift;
        p.microbatch = self.microbatch;
        p.router_compute_s = self.router_time();
        p.copied_bytes_per_token = self.copied_bytes_per_token;
        decode_moe_cost(&self.model, &self.system, &p)
    }

    /// Per-layer breakdown of one decode step. `overhead_s` is the
    /// whole-step predictor cost (the TEP predictor emits all layers'
    /// predictions in one pass, §3.1) — [`Self::step_total`] counts it
    /// once, not per layer.
    pub fn step_breakdown(&self, skewness: f64, strategy: Strategy) -> LayerBreakdown {
        let attn = self.attention();
        let moe = self.moe(skewness, strategy, attn.compute());
        LayerBreakdown {
            attention_s: attn.compute(),
            allreduce_s: attn.allreduce_s,
            // ADR 010: charge only the routing the wavefront left exposed.
            router_s: (self.router_time() - moe.router_hidden_s).max(0.0),
            ffn_s: moe.ffn_s,
            scatter_s: moe.scatter_s,
            gather_s: moe.gather_s,
            overhead_s: moe.overhead_s,
            movement_s: moe.movement_s,
            hidden_s: moe.hidden_s,
            host_copy_s: moe.host_copy_s,
        }
    }

    /// Full-step latency: all layers, predictor overhead charged once.
    pub fn step_total(&self, skewness: f64, strategy: Strategy) -> f64 {
        let b = self.step_breakdown(skewness, strategy);
        (b.total() - b.overhead_s) * self.model.n_layers as f64 + b.overhead_s
    }

    pub fn baseline_step(&self, skewness: f64) -> f64 {
        self.step_total(skewness, Strategy::NoPrediction)
    }

    /// Steady-state decode throughput (tokens/s) for the whole model.
    pub fn tokens_per_s(&self, skewness: f64, strategy: Strategy) -> f64 {
        self.batch as f64 / self.step_total(skewness, strategy)
    }

    /// baseline_step / step (≥ 1 means the strategy helps).
    pub fn normalized_performance(&self, skewness: f64, strategy: Strategy) -> f64 {
        self.baseline_step(skewness) / self.step_total(skewness, strategy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{LayerSim, SystemSpec};

    fn mixtral_nvlink() -> (ModelConfig, SystemSpec) {
        (ModelConfig::mixtral_8x7b(), SystemSpec::four_a100_nvlink())
    }

    #[test]
    fn decode_ffn_is_memory_bound_flat_in_skew() {
        let (m, s) = mixtral_nvlink();
        let at = |skew| {
            decode_moe_cost(
                &m,
                &s,
                &DecodeParams::new(16, 512, skew, Strategy::NoPrediction),
            )
        };
        let flat_ratio = at(2.0).ffn_s / at(1.0).ffn_s;
        assert!(
            flat_ratio < 1.3,
            "decode FFN should be ~flat in skew (weight streaming dominates), got {flat_ratio}"
        );
        // Prefill contrast: the same skew doubles the compute-bound FFN.
        let sim = LayerSim::new(m, s);
        let p1 = sim.breakdown(1.0, Strategy::NoPrediction).ffn_s;
        let p2 = sim.breakdown(2.0, Strategy::NoPrediction).ffn_s;
        assert!((p2 / p1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_comm_still_scales_with_skew() {
        let (m, s) = mixtral_nvlink();
        let at = |skew| {
            decode_moe_cost(
                &m,
                &s,
                &DecodeParams::new(16, 512, skew, Strategy::NoPrediction),
            )
        };
        assert!(at(3.0).comm_s() > at(1.0).comm_s() * 1.5);
    }

    #[test]
    fn tep_overhead_charged_every_step_regardless_of_cadence() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-3,
        };
        let mut p = DecodeParams::new(16, 512, 1.4, strategy);
        let every_step = decode_moe_cost(&m, &s, &p).overhead_s;
        p.replan_interval = 32;
        let with_cadence = decode_moe_cost(&m, &s, &p).overhead_s;
        assert_eq!(every_step, with_cadence, "prediction cannot amortise in decode");
        assert_eq!(every_step, 1e-3);
    }

    #[test]
    fn dop_movement_amortises_with_replan_cadence() {
        let (m, s) = mixtral_nvlink();
        let mut p = DecodeParams::new(
            16,
            512,
            1.4,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        p.hide_duplication = false;
        p.attention_compute_s = 0.0;
        let per_step = decode_moe_cost(&m, &s, &p).movement_s;
        assert!(per_step > 0.0);
        p.replan_interval = 8;
        let amortised = decode_moe_cost(&m, &s, &p).movement_s;
        assert!((per_step / amortised - 8.0).abs() < 1e-9);
    }

    #[test]
    fn lookahead_overlap_softens_decode_tep_overhead() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-3,
        };
        let mut p = DecodeParams::new(16, 512, 1.4, strategy);
        p.attention_compute_s = 1.0; // window larger than transfer + predict
        let plain = decode_moe_cost(&m, &s, &p);
        assert_eq!(plain.overhead_s, 1e-3);
        p.lookahead_overlap = true;
        let overlapped = decode_moe_cost(&m, &s, &p);
        assert_eq!(overlapped.overhead_s, 0.0, "overhead hidden under the window");
        assert!(overlapped.hidden_s >= 1e-3);
        assert!(overlapped.total() < plain.total());
        // DOP under overlap: cadence-amortised transfer hides too.
        let mut pd = DecodeParams::new(
            16,
            512,
            1.4,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        pd.attention_compute_s = 1.0;
        pd.replan_interval = 8;
        pd.lookahead_overlap = true;
        let dop = decode_moe_cost(&m, &s, &pd);
        assert_eq!(dop.movement_s, 0.0);
        assert!(dop.hidden_s > 0.0);
    }

    #[test]
    fn speculative_scatter_softens_decode_tep_repair() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-4,
        };
        let mut p = DecodeParams::new(16, 512, 2.0, strategy);
        p.lookahead_overlap = true;
        p.attention_compute_s = 1e-3;
        let plain = decode_moe_cost(&m, &s, &p);
        p.speculative_scatter = true;
        let spec = decode_moe_cost(&m, &s, &p);
        assert!(spec.scatter_s < plain.scatter_s);
        let moved = plain.scatter_s - spec.scatter_s;
        assert!((spec.hidden_s - plain.hidden_s - moved).abs() < 1e-15);
        assert_eq!(spec.gather_s, plain.gather_s);
        assert!(spec.total() < plain.total());
        // Sim plumbing: the builder prices it the same way.
        let base = DecodeSim::new(m.clone(), s.clone()).with_overlap(true);
        let spec_sim = DecodeSim::new(m, s).with_overlap(true).with_speculative(true);
        assert!(spec_sim.step_total(2.0, strategy) <= base.step_total(2.0, strategy));
    }

    #[test]
    fn decode_forecast_horizon_prewarms_dop_and_prices_staleness() {
        let (m, s) = mixtral_nvlink();
        let mut p = DecodeParams::new(
            16,
            512,
            2.0,
            Strategy::DistributionOnly { error_rate: 0.02 },
        );
        p.hide_duplication = false;
        p.attention_compute_s = 0.0;
        p.replan_interval = 8;
        let reactive = decode_moe_cost(&m, &s, &p);
        assert!(reactive.movement_s > 0.0);
        p.forecast_horizon = 4;
        let proactive = decode_moe_cost(&m, &s, &p);
        // Prewarmed before the boundary, still cadence-amortised.
        assert_eq!(proactive.movement_s, 0.0);
        assert!((proactive.hidden_s - reactive.movement_s).abs() < 1e-12);
        // Staleness can only inflate the (memory-bound, so often flat)
        // FFN term — never shrink it.
        assert!(proactive.ffn_s >= reactive.ffn_s);
        // Perfect forecast (drift 0): strictly a win under the ablation.
        p.forecast_drift = Some(0.0);
        let perfect = decode_moe_cost(&m, &s, &p);
        assert_eq!(perfect.ffn_s, reactive.ffn_s);
        assert!(perfect.total() < reactive.total());
        // TEP is untouched by the horizon knob.
        let strategy = Strategy::TokenToExpert {
            accuracy: 0.9,
            overhead_s: 1e-4,
        };
        let mut pt = DecodeParams::new(16, 512, 2.0, strategy);
        let plain = decode_moe_cost(&m, &s, &pt);
        pt.forecast_horizon = 4;
        pt.forecast_drift = Some(0.1);
        assert_eq!(decode_moe_cost(&m, &s, &pt), plain);
        // Sim plumbing: the builder threads the knob through.
        let strategy = Strategy::DistributionOnly { error_rate: 0.02 };
        let mut base = DecodeSim::new(m.clone(), s.clone());
        base.hide_duplication = false;
        let mut proactive_sim = DecodeSim::new(m, s).with_horizon(4, Some(0.0));
        proactive_sim.hide_duplication = false;
        assert!(
            proactive_sim.step_total(2.0, strategy) <= base.step_total(2.0, strategy) + 1e-15
        );
    }

    #[test]
    fn decode_sim_overlap_never_slower_than_exposed_ablation() {
        // The fair comparison for the explicit overlap model is the
        // explicit *exposed* ablation (hide_duplication = false), not the
        // paper's blanket everything-hides assumption: overlap hides the
        // same transfer window plus the predictor, so it can only help.
        let (m, s) = mixtral_nvlink();
        let mut base = DecodeSim::new(m.clone(), s.clone());
        base.hide_duplication = false;
        let over = DecodeSim::new(m, s).with_overlap(true);
        for strategy in [
            Strategy::NoPrediction,
            Strategy::DistributionOnly { error_rate: 0.02 },
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: 1e-4,
            },
        ] {
            let a = base.step_total(1.4, strategy);
            let b = over.step_total(1.4, strategy);
            assert!(
                b <= a + 1e-12,
                "overlap must never price slower than exposed: {a} vs {b} ({strategy:?})"
            );
        }
    }

    #[test]
    fn decode_memory_cap_charges_every_strategy_dup_most() {
        let (m, s) = mixtral_nvlink();
        let base_needed =
            m.n_layers as f64 * (m.n_experts as f64 / s.n_devices as f64) * m.expert_bytes();
        let cap = Some(base_needed * 0.5);
        let cost_at = |strategy: Strategy, cap: Option<f64>| {
            let mut p = DecodeParams::new(16, 512, 2.0, strategy);
            p.memory_cap_bytes = cap;
            decode_moe_cost(&m, &s, &p)
        };
        let base = cost_at(Strategy::NoPrediction, cap);
        let base_free = cost_at(Strategy::NoPrediction, None);
        assert!(base.movement_s > 0.0, "tight cap charges the baseline too");
        assert_eq!(base_free.movement_s, 0.0);
        let dop = cost_at(Strategy::DistributionOnly { error_rate: 0.02 }, cap);
        assert!(
            dop.movement_s > base.movement_s,
            "the duplicated replica must cost extra under pressure"
        );
        // Sim plumbing: the builder prices the cap identically.
        let capped = DecodeSim::new(m.clone(), s.clone()).with_memory_cap(cap);
        let free = DecodeSim::new(m, s);
        let strategy = Strategy::DistributionOnly { error_rate: 0.02 };
        assert!(capped.step_total(2.0, strategy) > free.step_total(2.0, strategy));
    }

    #[test]
    fn decode_microbatch_and_copied_bytes_builders_price_the_step() {
        let (m, s) = mixtral_nvlink();
        let strategy = Strategy::NoPrediction;
        let serial = DecodeSim::new(m.clone(), s.clone());
        let wave = DecodeSim::new(m.clone(), s.clone()).with_microbatch(4);
        // K=1 is an exact no-op; K=4 hides part of the per-step routing.
        assert_eq!(
            serial.step_total(2.0, strategy),
            DecodeSim::new(m.clone(), s.clone())
                .with_microbatch(1)
                .step_total(2.0, strategy)
        );
        let sb = serial.step_breakdown(2.0, strategy);
        let wb = wave.step_breakdown(2.0, strategy);
        assert!(wb.router_s < sb.router_s);
        assert_eq!(wb.ffn_s, sb.ffn_s);
        assert!(wave.step_total(2.0, strategy) < serial.step_total(2.0, strategy));
        // Measured copy traffic adds a host term, identically per strategy.
        let priced = DecodeSim::new(m, s).with_copied_bytes(4096.0 * 4.0);
        let pb = priced.step_breakdown(2.0, strategy);
        assert!(pb.host_copy_s > 0.0);
        assert!((pb.total() - sb.total() - pb.host_copy_s).abs() < 1e-15);
    }

    #[test]
    fn decode_attention_memory_bound_in_context() {
        let (m, s) = mixtral_nvlink();
        let short = decode_attention_cost(&m, &s, 16, 256);
        let long = decode_attention_cost(&m, &s, 16, 4096);
        // KV sweep grows ~linearly with context (sublinear only through
        // the fixed kernel-launch term).
        assert!(long.scores_s > short.scores_s * 4.0);
        // Projections do not depend on context.
        assert!((long.qkv_proj_s - short.qkv_proj_s).abs() < 1e-12);
    }

    #[test]
    fn step_total_counts_overhead_once() {
        let (m, s) = mixtral_nvlink();
        let sim = DecodeSim::new(m.clone(), s);
        let overhead = 5e-3;
        let with = sim.step_total(
            1.4,
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: overhead,
            },
        );
        let without = sim.step_total(
            1.4,
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: 0.0,
            },
        );
        assert!(((with - without) - overhead).abs() < 1e-12);
    }

    #[test]
    fn dop_normalized_perf_at_least_one_in_decode() {
        let (m, s) = mixtral_nvlink();
        let sim = DecodeSim::new(m, s);
        let perf = sim.normalized_performance(
            1.4,
            Strategy::DistributionOnly { error_rate: 0.018 },
        );
        assert!(perf >= 1.0 - 1e-9, "perf={perf}");
    }

    #[test]
    fn tokens_per_s_sane_magnitude() {
        let (m, s) = mixtral_nvlink();
        let sim = DecodeSim::new(m, s);
        let tps = sim.tokens_per_s(1.4, Strategy::NoPrediction);
        // 16 sequences on 4×A100 Mixtral: order 10–10k tok/s.
        assert!(tps > 10.0 && tps < 100_000.0, "tps={tps}");
    }
}
