//! LLMCompass-like block-level performance simulator.
//!
//! The paper evaluates everything through an augmented LLMCompass [36]: a
//! throughput-oriented analytical simulator that prices each transformer
//! block operation (GEMM, elementwise, softmax, communication) on a
//! parametric hardware description and sums a per-layer latency breakdown.
//! This module is our rust reimplementation of the slice of LLMCompass the
//! paper uses, plus the paper's own extensions (§3.4):
//!
//! * MoE + Expert Parallelism: EP-specific all-to-all communication and
//!   skew-scaled expert FFN workloads ([`moe`]).
//! * Mixtral support: Grouped-Query Attention, SwiGLU, sliding-window
//!   attention ([`attention`], [`ffn`]).
//! * Prediction-strategy modeling: Distribution-Only and Token-to-Expert
//!   with tunable accuracy and overhead, and the optimistic / typical /
//!   pessimistic error-distribution scenarios of Figure 5 ([`error_model`],
//!   [`moe`]).
//!
//! The simulator is *analytical*: `simulate` functions return seconds, not
//! samples. Fidelity target (DESIGN.md §5): relative behaviour — breakdown
//! shape, crossover points, who-wins — not absolute A100 milliseconds.

pub mod attention;
pub mod collective;
pub mod decode;
pub mod error_model;
pub mod ffn;
pub mod hardware;
pub mod layer;
pub mod moe;
pub mod roofline;

pub use decode::{DecodeParams, DecodeSim};
pub use error_model::ErrorModel;
pub use hardware::{DeviceSpec, InterconnectSpec, SystemSpec};
pub use layer::{LayerBreakdown, LayerSim};
