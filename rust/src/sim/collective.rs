//! Communication cost models for the collectives the paper's pipeline uses.
//!
//! Topology assumption (paper §2/§5): fully-connected GPUs with identical
//! per-link bandwidth. Formulas:
//!
//! * **Ring all-reduce** (after TP attention, [23]):
//!   `2 (N−1)/N · bytes / bw` plus per-step latency.
//! * **EP all-to-all scatter** (token shuffle to expert GPUs): with a
//!   balanced random distribution each GPU moves `(N−1)/N` of its `T/N`
//!   tokens → `(N−1)/N² · T` per GPU; the GPU hosting the most popular
//!   expert receives `skewness ×` that, and bottlenecks the phase:
//!   `(N−1) · skew / N² · T · bytes_per_token / bw`. The same volume moves
//!   back in the post-FFN gather.
//! * **Point-to-point expert transfer** (dynamic duplication, §5):
//!   `expert_bytes / bw + latency`.

use super::hardware::InterconnectSpec;

/// Contention factor for collectives: on a shared fabric (PCIe through the
/// host root complex) the N concurrent per-GPU flows serialise, so
/// effective per-flow bandwidth is `link_bw / N`.
fn contention(ic: &InterconnectSpec, n: usize) -> f64 {
    if ic.shared {
        n as f64
    } else {
        1.0
    }
}

/// Ring all-reduce of `bytes` over `n` devices.
pub fn ring_allreduce_time(ic: &InterconnectSpec, n: usize, bytes: f64) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let transfer = 2.0 * (n as f64 - 1.0) / n as f64 * bytes * contention(ic, n)
        / (ic.link_bw_gbs * 1e9);
    transfer + steps as f64 * ic.latency_s
}

/// EP all-to-all token shuffle (scatter **or** gather — the paper prices them
/// identically): `total_tokens` tokens of `bytes_per_token` across `n`
/// devices, with the receiving hot GPU scaled by `skewness ≥ 1`.
pub fn ep_all_to_all_time(
    ic: &InterconnectSpec,
    n: usize,
    total_tokens: f64,
    bytes_per_token: f64,
    skewness: f64,
) -> f64 {
    if n <= 1 || total_tokens <= 0.0 {
        return 0.0;
    }
    debug_assert!(skewness >= 1.0 - 1e-9, "skewness must be >= 1, got {skewness}");
    let bottleneck_tokens = (n as f64 - 1.0) * skewness / (n as f64).powi(2) * total_tokens;
    bottleneck_tokens * bytes_per_token * contention(ic, n) / (ic.link_bw_gbs * 1e9)
        + (n - 1) as f64 * ic.latency_s
}

/// Tree all-reduce of `bytes` over `n` devices (paper §5 lists Tree among
/// the alternative topologies; it trades the ring's bandwidth-optimality
/// for ~log(n) latency steps — better for small payloads, worse for large).
pub fn tree_allreduce_time(ic: &InterconnectSpec, n: usize, bytes: f64) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let levels = (n as f64).log2().ceil() as usize;
    // Reduce up + broadcast down: each level moves the full payload once.
    let transfer =
        2.0 * levels as f64 * bytes * contention(ic, n) / (ic.link_bw_gbs * 1e9);
    transfer + 2.0 * levels as f64 * ic.latency_s
}

/// 2-D mesh all-to-all (paper §5's Mesh/Torus discussion): without full
/// connectivity each token crosses ~√N hops on average, multiplying the
/// bandwidth term relative to the fully-connected case.
pub fn mesh_all_to_all_time(
    ic: &InterconnectSpec,
    n: usize,
    total_tokens: f64,
    bytes_per_token: f64,
    skewness: f64,
) -> f64 {
    let hops = (n as f64).sqrt();
    let base = ep_all_to_all_time(ic, n, total_tokens, bytes_per_token, skewness);
    let latency = (n - 1) as f64 * ic.latency_s;
    (base - latency) * hops + latency * hops
}

/// Point-to-point transfer of one expert's weights (dynamic duplication).
/// Uses the striped p2p bandwidth; movements are staggered across the layer
/// pipeline, so no contention factor applies (paper §5 arithmetic).
pub fn p2p_time(ic: &InterconnectSpec, bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    bytes / (ic.p2p_bw_gbs * 1e9) + ic.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hardware::InterconnectSpec;

    #[test]
    fn allreduce_matches_closed_form() {
        let ic = InterconnectSpec {
            name: "t".into(),
            link_bw_gbs: 100.0,
            p2p_bw_gbs: 100.0,
            latency_s: 0.0,
            shared: false,
        };
        // 4 GPUs, 1 GB: 2*(3/4)*1GB / 100GB/s = 15 ms.
        let t = ring_allreduce_time(&ic, 4, 1e9);
        assert!((t - 0.015).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn allreduce_trivial_cases() {
        let ic = InterconnectSpec::nvlink3();
        assert_eq!(ring_allreduce_time(&ic, 1, 1e9), 0.0);
        assert_eq!(ring_allreduce_time(&ic, 4, 0.0), 0.0);
    }

    #[test]
    fn ep_scatter_matches_paper_formula() {
        let ic = InterconnectSpec {
            name: "t".into(),
            link_bw_gbs: 100.0,
            p2p_bw_gbs: 100.0,
            latency_s: 0.0,
            shared: false,
        };
        // N=4, T=1024 tokens, 1 MB/token, skew=1:
        // (3/16)*1024 tokens * 1e6 B / 100e9 B/s = 1.92 ms.
        let t = ep_all_to_all_time(&ic, 4, 1024.0, 1e6, 1.0);
        assert!((t - 1.92e-3).abs() < 1e-9, "t={t}");
        // Skew 3 triples it (paper Figure 2 example).
        let t3 = ep_all_to_all_time(&ic, 4, 1024.0, 1e6, 3.0);
        assert!((t3 / t - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ep_scatter_latency_term() {
        let ic = InterconnectSpec {
            name: "t".into(),
            link_bw_gbs: 1e9, // effectively infinite bandwidth
            p2p_bw_gbs: 1e9,
            latency_s: 1e-6,
            shared: false,
        };
        let t = ep_all_to_all_time(&ic, 4, 1.0, 1.0, 1.0);
        assert!((t - 3e-6).abs() < 1e-9);
    }

    #[test]
    fn p2p_expert_transfer_mixtral_example() {
        // Paper §5: one Mixtral expert ≈ 4096*14336*2*2 bytes over NVLink
        // at the 2 TB/s striped p2p bandwidth ≈ 0.1 ms.
        let bytes = 4096.0 * 14336.0 * 2.0 * 2.0;
        let t = p2p_time(&InterconnectSpec::nvlink3(), bytes);
        assert!(t > 0.8e-4 && t < 1.5e-4, "t={t}");
    }

    #[test]
    fn tree_beats_ring_for_tiny_payloads_loses_for_large() {
        let ic = InterconnectSpec::nvlink3();
        // Tiny payload: latency-dominated → tree's 2·log2(4)=4 steps beat
        // the ring's 2·(4−1)=6 steps.
        assert!(tree_allreduce_time(&ic, 4, 64.0) < ring_allreduce_time(&ic, 4, 64.0));
        // Large payload: bandwidth-dominated → ring's (N−1)/N factor wins
        // over the tree's log2(N) full-payload hops.
        assert!(tree_allreduce_time(&ic, 4, 1e9) > ring_allreduce_time(&ic, 4, 1e9));
    }

    #[test]
    fn mesh_all_to_all_pays_hop_factor() {
        let ic = InterconnectSpec::nvlink3();
        let full = ep_all_to_all_time(&ic, 16, 4096.0, 8192.0, 1.5);
        let mesh = mesh_all_to_all_time(&ic, 16, 4096.0, 8192.0, 1.5);
        assert!(mesh > full * 2.0, "mesh={mesh} full={full}");
    }

    #[test]
    fn pcie_much_slower_than_nvlink() {
        let nv = InterconnectSpec::nvlink3();
        let pcie = InterconnectSpec::pcie4();
        let t_nv = ep_all_to_all_time(&nv, 4, 512.0, 8192.0, 1.4);
        let t_pcie = ep_all_to_all_time(&pcie, 4, 512.0, 8192.0, 1.4);
        // PCIe is both ~19x slower per link and shared (x4 contention).
        assert!(t_pcie / t_nv > 10.0, "ratio={}", t_pcie / t_nv);
    }
}
