//! Prediction-error distribution scenarios (paper §3.3, Figure 5).
//!
//! For a prediction error rate ε (= 1 − accuracy for Token-to-Expert, or the
//! normalised L1 distribution distance for Distribution-Only), the effect on
//! the post-duplication FFN load depends on *where* the errors land:
//!
//! * **Optimistic** — errors happen to preserve perfect balance (e.g.
//!   predicting 85% instead of 75% for an already-duplicated expert):
//!   bottleneck load = `avg_tokens`.
//! * **Typical** — errors are uniformly distributed across GPUs: bottleneck
//!   load = `(1 + ε) · avg_tokens`. This is the paper's default and ours.
//! * **Pessimistic** — all errors concentrate on one GPU: bottleneck load =
//!   `N · (1 + ε) · avg_tokens` — an upper bound on degradation.

/// Error-distribution scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ErrorModel {
    Optimistic,
    #[default]
    Typical,
    Pessimistic,
}

impl ErrorModel {
    /// Multiplier on the *balanced* bottleneck FFN load for error rate
    /// `epsilon ∈ [0, 1]` on an `n`-device system.
    pub fn load_multiplier(self, epsilon: f64, n: usize) -> f64 {
        let eps = epsilon.clamp(0.0, 1.0);
        match self {
            ErrorModel::Optimistic => 1.0,
            ErrorModel::Typical => 1.0 + eps,
            ErrorModel::Pessimistic => n as f64 * (1.0 + eps),
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<ErrorModel> {
        match name.to_ascii_lowercase().as_str() {
            "optimistic" => Ok(ErrorModel::Optimistic),
            "typical" => Ok(ErrorModel::Typical),
            "pessimistic" => Ok(ErrorModel::Pessimistic),
            other => anyhow::bail!("unknown error model `{other}`"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorModel::Optimistic => "optimistic",
            ErrorModel::Typical => "typical",
            ErrorModel::Pessimistic => "pessimistic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multipliers_match_paper() {
        let eps = 0.1;
        assert_eq!(ErrorModel::Optimistic.load_multiplier(eps, 4), 1.0);
        assert!((ErrorModel::Typical.load_multiplier(eps, 4) - 1.1).abs() < 1e-12);
        assert!((ErrorModel::Pessimistic.load_multiplier(eps, 4) - 4.4).abs() < 1e-12);
    }

    #[test]
    fn epsilon_is_clamped() {
        assert_eq!(ErrorModel::Typical.load_multiplier(-0.5, 4), 1.0);
        assert_eq!(ErrorModel::Typical.load_multiplier(2.0, 4), 2.0);
    }

    #[test]
    fn ordering_optimistic_typical_pessimistic() {
        for &eps in &[0.0, 0.05, 0.3, 1.0] {
            let o = ErrorModel::Optimistic.load_multiplier(eps, 4);
            let t = ErrorModel::Typical.load_multiplier(eps, 4);
            let p = ErrorModel::Pessimistic.load_multiplier(eps, 4);
            assert!(o <= t && t <= p);
        }
    }

    #[test]
    fn names_round_trip() {
        for m in [
            ErrorModel::Optimistic,
            ErrorModel::Typical,
            ErrorModel::Pessimistic,
        ] {
            assert_eq!(ErrorModel::by_name(m.name()).unwrap(), m);
        }
        assert!(ErrorModel::by_name("bogus").is_err());
    }
}
