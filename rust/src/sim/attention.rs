//! Attention cost model (prefill) under Tensor Parallelism.
//!
//! The paper runs Attention with TP across all N devices (§2): heads are
//! split N ways, each device computes its shard, and a ring all-reduce
//! combines the output projections. Supports MHA, GQA (Mixtral), MLA
//! (DeepSeek discussion) and sliding-window attention (Mixtral's 4K window).
//! LLMCompass does not model FlashAttention, so — like the paper — the
//! score/softmax/PV phases are priced as materialised operations
//! ("conservatively overestimated", §3.4).

use super::hardware::{DeviceSpec, SystemSpec};
use super::roofline;
use crate::model::{AttentionKind, ModelConfig};

/// Per-phase attention latency breakdown for one layer on one device shard
/// (the slowest shard — shards are symmetric under TP).
#[derive(Clone, Debug, Default)]
pub struct AttentionCost {
    pub qkv_proj_s: f64,
    pub rope_s: f64,
    pub scores_s: f64,
    pub softmax_s: f64,
    pub pv_s: f64,
    pub out_proj_s: f64,
    pub allreduce_s: f64,
}

impl AttentionCost {
    /// Total attention-phase latency (compute + TP all-reduce).
    pub fn total(&self) -> f64 {
        self.compute() + self.allreduce_s
    }

    /// Compute-only portion (used by the duplication-hiding analysis, §5).
    pub fn compute(&self) -> f64 {
        self.qkv_proj_s
            + self.rope_s
            + self.scores_s
            + self.softmax_s
            + self.pv_s
            + self.out_proj_s
    }
}

/// Average attended key length per query token for causal attention with an
/// optional sliding window: token `i` attends `min(i+1, window)` keys.
pub fn avg_attended_len(seq: usize, window: Option<usize>) -> f64 {
    if seq == 0 {
        return 0.0;
    }
    let w = window.unwrap_or(usize::MAX);
    let mut total: u64 = 0;
    // Closed form: sum over i in 1..=seq of min(i, w)
    //   = w*(w+1)/2 + (seq-w)*w when seq > w, else seq*(seq+1)/2.
    if seq <= w {
        total += (seq as u64 * (seq as u64 + 1)) / 2;
    } else {
        total += (w as u64 * (w as u64 + 1)) / 2;
        total += ((seq - w) as u64) * w as u64;
    }
    total as f64 / seq as f64
}

/// Price one layer's attention phase for `batch × seq` tokens on `system`
/// (TP over all devices).
pub fn attention_cost(
    model: &ModelConfig,
    system: &SystemSpec,
    batch: usize,
    seq: usize,
) -> AttentionCost {
    let dev = &system.device;
    let n = system.n_devices;
    let tokens = batch * seq;
    let dtype = model.dtype;

    // TP splits query heads evenly; KV heads are split as far as possible
    // (GQA shards KV when n_kv_heads >= n, replicates otherwise).
    let heads_local = div_at_least_one(model.n_heads, n);
    let kv_heads_local = div_at_least_one(model.n_kv_heads, n);
    let q_width = heads_local * model.head_dim;

    let mut cost = AttentionCost::default();

    match model.attention {
        AttentionKind::Mha | AttentionKind::Gqa => {
            let kv_width = 2 * kv_heads_local * model.head_dim;
            cost.qkv_proj_s =
                roofline::gemm_time(dev, tokens, q_width + kv_width, model.d_model, dtype);
        }
        AttentionKind::Mla => {
            // Query proj + joint KV down-projection to the latent rank +
            // up-projection back to per-head keys/values.
            let rank = model.mla_kv_rank.max(1);
            cost.qkv_proj_s = roofline::gemm_time(dev, tokens, q_width, model.d_model, dtype)
                + roofline::gemm_time(dev, tokens, rank, model.d_model, dtype)
                + roofline::gemm_time(dev, tokens, 2 * kv_heads_local * model.head_dim, rank, dtype);
        }
    }

    cost.rope_s = roofline::rope_time(dev, tokens, q_width, dtype);

    // Scores + PV: per local head, per query token, attend `attended` keys.
    let attended = avg_attended_len(seq, model.sliding_window);
    let score_flops =
        2.0 * batch as f64 * heads_local as f64 * seq as f64 * attended * model.head_dim as f64;
    cost.scores_s = matrix_flops_time(dev, score_flops, seq, attended, model.head_dim);
    cost.softmax_s = roofline::softmax_time(
        dev,
        batch * heads_local * seq,
        attended.ceil() as usize,
        dtype,
    );
    cost.pv_s = cost.scores_s; // PV has identical flop count and shape class.

    cost.out_proj_s = roofline::gemm_time(dev, tokens, model.d_model, q_width, dtype);

    // Ring all-reduce of the output activations across the TP group.
    let bytes = tokens as f64 * model.d_model as f64 * dtype.bytes() as f64;
    cost.allreduce_s = super::collective::ring_allreduce_time(&system.interconnect, n, bytes);

    cost
}

/// Price `flops` of batched attention matmul with utilisation derived from
/// its effective GEMM shape (seq × attended × head_dim).
fn matrix_flops_time(dev: &DeviceSpec, flops: f64, m: usize, n_f: f64, k: usize) -> f64 {
    if flops <= 0.0 {
        return 0.0;
    }
    let util = roofline::gemm_utilization(m, n_f.ceil().max(1.0) as usize, k);
    flops / (dev.peak_matrix_tflops * 1e12 * util) + dev.kernel_launch_s
}

fn div_at_least_one(a: usize, b: usize) -> usize {
    (a / b).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_attended_without_window_is_half() {
        // Causal: mean of 1..=L = (L+1)/2.
        assert_eq!(avg_attended_len(512, None), 256.5);
        assert_eq!(avg_attended_len(0, None), 0.0);
    }

    #[test]
    fn avg_attended_with_window_saturates() {
        // window ≥ seq: same as causal.
        assert_eq!(avg_attended_len(512, Some(4096)), 256.5);
        // tiny window: approaches the window size.
        let v = avg_attended_len(8192, Some(64));
        assert!(v < 64.0 && v > 63.0, "v={v}");
    }

    #[test]
    fn mixtral_attention_breakdown_positive() {
        let m = ModelConfig::mixtral_8x7b();
        let sys = crate::sim::SystemSpec::four_a100_nvlink();
        let c = attention_cost(&m, &sys, 1, 512);
        assert!(c.qkv_proj_s > 0.0);
        assert!(c.scores_s > 0.0);
        assert!(c.softmax_s > 0.0);
        assert!(c.out_proj_s > 0.0);
        assert!(c.allreduce_s > 0.0);
        assert!(c.total() > c.compute());
        // Sanity: single-layer prefill attention at bs=1/seq=512 should be
        // sub-millisecond-to-few-ms on 4×A100.
        assert!(c.total() > 10e-6 && c.total() < 20e-3, "total={}", c.total());
    }

    #[test]
    fn sliding_window_reduces_long_seq_cost() {
        let mut m = ModelConfig::mixtral_8x7b();
        let sys = crate::sim::SystemSpec::four_a100_nvlink();
        m.sliding_window = None;
        let full = attention_cost(&m, &sys, 1, 16384);
        m.sliding_window = Some(4096);
        let windowed = attention_cost(&m, &sys, 1, 16384);
        assert!(windowed.scores_s < full.scores_s * 0.6);
    }

    #[test]
    fn gqa_cheaper_than_mha_on_qkv() {
        let sys = crate::sim::SystemSpec::four_a100_nvlink();
        let gqa = ModelConfig::mixtral_8x7b(); // 32q/8kv
        let mut mha = gqa.clone();
        mha.n_kv_heads = 32;
        let c_gqa = attention_cost(&gqa, &sys, 1, 512);
        let c_mha = attention_cost(&mha, &sys, 1, 512);
        assert!(c_gqa.qkv_proj_s < c_mha.qkv_proj_s);
    }

    #[test]
    fn mla_runs_and_is_positive() {
        let m = ModelConfig::deepseek_like();
        let sys = crate::sim::SystemSpec::four_a100_nvlink();
        let c = attention_cost(&m, &sys, 1, 512);
        assert!(c.total() > 0.0);
    }

    #[test]
    fn pcie_allreduce_dominates() {
        let m = ModelConfig::mixtral_8x7b();
        let nv = crate::sim::SystemSpec::four_a100_nvlink();
        let pcie = crate::sim::SystemSpec::four_a100_pcie();
        let c_nv = attention_cost(&m, &nv, 1, 512);
        let c_pcie = attention_cost(&m, &pcie, 1, 512);
        assert!(c_pcie.allreduce_s > c_nv.allreduce_s * 10.0);
        assert_eq!(c_pcie.compute(), c_nv.compute());
    }
}
