//! Expert FFN cost model.
//!
//! Each expert is an independent FFN applied to the tokens routed to it.
//! Mixtral / LLaMA use SwiGLU (gate·up·down, 3 matrices); Switch uses a
//! plain ReLU MLP (2 matrices). Costing per-expert GEMMs (rather than one
//! fused GEMM) captures the paper's §5 small-batch utilisation observation:
//! a skewed assignment concentrates tokens in one expert whose GEMM runs at
//! better utilisation, while starved experts pay the low-occupancy penalty.

use super::hardware::DeviceSpec;
use super::roofline;
use crate::model::{FfnActivation, ModelConfig};

/// Time for one expert FFN applied to `tokens` tokens.
pub fn expert_ffn_time(model: &ModelConfig, dev: &DeviceSpec, tokens: usize) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let d = model.d_model;
    let ff = model.d_ff;
    let dt = model.dtype;
    match model.activation {
        FfnActivation::SwiGlu | FfnActivation::GeGlu => {
            let gate = roofline::gemm_time(dev, tokens, ff, d, dt);
            let up = roofline::gemm_time(dev, tokens, ff, d, dt);
            // SiLU(gate) * up: ~8 flops/element, two read operands.
            let act = roofline::elementwise_time(dev, tokens * ff, 8.0, 2, dt);
            let down = roofline::gemm_time(dev, tokens, d, ff, dt);
            gate + up + act + down
        }
        FfnActivation::Relu => {
            let up = roofline::gemm_time(dev, tokens, ff, d, dt);
            let act = roofline::elementwise_time(dev, tokens * ff, 1.0, 1, dt);
            let down = roofline::gemm_time(dev, tokens, d, ff, dt);
            up + act + down
        }
    }
}

/// Time for one device hosting `n_experts_local` experts to process the
/// given per-expert token counts (sequentially — experts on a device share
/// its compute).
pub fn device_ffn_time(
    model: &ModelConfig,
    dev: &DeviceSpec,
    per_expert_tokens: &[usize],
) -> f64 {
    per_expert_tokens
        .iter()
        .map(|&t| expert_ffn_time(model, dev, t))
        .sum()
}

/// Balanced reference: each of the `E` experts receives `total_slots / E`
/// token-slots and experts are spread evenly over `n_devices`; returns the
/// per-device FFN time (all devices equal).
pub fn balanced_device_ffn_time(
    model: &ModelConfig,
    dev: &DeviceSpec,
    total_slots: usize,
    n_devices: usize,
) -> f64 {
    let experts_local = (model.n_experts / n_devices).max(1);
    let per_expert = total_slots / model.n_experts.max(1);
    device_ffn_time(model, dev, &vec![per_expert; experts_local])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hardware::DeviceSpec;

    #[test]
    fn zero_tokens_cost_nothing() {
        let m = ModelConfig::mixtral_8x7b();
        let d = DeviceSpec::a100();
        assert_eq!(expert_ffn_time(&m, &d, 0), 0.0);
    }

    #[test]
    fn swiglu_more_expensive_than_relu_same_dims() {
        let d = DeviceSpec::a100();
        let mut m = ModelConfig::mixtral_8x7b();
        let swiglu = expert_ffn_time(&m, &d, 256);
        m.activation = FfnActivation::Relu;
        let relu = expert_ffn_time(&m, &d, 256);
        assert!(swiglu > relu * 1.3, "swiglu={swiglu} relu={relu}");
    }

    #[test]
    fn time_grows_with_tokens() {
        let m = ModelConfig::mixtral_8x7b();
        let d = DeviceSpec::a100();
        let t64 = expert_ffn_time(&m, &d, 64);
        let t512 = expert_ffn_time(&m, &d, 512);
        assert!(t512 > t64);
    }

    #[test]
    fn skewed_assignment_slower_than_balanced_on_device() {
        // Same device-total tokens, one hot expert vs spread: the hot case
        // must not be cheaper than ~proportional; with utilisation effects
        // concentrating tokens is actually *more* efficient per flop, but
        // the device with more total tokens is always slower than balanced.
        let m = ModelConfig::mixtral_8x7b();
        let d = DeviceSpec::a100();
        let balanced = device_ffn_time(&m, &d, &[128, 128]);
        let hot_device = device_ffn_time(&m, &d, &[384, 128]);
        assert!(hot_device > balanced);
    }

    #[test]
    fn balanced_reference_matches_manual() {
        let m = ModelConfig::mixtral_8x7b();
        let d = DeviceSpec::a100();
        // 1024 slots over 8 experts = 128/expert; 4 devices → 2 experts each.
        let auto = balanced_device_ffn_time(&m, &d, 1024, 4);
        let manual = device_ffn_time(&m, &d, &[128, 128]);
        assert!((auto - manual).abs() < 1e-15);
    }

    #[test]
    fn mixtral_ffn_magnitude() {
        // 512 tokens × top-2 = 1024 slots over 8 experts on 4 GPUs.
        // Each GPU: 2 experts × 128 tokens; order ~1 ms on A100.
        let m = ModelConfig::mixtral_8x7b();
        let d = DeviceSpec::a100();
        let t = balanced_device_ffn_time(&m, &d, 1024, 4);
        assert!(t > 0.2e-3 && t < 10e-3, "t={t}");
    }
}
