//! Dataset emulators calibrated to the paper's measurements (§3.2, Table 1).
//!
//! | dataset     | paper skew | paper DOP error |
//! |-------------|-----------:|----------------:|
//! | MMLU        |      1.388 |           1.80% |
//! | Alpaca Eval |      1.402 |           0.98% |
//! | SST2        |      1.990 |          16.00% |
//!
//! Knob mapping (see `trace::generator`):
//! * `target_skew` → the reported skewness;
//! * `concentration` → batch heterogeneity → the Table-1 error rate (SST2 is
//!   a short-utterance sentiment set whose batches differ a lot, hence the
//!   16% error; MMLU/Alpaca are broad-domain and much more stable);
//! * `lambda`/`mu` → token- and context-level predictability, which bounds
//!   the accuracy the Token-to-Expert predictors can reach (Figure 4). The
//!   paper observes prediction is *easier* at higher skew — SST2 gets a
//!   higher floor via its skewed base distribution, and we give MMLU/Alpaca
//!   moderate predictability so the Figure-4 accuracy range matches.

use super::generator::TraceSpec;

/// Standard trace dimensions used across the benches: 8 experts (Mixtral),
/// sequence length 512 (the paper's setting).
pub const N_EXPERTS: usize = 8;
pub const SEQ_LEN: usize = 512;
pub const VOCAB: usize = 4096;

/// MMLU-like: skew ≈ 1.39, very homogeneous batches (error ≈ 1.8%).
pub fn mmlu_like(seed: u64) -> TraceSpec {
    TraceSpec {
        name: "mmlu-like".into(),
        n_experts: N_EXPERTS,
        vocab_size: VOCAB,
        seq_len: SEQ_LEN,
        sequences_per_batch: 8,
        n_batches: 50,
        target_skew: 1.40,
        concentration: 2500.0,
        lambda: 0.55,
        mu: 0.15,
        drift: 0.13,
        seed,
    }
}

/// Alpaca-Eval-like: skew ≈ 1.40, the most homogeneous batches (0.98%).
pub fn alpaca_like(seed: u64) -> TraceSpec {
    TraceSpec {
        name: "alpaca-like".into(),
        n_experts: N_EXPERTS,
        vocab_size: VOCAB,
        seq_len: SEQ_LEN,
        sequences_per_batch: 8,
        n_batches: 50,
        target_skew: 1.402,
        concentration: 9000.0,
        lambda: 0.55,
        mu: 0.15,
        drift: 0.034,
        seed,
    }
}

/// SST2-like: skew ≈ 1.99, strong train→test distribution shift (16%
/// error — SST2 has a dedicated test split in the paper), higher
/// predictability (high skew makes accurate prediction cheaper, §4).
pub fn sst2_like(seed: u64) -> TraceSpec {
    TraceSpec {
        name: "sst2-like".into(),
        n_experts: N_EXPERTS,
        vocab_size: VOCAB,
        seq_len: SEQ_LEN,
        sequences_per_batch: 8,
        n_batches: 50,
        target_skew: 1.99,
        concentration: 300.0,
        lambda: 0.70,
        mu: 0.12,
        drift: 0.56,
        seed,
    }
}

/// A spec at an arbitrary target skewness (Figure 6/8/9 sweep points that
/// have no matching dataset — the paper interpolates; we generate).
pub fn at_skew(target_skew: f64, seed: u64) -> TraceSpec {
    // Interpolate predictability/heterogeneity between the measured
    // datasets: higher skew → easier prediction (paper §4 takeaway) and
    // noisier estimation (Table 1 trend).
    let t = ((target_skew - 1.4) / (2.0 - 1.4)).clamp(0.0, 2.0);
    TraceSpec {
        name: format!("skew-{target_skew:.2}"),
        n_experts: N_EXPERTS,
        vocab_size: VOCAB,
        seq_len: SEQ_LEN,
        sequences_per_batch: 8,
        n_batches: 50,
        target_skew,
        concentration: (2500.0 * (1.0 - t) + 300.0 * t).max(100.0),
        lambda: 0.55 + 0.15 * t.min(1.5),
        mu: (0.15 - 0.02 * t.min(1.0)).max(0.0),
        drift: (0.10 + 0.65 * t).min(0.9),
        seed,
    }
}

/// All three dataset emulators.
pub fn all(seed: u64) -> Vec<TraceSpec> {
    vec![mmlu_like(seed), alpaca_like(seed + 1), sst2_like(seed + 2)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    #[test]
    fn dataset_skews_match_paper() {
        let cases = [
            (mmlu_like(7), 1.388, 0.12),
            (alpaca_like(7), 1.402, 0.12),
            (sst2_like(7), 1.990, 0.15),
        ];
        for (spec, target, tol) in cases {
            let name = spec.name.clone();
            let t = Trace::generate(spec);
            let skew = t.avg_skewness();
            assert!(
                (skew - target).abs() < tol,
                "{name}: measured skew {skew} vs paper {target}"
            );
        }
    }

    #[test]
    fn at_skew_interpolates() {
        for &s in &[1.0, 1.4, 2.0, 3.0, 4.0] {
            let spec = at_skew(s, 3);
            let t = Trace::generate(spec);
            let measured = t.avg_skewness();
            let tol = 0.1 * s + 0.12;
            assert!(
                (measured - s).abs() < tol,
                "target={s} measured={measured}"
            );
        }
    }

    #[test]
    fn all_returns_three() {
        assert_eq!(all(1).len(), 3);
    }
}
