//! Synthetic routing-trace generation.
//!
//! The paper measures token→expert routing of Mixtral / LLaMA-MoE / Switch
//! on MMLU, Alpaca Eval and SST2 using real model inference on A100s. We do
//! not have those models or GPUs, so — per the DESIGN.md substitution table —
//! we generate synthetic traces whose *statistics* are calibrated to what
//! the paper reports: average per-batch skewness (MMLU 1.39, Alpaca 1.40,
//! SST2 1.99), train/test distribution-estimation error (Table 1), and a
//! tunable degree of token-level predictability so the Token-to-Expert
//! accuracy↔overhead trade-off (Figure 4) exists.
//!
//! Generative model per (dataset, layer):
//!
//! * a **base expert distribution** `p` from a geometric family solved to a
//!   target skewness ([`base_distribution`]),
//! * per-batch distributions drawn `Dirichlet(c · p)` — the concentration
//!   `c` controls batch heterogeneity and hence the train→test estimation
//!   error that Table 1 reports,
//! * each vocabulary token has an **affinity expert** sampled from `p`
//!   (so the aggregate stays `p`), and each *token pair* has a bigram
//!   affinity: routing draws the affinity expert with prob `lambda`
//!   (unigram predictability), the bigram affinity with prob `mu`
//!   (context predictability — what the paper's LSTM exploits), otherwise
//!   samples the per-batch distribution.

pub mod datasets;
pub mod generator;

pub use generator::{base_distribution, Batch, Token, Trace, TraceSpec};

use crate::util::stats;

/// Measure the paper's skewness on a slice of expert counts.
pub fn skewness(counts: &[usize]) -> f64 {
    stats::skewness_of_counts(counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skewness_reexport_consistent() {
        assert!((skewness(&[75, 9, 8, 8]) - 3.0).abs() < 0.01);
    }
}
