//! The synthetic routing-trace generator (see module docs in `trace`).

use crate::util::rng::Rng;
use crate::util::stats;

/// One routed token: vocabulary id + the expert the (simulated) router
/// assigned it to. The paper's predictors classify the top-1 expert; top-k
/// load accounting replicates slots downstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Token {
    pub id: u32,
    pub expert: u8,
}

/// One batch: `sequences × seq_len` tokens routed under one per-batch
/// expert distribution.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub sequences: Vec<Vec<Token>>,
}

impl Batch {
    /// Per-expert token counts in this batch.
    pub fn expert_counts(&self, n_experts: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_experts];
        for seq in &self.sequences {
            for tok in seq {
                counts[tok.expert as usize] += 1;
            }
        }
        counts
    }

    pub fn n_tokens(&self) -> usize {
        self.sequences.iter().map(Vec::len).sum()
    }

    pub fn skewness(&self, n_experts: usize) -> f64 {
        stats::skewness_of_counts(&self.expert_counts(n_experts))
    }
}

/// Generator specification for one dataset-like workload.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub name: String,
    pub n_experts: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    /// Sequences per batch (the paper uses batch 1 × seq 512 for the
    /// simulator; predictor training uses many batches).
    pub sequences_per_batch: usize,
    pub n_batches: usize,
    /// Target average per-batch skewness.
    pub target_skew: f64,
    /// Dirichlet concentration for per-batch distributions (higher = more
    /// homogeneous batches = lower Table-1 error rate).
    pub concentration: f64,
    /// Probability a token routes to its unigram affinity expert.
    pub lambda: f64,
    /// Probability a token routes to its bigram (context) affinity expert.
    pub mu: f64,
    /// Total L1 distance the expert distribution drifts across the trace
    /// (skew-preserving rotation of the non-top experts). An 80/20
    /// train/test split then sees a systematic shift of ≈ `drift / 2` —
    /// this is what produces SST2's 16% Table-1 error in the paper, where
    /// the test split comes from a genuinely different distribution.
    pub drift: f64,
    pub seed: u64,
}

impl TraceSpec {
    pub fn tokens_per_batch(&self) -> usize {
        self.seq_len * self.sequences_per_batch
    }
}

/// A generated routing trace plus the ground-truth base distribution.
#[derive(Clone, Debug)]
pub struct Trace {
    pub spec: TraceSpec,
    pub base_probs: Vec<f64>,
    pub batches: Vec<Batch>,
    /// Unigram affinity expert per vocab id (ground truth — predictors must
    /// *learn* it from the batches, never read it).
    affinity: Vec<u8>,
}

impl Trace {
    /// Generate a trace from a spec.
    pub fn generate(spec: TraceSpec) -> Trace {
        let mut rng = Rng::new(spec.seed);
        // The `mu` fraction routes via the (uniform-ish) bigram hash, which
        // flattens the aggregate distribution; compensate so the *measured*
        // skew hits the target: max_eff = (1−mu)·s_base/E + mu/E = target/E.
        let base_skew = if spec.mu < 1.0 {
            ((spec.target_skew - spec.mu) / (1.0 - spec.mu)).max(1.0)
        } else {
            1.0
        };
        let base_probs = base_distribution(spec.n_experts, base_skew);

        // Skew-preserving drift target: a permuted copy of `base_probs`
        // with the argmax fixed (max stays put → skewness preserved).
        let drift_target = drift_permutation(&base_probs, &mut rng);
        let drift = spec.drift.clamp(0.0, 1.0);

        // Unigram affinities: a start table sampled from the base
        // distribution, an end table from the drift target, and a per-token
        // switch threshold — by the end of the trace a `drift` fraction of
        // the vocabulary has re-routed. This models the "expert load
        // distribution fluctuates" regime that makes SST2's Table-1 error
        // large: the test split genuinely differs from the train split.
        let affinity: Vec<u8> = (0..spec.vocab_size)
            .map(|_| rng.categorical(&base_probs) as u8)
            .collect();
        let affinity_end: Vec<u8> = (0..spec.vocab_size)
            .map(|_| rng.categorical(&drift_target) as u8)
            .collect();
        let thresholds: Vec<f64> = (0..spec.vocab_size).map(|_| rng.f64()).collect();

        let mut batches = Vec::with_capacity(spec.n_batches);
        for b in 0..spec.n_batches {
            let u = if spec.n_batches > 1 {
                b as f64 / (spec.n_batches - 1) as f64
            } else {
                0.0
            };
            let t = u * drift;
            // Per-batch distribution: drifted base + Dirichlet jitter
            // (heterogeneity across batches).
            let drifted: Vec<f64> = base_probs
                .iter()
                .zip(&drift_target)
                .map(|(&p, &q)| (1.0 - t) * p + t * q)
                .collect();
            let alphas: Vec<f64> = drifted
                .iter()
                .map(|&p| (p * spec.concentration).max(1e-3))
                .collect();
            let batch_probs = rng.dirichlet(&alphas);
            let mut batch = Batch::default();
            for _ in 0..spec.sequences_per_batch {
                let mut seq = Vec::with_capacity(spec.seq_len);
                let mut prev_id: u32 = 0;
                for pos in 0..spec.seq_len {
                    let id = rng.below(spec.vocab_size as u64) as u32;
                    let r = rng.f64();
                    let expert = if r < spec.lambda {
                        let idx = id as usize;
                        if thresholds[idx] < t {
                            affinity_end[idx]
                        } else {
                            affinity[idx]
                        }
                    } else if r < spec.lambda + spec.mu && pos > 0 {
                        bigram_affinity(prev_id, id, spec.n_experts)
                    } else {
                        rng.categorical(&batch_probs) as u8
                    };
                    seq.push(Token { id, expert });
                    prev_id = id;
                }
                batch.sequences.push(seq);
            }
            batches.push(batch);
        }

        Trace {
            spec,
            base_probs,
            batches,
            affinity,
        }
    }

    /// Average per-batch skewness (the number the paper reports per dataset).
    pub fn avg_skewness(&self) -> f64 {
        let skews: Vec<f64> = self
            .batches
            .iter()
            .map(|b| b.skewness(self.spec.n_experts))
            .collect();
        stats::mean(&skews)
    }

    /// Aggregate expert counts over all batches.
    pub fn expert_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.spec.n_experts];
        for b in &self.batches {
            for (i, c) in b.expert_counts(self.spec.n_experts).iter().enumerate() {
                counts[i] += c;
            }
        }
        counts
    }

    /// 80/20-style split by batches (the paper randomly partitions; we split
    /// deterministically after generation order is already random).
    pub fn split(&self, train_frac: f64) -> (Trace, Trace) {
        let n_train = ((self.batches.len() as f64) * train_frac).round() as usize;
        let n_train = n_train.clamp(1, self.batches.len().saturating_sub(1).max(1));
        let mk = |batches: Vec<Batch>| Trace {
            spec: self.spec.clone(),
            base_probs: self.base_probs.clone(),
            batches,
            affinity: self.affinity.clone(),
        };
        (
            mk(self.batches[..n_train].to_vec()),
            mk(self.batches[n_train..].to_vec()),
        )
    }

    /// Total number of tokens across all batches.
    pub fn n_tokens(&self) -> usize {
        self.batches.iter().map(Batch::n_tokens).sum()
    }
}

/// A permuted copy of `probs` with the argmax fixed: rotating the non-top
/// components preserves the max (hence the skewness) while moving L1 mass.
fn drift_permutation(probs: &[f64], rng: &mut Rng) -> Vec<f64> {
    let argmax = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut rest: Vec<usize> = (0..probs.len()).filter(|&i| i != argmax).collect();
    rng.shuffle(&mut rest);
    let mut out = probs.to_vec();
    let original: Vec<usize> = (0..probs.len()).filter(|&i| i != argmax).collect();
    for (dst, src) in original.iter().zip(&rest) {
        out[*dst] = probs[*src];
    }
    out
}

/// Deterministic bigram affinity via a mixing hash (stand-in for the
/// context-dependent routing the paper's LSTM predictor captures).
pub fn bigram_affinity(prev_id: u32, id: u32, n_experts: usize) -> u8 {
    let mut h = (prev_id as u64) << 32 | id as u64;
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    (h % n_experts as u64) as u8
}

/// Construct a probability vector over `n` experts with
/// `max(p) / (1/n) == skew` using a geometric family `p_i ∝ r^i`
/// (bisection on the ratio `r`). `skew = 1` → uniform; `skew = n` →
/// one-hot (approached asymptotically).
pub fn base_distribution(n: usize, skew: f64) -> Vec<f64> {
    assert!(n >= 1);
    let skew = skew.clamp(1.0, n as f64 * 0.999);
    if (skew - 1.0).abs() < 1e-9 {
        return vec![1.0 / n as f64; n];
    }
    // For ratio r ∈ (0,1): p_0 = (1−r)/(1−r^n), skewness = n·p_0.
    let skew_of = |r: f64| -> f64 {
        if (r - 1.0).abs() < 1e-12 {
            1.0
        } else {
            n as f64 * (1.0 - r) / (1.0 - r.powi(n as i32))
        }
    };
    let (mut lo, mut hi) = (1e-9, 1.0 - 1e-9); // r→0: skew→n; r→1: skew→1
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if skew_of(mid) > skew {
            lo = mid; // too skewed → raise r
        } else {
            hi = mid;
        }
    }
    let r = 0.5 * (lo + hi);
    let mut p: Vec<f64> = (0..n).map(|i| r.powi(i as i32)).collect();
    let sum: f64 = p.iter().sum();
    for x in &mut p {
        *x /= sum;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> TraceSpec {
        TraceSpec {
            name: "test".into(),
            n_experts: 8,
            vocab_size: 512,
            seq_len: 128,
            sequences_per_batch: 4,
            n_batches: 10,
            target_skew: 1.4,
            concentration: 500.0,
            lambda: 0.5,
            mu: 0.1,
            drift: 0.0,
            seed: 42,
        }
    }

    #[test]
    fn base_distribution_hits_target_skew() {
        for &skew in &[1.0, 1.4, 2.0, 3.0, 5.0] {
            let p = base_distribution(8, skew);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            let measured = stats::skewness_of_probs(&p);
            assert!(
                (measured - skew).abs() < 0.01,
                "target={skew} measured={measured}"
            );
        }
    }

    #[test]
    fn base_distribution_clamps_extremes() {
        let p = base_distribution(8, 0.5); // below 1 → uniform
        assert!((p[0] - 0.125).abs() < 1e-9);
        let p = base_distribution(8, 100.0); // above n → near one-hot
        assert!(p[0] > 0.98);
    }

    #[test]
    fn trace_shape_matches_spec() {
        let t = Trace::generate(small_spec());
        assert_eq!(t.batches.len(), 10);
        assert_eq!(t.batches[0].sequences.len(), 4);
        assert_eq!(t.batches[0].sequences[0].len(), 128);
        assert_eq!(t.n_tokens(), 10 * 4 * 128);
        assert!(t.batches[0].sequences[0]
            .iter()
            .all(|tok| (tok.expert as usize) < 8 && (tok.id as usize) < 512));
    }

    #[test]
    fn trace_is_deterministic() {
        let a = Trace::generate(small_spec());
        let b = Trace::generate(small_spec());
        assert_eq!(a.batches[3].sequences[1], b.batches[3].sequences[1]);
    }

    #[test]
    fn measured_skew_tracks_target() {
        for &target in &[1.4, 2.0] {
            let mut spec = small_spec();
            spec.target_skew = target;
            spec.seq_len = 512;
            spec.n_batches = 20;
            let t = Trace::generate(spec);
            let measured = t.avg_skewness();
            // Finite-sample noise adds a little skew on top of the base.
            assert!(
                (measured - target).abs() < 0.25,
                "target={target} measured={measured}"
            );
        }
    }

    #[test]
    fn split_preserves_batches() {
        let t = Trace::generate(small_spec());
        let (train, test) = t.split(0.8);
        assert_eq!(train.batches.len(), 8);
        assert_eq!(test.batches.len(), 2);
        assert_eq!(
            train.n_tokens() + test.n_tokens(),
            t.n_tokens()
        );
    }

    #[test]
    fn aggregate_counts_track_base_probs() {
        let mut spec = small_spec();
        spec.n_batches = 40;
        spec.seq_len = 512;
        let t = Trace::generate(spec);
        let counts = t.expert_counts();
        let total: usize = counts.iter().sum();
        let freq0 = counts[0] as f64 / total as f64;
        assert!(
            (freq0 - t.base_probs[0]).abs() < 0.05,
            "freq0={freq0} base={}",
            t.base_probs[0]
        );
    }

    #[test]
    fn higher_lambda_means_more_predictable() {
        // With lambda=1 every token routes to its affinity expert: a
        // perfect conditional predictor would be 100% accurate.
        let mut spec = small_spec();
        spec.lambda = 1.0;
        spec.mu = 0.0;
        let t = Trace::generate(spec);
        for b in &t.batches {
            for s in &b.sequences {
                for tok in s {
                    assert_eq!(tok.expert, t.affinity_for_test(tok.id));
                }
            }
        }
    }

    impl Trace {
        /// Test-only accessor.
        fn affinity_for_test(&self, id: u32) -> u8 {
            self.affinity[id as usize]
        }
    }
}
