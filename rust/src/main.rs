//! `moe-gps` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   simulate      one (model, system, skew, strategy) → latency breakdown
//!   sweep         Figure-6-style grid over skew × strategy × accuracy
//!   advise        Figure-1 guideline decision map
//!   trace         generate + inspect a synthetic routing trace
//!   predict       train/evaluate the predictor zoo on a dataset emulator
//!   serve         run the real tiny-MoE serving driver (requires artifacts)
//!   bench-report  regenerate a paper table/figure (table1, fig4, fig6, fig7)

use anyhow::Result;

use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{
    ControllerConfig, Coordinator, DecodeOptions, FaultPlan, ServeStrategy, StrategyController,
};
use moe_gps::gps::select::recommend;
use moe_gps::gps::{self, calibrate, CalibrationOptions, ServePhase};
use moe_gps::model::ModelConfig;
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{LayerSim, SystemSpec};
use moe_gps::trace::{datasets, Trace};
use moe_gps::util::args::Args;

fn main() {
    let args = Args::from_env(&[
        "fast",
        "csv",
        "help",
        "version",
        "overlap",
        "speculative",
        "require-results",
        "adaptive",
        "pin",
    ]);
    if args.flag("version") {
        println!("moe-gps {}", moe_gps::VERSION);
        return;
    }
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("advise") => cmd_advise(&args),
        Some("trace") => cmd_trace(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-report") => cmd_bench_report(&args),
        Some("bench-validate") => cmd_bench_validate(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(err) = result {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "moe-gps {} — prediction-strategy selection for MoE expert duplication

USAGE: moe-gps <subcommand> [options]

  simulate     --model mixtral-8x7b --system nvlink|pcie|<GB/s> --skew 1.4
               [--strategy none|dop|tep --accuracy 0.9 --batch 1 --seq 512
                --error-model typical]
  sweep        --model ... --system ... [--skews 1.0,1.4,2.0,3.0,4.0 --fast]
  advise       --model ... [--phase prefill|decode --skews ...
                --bandwidths 600,300,128,64 --batch 16 --ctx 512 --fast
                --overlap      (price the ADR-002 lookahead engine and show
                                which guideline cells it flips)
                --speculative  (additionally price the ADR-003 speculative
                                TEP scatter; implies --overlap)
                --memory-cap B (ADR 004: per-device HBM budget for expert
                                weights, e.g. 24g; duplication that
                                overflows it pays exposed refetch — shows
                                the cells the cap flips)
                --horizon H    (ADR 006: price proactive replanning H
                                replan windows ahead — DOP's duplication
                                movement prewarms fully but the plan runs
                                drift×H staler; shows the cells the
                                horizon flips)
                --forecast-drift F (per-window forecast L1 drift used in
                                the staleness term; default 0.02, or pass
                                a measured value)
                --microbatch K (ADR 010: price the micro-batch wavefront —
                                per-micro-batch routing compute hides
                                under the previous micro-batch's FFN
                                window; shows the cells the overlap flips)
                --from-serve report.json (ADR 005: render the map from the
                                *measured* constants a `serve --report` run
                                recorded — measured skew/bandwidth/share
                                error; --max-delta F fails when the
                                fit-vs-holdout throughput drift exceeds F)]
  trace        --dataset mmlu|alpaca|sst2 [--seed 7]
  predict      --dataset mmlu|alpaca|sst2 [--fast --seed 7]
  serve        --strategy none|dop|tep [--phase prefill|decode|mixed
                --workers 4 --artifacts artifacts
                --lookahead N  (prewarm the next N layers' replicas under
                                the current layer's compute; 0 = off)
                --prewarm-budget B (byte budget for prewarm transfers per
                                layer step; deepest prewarms drop first)
                --memory-cap B (per-worker byte cap for expert replica
                                weights: LRU eviction + refetch, ADR 004)
                --speculative  (TEP speculative scatter; implies lookahead)
                --parallel-attention (ADR 009: fan prefill attention out
                                to the workers as Arc-shared read views;
                                bitwise identical, traffic accounted as
                                bytes_shared instead of bytes_copied)
                --microbatch K (ADR 010: split each round/step into K
                                micro-batches and pipeline them as a
                                wavefront — the leader routes micro-batch
                                B while A's FFN slabs are in flight.
                                Bitwise identical at every K; 1 = serial)
                --horizon H    (ADR 006: plan for the forecast distribution
                                H replan windows ahead; predicted-hot
                                replicas prewarm before the spike; 0 =
                                reactive, bitwise identical to omitting)
                --forecast-error-max F (with --adaptive: realized forecast
                                L1 past which the controller falls back to
                                reactive replanning; default 0.5)
                --threads N    (reference-backend compute pool; 0 = auto)
                --pin          (ADR 007: pin pool helpers to cores and
                                reserve the first core for the leader;
                                linux only, bitwise identical either way.
                                MOE_GPS_SIMD=scalar|native forces or
                                auto-detects the kernel dispatch tier)
                --adaptive     (ADR 005: online strategy controller —
                                re-selects DOP/TEP/speculative/lookahead at
                                replan boundaries from measured metrics;
                                tune with --hysteresis N --margin F
                                --window N --min-window N, price on
                                --model/--system)
                --inject-faults SPEC (ADR 008: deterministic fault
                                injection — comma-separated
                                kind[:worker]@op[xMS] scripts, kinds
                                kill|delay|drop, e.g. `kill:1@3` or
                                `delay@5x250`; MOE_GPS_FAULTS sets the
                                same spec via the environment. Disabled =
                                bitwise-identical serving)
                --worker-timeout S (override the cost-model reply deadline
                                with a fixed S seconds; lost replies past
                                it retry with backoff, then the worker is
                                declared dead and its groups fail over to
                                surviving replicas)
                --report F.json (write the serve report: measured
                                constants, calibration check, controller
                                decision trace — advise --from-serve input)]
               prefill: [--rounds 8 --seqs 4]
               decode/mixed (continuous batching): [--steps 256 --seqs 8
                --max-active 8 --prompt 32 --max-new 32 --replan 4
                --temperature 1.0 --arrival-every 2]
               (without artifacts the synthetic tiny model is served)
  bench-report table1|fig4|fig6|fig7 [--fast]
  bench-validate [BENCH_serve.json] [--require-results
                --forecast-report F.json --max-forecast-l1 B
                --min-kernel-speedup X --baseline OLD.json
                --max-regression F --chaos-report F.json
                --copy-report F.json --max-copied-frac F
                --wavefront-report F.json --max-idle-frac F]
               validate a serve-bench trajectory file against the
               moe-gps/serve-bench/v1 schema (the CI bench-smoke gate);
               with --forecast-report, additionally gate the realized
               forecast L1 recorded by a `serve --horizon` report;
               with --min-kernel-speedup, require the kernels bench's
               vector tier ≥ X× scalar on dot/matmul (ADR 007 — a
               forced-scalar file is reported, never silently passed);
               with --baseline, fail when serve_hotpath throughput
               regressed more than --max-regression (default 0.2) vs
               the stored records;
               with --chaos-report, gate a fault-injected serve report
               (ADR 008): at least one worker death must have been
               injected AND zero sequences lost;
               with --copy-report, gate a serve report's data-plane copy
               accounting (ADR 009): fail when bytes_copied /
               (bytes_copied + bytes_shared) exceeds --max-copied-frac
               (default 0.5);
               with --wavefront-report, gate a serve report's wavefront
               occupancy (ADR 010): fail when the window-weighted worker
               idle fraction exceeds --max-idle-frac (default 0.95)
",
        moe_gps::VERSION
    );
}

fn parse_system(args: &Args) -> Result<SystemSpec> {
    Ok(match args.opt_or("system", "nvlink") {
        "nvlink" => SystemSpec::four_a100_nvlink(),
        "pcie" => SystemSpec::four_a100_pcie(),
        other => SystemSpec::four_a100_custom_bw(
            other
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--system expects nvlink|pcie|<GB/s>"))?,
        ),
    })
}

fn parse_model(args: &Args) -> Result<ModelConfig> {
    ModelConfig::by_name(args.opt_or("model", "mixtral-8x7b"))
}

fn dataset_spec(name: &str, seed: u64) -> Result<moe_gps::trace::TraceSpec> {
    Ok(match name {
        "mmlu" => datasets::mmlu_like(seed),
        "alpaca" => datasets::alpaca_like(seed),
        "sst2" => datasets::sst2_like(seed),
        other => anyhow::bail!("unknown dataset `{other}` (mmlu|alpaca|sst2)"),
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let system = parse_system(args)?;
    let skew = args.opt_f64("skew", 1.4)?;
    let batch = args.opt_usize("batch", 1)?;
    let seq = args.opt_usize("seq", 512)?;
    let mut sim = LayerSim::new(model, system).with_workload(batch, seq);
    sim.error_model =
        moe_gps::sim::ErrorModel::by_name(args.opt_or("error-model", "typical"))?;
    let strategy = match args.opt_or("strategy", "none") {
        "none" => Strategy::NoPrediction,
        "dop" | "distribution-only" => Strategy::DistributionOnly {
            error_rate: args.opt_f64("error-rate", 0.018)?,
        },
        "tep" | "token-to-expert" => Strategy::TokenToExpert {
            accuracy: args.opt_f64("accuracy", 0.9)?,
            overhead_s: args.opt_f64("overhead-ms", 0.1)? * 1e-3,
        },
        other => anyhow::bail!("unknown strategy `{other}`"),
    };
    let b = sim.breakdown(skew, strategy);
    println!("{}", b.to_json().to_string_pretty());
    println!(
        "normalized performance vs baseline: {:.3}",
        sim.normalized_performance(skew, strategy)
    );
    Ok(())
}

fn calibrations(
    model: &ModelConfig,
    system: &SystemSpec,
    fast: bool,
    seed: u64,
) -> Vec<gps::WorkloadCalibration> {
    let opts = CalibrationOptions {
        fast,
        ..Default::default()
    };
    datasets::all(seed)
        .into_iter()
        .map(|spec| calibrate(spec, model, system, &opts))
        .collect()
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let system = parse_system(args)?;
    let skews = args.opt_f64_list("skews", &gps::sweep::figure6_skews())?;
    let cals = calibrations(&model, &system, args.flag("fast"), args.opt_u64("seed", 7)?);
    let points = gps::skew_sweep(&model, &system, &cals, &skews, 1, 512);
    println!(
        "{}",
        gps::report::figure6(
            &points,
            &format!("{} on {}", model.name, system.interconnect.name)
        )
    );
    Ok(())
}

/// Decode-phase guideline cells: the decision map grid priced on the
/// decode-step simulator (memory-bound FFN, per-step TEP overhead — ADR
/// 001). Shared by the static map, the regime overlays and
/// `advise --from-serve`.
fn decode_cells(
    model: &ModelConfig,
    cals: &[gps::WorkloadCalibration],
    skews: &[f64],
    bandwidths: &[f64],
    batch: usize,
    ctx: usize,
    regime: gps::Regime,
) -> Vec<gps::guidelines::GuidelineCell> {
    let mut cells = Vec::new();
    for &bw in bandwidths {
        let sys = SystemSpec::four_a100_custom_bw(bw);
        for &skew in skews {
            let cmp =
                gps::decode_strategy_savings_in(model, &sys, cals, skew, batch, ctx, regime);
            let best_saving = cmp.dop_saving_s.max(cmp.tep_best_saving_s).max(0.0);
            cells.push(gps::guidelines::GuidelineCell {
                skewness: skew,
                bandwidth_gbs: bw,
                recommendation: recommend(&cmp),
                saving_frac: best_saving / cmp.baseline_s,
            });
        }
    }
    cells
}

fn cmd_advise(args: &Args) -> Result<()> {
    if let Some(path) = args.opt("from-serve") {
        return cmd_advise_from_serve(args, path);
    }
    let model = parse_model(args)?;
    let phase = ServePhase::by_name(args.opt_or("phase", "prefill"))?;
    let speculative = args.flag("speculative");
    // Speculative scatter rides the lookahead pipeline, so pricing it
    // implies the overlap regime (ADR 003).
    let overlap = args.flag("overlap") || speculative;
    // ADR 004: per-device HBM budget for expert weights (e.g. `24g`).
    let memory_cap_bytes = args.opt_bytes("memory-cap")?.map(|b| b as f64);
    // ADR 006: proactive forecast horizon (replan windows) — prewarms
    // DOP's replica movement ahead of the boundary at the price of a
    // `drift × horizon` staler plan; `--forecast-drift` overrides the
    // default per-window drift (e.g. with a measured value).
    let horizon = args.opt_usize("horizon", 0)?;
    let forecast_drift = match args.opt("forecast-drift") {
        Some(s) => Some(s.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("--forecast-drift expects a number (L1 per window)")
        })?),
        None => None,
    };
    // ADR 010: micro-batch wavefront depth — K > 1 hides the leader's
    // per-micro-batch routing compute under the previous micro-batch's
    // in-flight FFN window. 0/1 both mean serial.
    let microbatch = args.opt_usize("microbatch", 0)?;
    let regime = gps::Regime {
        overlap,
        speculative,
        memory_cap_bytes,
        horizon,
        forecast_drift,
        microbatch,
        copied_bytes_per_token: None,
    };
    let skews = args.opt_f64_list("skews", &[1.0, 1.4, 2.0, 3.0, 4.0])?;
    let bandwidths = args.opt_f64_list("bandwidths", &[600.0, 300.0, 128.0, 64.0])?;
    let system = SystemSpec::four_a100_nvlink();
    let cals = calibrations(&model, &system, args.flag("fast"), args.opt_u64("seed", 7)?);
    // One map builder per phase, parameterised by regime so `--overlap` /
    // `--speculative` / `--memory-cap` can render their map *and* the
    // cells they flip.
    let build = |regime: gps::Regime| -> Result<Vec<gps::guidelines::GuidelineCell>> {
        Ok(match phase {
            ServePhase::Prefill => gps::guidelines::decision_map_in(
                &model,
                &cals,
                &skews,
                &bandwidths,
                1,
                512,
                regime,
            ),
            ServePhase::Decode => decode_cells(
                &model,
                &cals,
                &skews,
                &bandwidths,
                args.opt_usize("batch", 16)?,
                args.opt_usize("ctx", 512)?,
                regime,
            ),
        })
    };
    let cells = build(regime)?;
    let mut tags: Vec<String> = Vec::new();
    if speculative {
        tags.push("lookahead overlap + speculative scatter".into());
    } else if overlap {
        tags.push("lookahead overlap".into());
    }
    if memory_cap_bytes.is_some() {
        tags.push("memory-capped".into());
    }
    if horizon > 0 {
        tags.push(format!("forecast horizon {horizon}"));
    }
    if microbatch > 1 {
        tags.push(format!("microbatch {microbatch}"));
    }
    println!(
        "phase: {}{}",
        phase.name(),
        if tags.is_empty() {
            String::new()
        } else {
            format!(" ({})", tags.join(", "))
        }
    );
    println!("{}", gps::guidelines::render_map(&cells, &skews, &bandwidths));
    println!("{}", gps::guidelines::summarize(&cells));
    if memory_cap_bytes.is_some() {
        // Flips vs the same regime without the cap: what memory pressure
        // alone changes about the guidance (ADR 004).
        let base = build(gps::Regime {
            memory_cap_bytes: None,
            ..regime
        })?;
        println!("{}", gps::guidelines::render_flips(&base, &cells));
    }
    if speculative {
        // Flips vs the overlap-only map: what speculation alone buys.
        let base = build(gps::Regime {
            speculative: false,
            ..regime
        })?;
        println!("{}", gps::guidelines::render_flips(&base, &cells));
    } else if overlap {
        let base = build(gps::Regime {
            overlap: false,
            speculative: false,
            ..regime
        })?;
        println!("{}", gps::guidelines::render_flips(&base, &cells));
    }
    if horizon > 0 {
        // Flips vs the same regime replanned reactively (horizon 0): how
        // the DOP/TEP frontier moves when plans are made for the forecast
        // distribution instead of the last observed one (ADR 006).
        let base = build(gps::Regime {
            horizon: 0,
            forecast_drift: None,
            ..regime
        })?;
        println!("{}", gps::guidelines::render_flips(&base, &cells));
    }
    if microbatch > 1 {
        // Flips vs the same regime served serially: which cells the
        // wavefront's hidden routing compute moves (ADR 010). The hiding
        // is strategy-independent but shrinks with the FFN window, so
        // cells near the DOP/TEP frontier can flip.
        let base = build(gps::Regime {
            microbatch: 0,
            ..regime
        })?;
        println!("{}", gps::guidelines::render_flips(&base, &cells));
    }
    Ok(())
}

/// `advise --from-serve report.json`: render the guideline map from the
/// *measured* constants a serve run recorded (ADR 005). The measured
/// share error overrides the offline calibrations, the measured
/// effective bandwidth defines the operating point, and the fit-vs-
/// holdout calibration check gates silent cost-model rot
/// (`--max-delta`, the CI smoke bound).
fn cmd_advise_from_serve(args: &Args, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let served = gps::parse_serve_report(&text)?;
    let measured = &served.measured;
    let model = parse_model(args)?;
    let base_system = SystemSpec::four_a100_nvlink();
    let cals = calibrations(&model, &base_system, args.flag("fast"), args.opt_u64("seed", 7)?);
    let cals_measured = measured.apply_to_cals(&cals);
    let skews = args.opt_f64_list("skews", &[1.0, 1.4, 2.0, 3.0, 4.0])?;
    let bandwidths = args.opt_f64_list("bandwidths", &[600.0, 300.0, 128.0, 64.0])?;
    let batch = args.opt_usize("batch", 16)?;
    let ctx = args.opt_usize("ctx", 512)?;

    println!(
        "measured constants from {path} ({} run, strategy {}, {} samples):",
        served.phase.name(),
        served.strategy,
        measured.samples
    );
    println!(
        "  skew {:.3}  tokens/s {:.1}  bandwidth {}  share-L1 {}  \
         top-k hit {}  hidden {:.0}%  refetch {:.0}%",
        measured.mean_skew,
        measured.tokens_per_s,
        measured
            .effective_bandwidth_gbs
            .map(|b| format!("{b:.2} GB/s"))
            .unwrap_or_else(|| "unmeasured".into()),
        measured
            .dop_error
            .map(|e| format!("{:.1}%", e * 100.0))
            .unwrap_or_else(|| "n/a".into()),
        measured
            .tep_topk_hit
            .map(|h| format!("{:.1}%", h * 100.0))
            .unwrap_or_else(|| "n/a".into()),
        measured.hidden_frac * 100.0,
        measured.refetch_frac * 100.0,
    );
    if served.adaptive {
        println!(
            "  controller: {} decisions, {} switches",
            served.decisions, served.switches
        );
    }
    if let Some(threads) = served.threads {
        // The kernel regime the constants were calibrated under (ADR
        // 007): a report measured with SIMD+pinning prices a different
        // operating point than a scalar one — say which this was.
        println!(
            "  kernels: simd={} threads={} pinned={}",
            served.simd_tier.as_deref().unwrap_or("?"),
            threads,
            served.pinned,
        );
    }
    // The regime the map is priced under starts from what the run served
    // and gains the measured data-plane term: copied bytes per token
    // prices the host-copy bandwidth charge (ADR 009 follow-up).
    let mut regime = served.regime;
    if let (Some(copied), Some(shared)) = (served.bytes_copied, served.bytes_shared) {
        // ADR 009: how much of the coordinator↔worker data plane moved by
        // reference — high copied fractions mean host-copy overhead is
        // inflating the measured per-token cost.
        let total = copied + shared;
        let frac = if total > 0.0 { copied / total } else { 0.0 };
        println!(
            "  data plane: copied {} / shared {} (copied frac {:.3})",
            moe_gps::util::human_bytes(copied),
            moe_gps::util::human_bytes(shared),
            frac,
        );
        if measured.tokens > 0.0 && copied > 0.0 {
            regime.copied_bytes_per_token = Some(copied / measured.tokens);
            println!(
                "  pricing host copies at {} per token",
                moe_gps::util::human_bytes(copied / measured.tokens),
            );
        }
    }
    if let Some(idle) = served.worker_idle_frac {
        // ADR 010: wavefront occupancy — how much worker capacity the
        // serve left on the table waiting for leader routing/combine.
        println!(
            "  wavefront: microbatch {}  worker idle frac {:.3}  leader stall {}",
            if regime.microbatch > 0 { regime.microbatch } else { 1 },
            idle,
            moe_gps::util::human_time(served.leader_stall_s.unwrap_or(0.0)),
        );
    }
    if served.worker_deaths.unwrap_or(0) > 0 || served.degraded_samples.unwrap_or(0) > 0 {
        // ADR 008: the constants blend healthy and failover windows —
        // timeouts, redispatch and re-uploads inflate transfer/compute
        // terms, so the rendered map is pessimistic for a healthy fleet.
        println!(
            "  note: degraded run — {} worker death(s), {} degraded \
             sample(s); prefer a fault-free report for capacity planning",
            served.worker_deaths.unwrap_or(0),
            served.degraded_samples.unwrap_or(0),
        );
    }

    // The guideline map under the measured constants, priced under the
    // regime the run actually served (overlap/speculative/memory-cap).
    let cells = match served.phase {
        ServePhase::Prefill => gps::guidelines::decision_map_in(
            &model,
            &cals_measured,
            &skews,
            &bandwidths,
            1,
            512,
            regime,
        ),
        ServePhase::Decode => decode_cells(
            &model,
            &cals_measured,
            &skews,
            &bandwidths,
            batch,
            ctx,
            regime,
        ),
    };
    println!(
        "phase: {} (calibrated from measured serve)",
        served.phase.name()
    );
    println!("{}", gps::guidelines::render_map(&cells, &skews, &bandwidths));
    println!("{}", gps::guidelines::summarize(&cells));

    // The measured operating point through the same pricing path.
    let seq_or_ctx = match served.phase {
        ServePhase::Prefill => 512,
        ServePhase::Decode => ctx,
    };
    let op_batch = match served.phase {
        ServePhase::Prefill => 1,
        ServePhase::Decode => batch,
    };
    let cmp = measured.savings(
        served.phase,
        &model,
        &base_system,
        &cals,
        op_batch,
        seq_or_ctx,
        regime,
    );
    println!(
        "measured operating point (skew {:.2}, bw {}): recommend {}",
        cmp.skewness,
        measured
            .effective_bandwidth_gbs
            .map(|b| format!("{b:.2} GB/s"))
            .unwrap_or_else(|| "nominal".into()),
        recommend(&cmp).name()
    );

    // Measured-vs-predicted throughput delta: the drift gate.
    match &served.check {
        Some(check) => {
            println!(
                "calibration check: fit {:.1} tok/s vs holdout {:.1} tok/s \
                 (delta {:.1}%)",
                check.fit_tokens_per_s,
                check.holdout_tokens_per_s,
                check.delta_frac * 100.0
            );
            if let Some(max_delta) = args.opt("max-delta") {
                let bound: f64 = max_delta
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--max-delta expects a fraction"))?;
                anyhow::ensure!(
                    check.delta_frac <= bound,
                    "calibration drift {:.3} exceeds --max-delta {bound} \
                     (cost model no longer predicts measured throughput)",
                    check.delta_frac
                );
                println!("calibration drift within --max-delta {bound}: OK");
            }
        }
        None => {
            anyhow::ensure!(
                args.opt("max-delta").is_none(),
                "--max-delta given but the report carries no calibration \
                 check (run more rounds/steps)"
            );
            println!("calibration check: n/a (run too short)");
        }
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let spec = dataset_spec(args.opt_or("dataset", "mmlu"), args.opt_u64("seed", 7)?)?;
    let trace = Trace::generate(spec);
    println!("trace: {}", trace.spec.name);
    println!(
        "  batches: {}   tokens: {}",
        trace.batches.len(),
        trace.n_tokens()
    );
    println!("  avg skewness: {:.3}", trace.avg_skewness());
    let counts = trace.expert_counts();
    println!("  expert counts: {counts:?}");
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let system = parse_system(args)?;
    let spec = dataset_spec(args.opt_or("dataset", "mmlu"), args.opt_u64("seed", 7)?)?;
    let opts = CalibrationOptions {
        fast: args.flag("fast"),
        ..Default::default()
    };
    let cal = calibrate(spec, &model, &system, &opts);
    println!("{}", gps::report::figure4(&cal));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let strategy = ServeStrategy::by_name(args.opt_or("strategy", "dop"))?;
    let artifacts = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let workers = args.opt_usize("workers", 4)?;
    let phase = args.opt_or("phase", "prefill");
    let seed = args.opt_u64("seed", 11)?;
    // ADR 003: size the reference backend's shared compute pool before
    // the first engine spins up (0 = auto-detect).
    moe_gps::runtime::configure_compute_threads(args.opt_usize("threads", 0)?);
    // ADR 007: pin pool helpers to cores and keep the leader on its own
    // reserved core. Placement only decides where threads run — outputs
    // are bitwise identical pinned or unpinned.
    if args.flag("pin") {
        moe_gps::runtime::configure_pool_pinning(true);
        if !moe_gps::runtime::pool::pin_leader() {
            eprintln!(
                "warning: --pin requested but sched_setaffinity is unavailable \
                 (non-linux or sandboxed); threads will float"
            );
        }
    }
    let mut coord = Coordinator::new(&artifacts, workers, strategy)?;
    // ADR 002/004: overlap the next N layers' prediction/planning/prewarm
    // with the current layer's compute. Numerics are identical at every
    // depth; all regimes stay reproducible from the CLI.
    coord.lookahead = args.opt_usize("lookahead", 0)?;
    // ADR 004: byte budget for prewarm transfers issued per layer step
    // (deepest lookahead transfers drop first when it runs out).
    coord.prewarm_budget_bytes = args.opt_bytes("prewarm-budget")?;
    // ADR 004: per-worker cap on resident expert replica bytes — real LRU
    // eviction via WorkerMsg::Evict; bitwise-identical outputs.
    coord.set_memory_cap(args.opt_bytes("memory-cap")?);
    // ADR 009: fan per-sequence prefill attention out to the workers as
    // Arc-shared read views (decode attention always runs on the leader).
    // Bitwise identical either way; the copy counters show the traffic
    // moving from `bytes_copied` to `bytes_shared`.
    coord.parallel_attention = args.flag("parallel-attention");
    // ADR 010: micro-batch wavefront depth. K > 1 splits every round's /
    // step's slot set into K deterministic micro-batches and overlaps the
    // leader's routing/dispatch with in-flight FFN slabs. Combine order is
    // pinned to global slot order, so outputs are bitwise identical at
    // every K (1 = the serial path, literally).
    coord.microbatch = args.opt_usize("microbatch", 1)?.max(1);
    // ADR 003: speculative TEP scatter rides the lookahead pipeline.
    coord.speculative = args.flag("speculative");
    if coord.speculative {
        coord.lookahead = coord.lookahead.max(1);
    }
    // ADR 006: plan for the *forecast* distribution H replan windows
    // ahead instead of the last observed one — replicas for predicted-hot
    // experts prewarm before the spike. Horizon 0 is the reactive planner,
    // bitwise identical to not passing the flag.
    coord.placement.horizon = args.opt_usize("horizon", 0)?;
    if coord.prewarm_budget_bytes.is_some() && coord.lookahead == 0 {
        eprintln!(
            "warning: --prewarm-budget has no effect without --lookahead N \
             (no prewarm stream to budget)"
        );
    }
    // ADR 008: deterministic fault injection (chaos testing) + the reply
    // deadline override. With neither flag nor MOE_GPS_FAULTS set, serving
    // output is bitwise identical to a fault-free build.
    if let Some(spec) = args.opt("inject-faults") {
        coord.set_fault_plan(&FaultPlan::parse(spec)?);
    }
    let worker_timeout = match args.opt("worker-timeout") {
        Some(s) => Some(
            s.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad --worker-timeout `{s}`: {e}"))?,
        ),
        None => None,
    };
    coord.set_worker_timeout(worker_timeout);
    // ADR 005: `--adaptive` installs the online strategy controller — at
    // replan boundaries it re-prices DOP/TEP/speculative on constants
    // calibrated from the measured serving metrics (rolling window) and
    // switches behind hysteresis. `--system`/`--model` pick the sim the
    // decisions are priced on; `--hysteresis`/`--margin` tune stability.
    if args.flag("adaptive") {
        let ctrl_phase = if phase == "prefill" {
            ServePhase::Prefill
        } else {
            ServePhase::Decode
        };
        // Price decisions on the run's actual workload shape: the decode
        // batch is the continuous-batch size and its context is the full
        // generated depth; a prefill round's batch is its sequence count
        // at the model's sequence length.
        let (ctrl_batch, ctrl_ctx) = if ctrl_phase == ServePhase::Decode {
            // Mirror the decode branch's own defaults exactly: max_active
            // defaults to seqs.clamp(1, 8) and prompts are capped at the
            // compiled prefill bucket before serving.
            let seqs = args.opt_usize("seqs", 8)?;
            let prompt = args
                .opt_usize("prompt", (coord.seq_len() / 8).max(4))?
                .min(coord.seq_len().max(1));
            let max_new = args.opt_usize("max-new", 32)?;
            (
                args.opt_usize("max-active", seqs.clamp(1, 8))?,
                prompt + max_new,
            )
        } else {
            (args.opt_usize("seqs", 4)?, coord.seq_len())
        };
        let cfg = ControllerConfig {
            phase: ctrl_phase,
            model: parse_model(args)?,
            system: parse_system(args)?,
            hysteresis: args.opt_usize("hysteresis", 2)?,
            margin_frac: args.opt_f64("margin", 0.01)?,
            min_window: args.opt_usize("min-window", 4)?,
            window: args.opt_usize("window", 32)?,
            batch: ctrl_batch,
            seq_or_ctx: ctrl_ctx,
            // Depth bounds honour the launch configuration: the
            // controller may move the prewarm window between "off" and
            // the launched depth (or 2, whichever is larger) but never
            // silently cuts a deeper `--lookahead` the user asked for.
            min_lookahead: 0,
            max_lookahead: coord.lookahead.max(2),
            // ADR 006: the launched forecast horizon, plus the realized-
            // forecast-error threshold past which the controller falls
            // back to reactive replanning (horizon 0) for the rest of the
            // run.
            horizon: coord.placement.horizon,
            forecast_error_max: args.opt_f64("forecast-error-max", 0.5)?,
            seed,
            ..Default::default()
        };
        coord.controller = Some(StrategyController::new(cfg));
    }
    let report_path = args.opt("report").map(str::to_string);
    let write_report = |json: moe_gps::util::json::Value| -> Result<()> {
        if let Some(path) = &report_path {
            std::fs::write(path, json.to_string_pretty())
                .map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))?;
            println!("serve report written to {path}");
        }
        Ok(())
    };
    let mut gen = RequestGen::new(seed, coord.vocab());
    match phase {
        "prefill" => {
            let rounds = args.opt_usize("rounds", 8)?;
            let seqs = args.opt_usize("seqs", 4)?;
            let max_len = coord.seq_len();
            let batches: Vec<Vec<_>> = (0..rounds)
                .map(|_| {
                    (0..seqs)
                        .map(|_| gen.request_varlen(max_len / 4, max_len))
                        .collect()
                })
                .collect();
            let report = coord.serve(batches)?;
            println!("{}", report.summary());
            write_report(report.to_json())?;
        }
        "decode" | "mixed" => {
            let seqs = args.opt_usize("seqs", 8)?;
            let prompt = args.opt_usize("prompt", (coord.seq_len() / 8).max(4))?;
            let max_new = args.opt_usize("max-new", 32)?;
            coord.placement.replan_interval = args.opt_usize("replan", 4)?;
            let requests: Vec<_> = (0..seqs)
                .map(|_| gen.decode_request(prompt, max_new))
                .collect();
            let opts = DecodeOptions {
                max_active: args.opt_usize("max-active", seqs.clamp(1, 8))?,
                max_steps: args.opt_usize("steps", 256)?,
                temperature: args.opt_f64("temperature", 1.0)?,
                seed,
                // mixed: requests trickle in so steps interleave prefill
                // and decode work; decode: everything queued up front.
                arrival_interval: if phase == "mixed" {
                    args.opt_usize("arrival-every", 2)?
                } else {
                    0
                },
            };
            let report = coord.serve_decode(requests, &opts)?;
            println!("{}", report.summary());
            write_report(report.to_json())?;
        }
        other => anyhow::bail!("unknown --phase `{other}` (prefill|decode|mixed)"),
    }
    Ok(())
}

fn cmd_bench_report(args: &Args) -> Result<()> {
    let what = args
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("table1");
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let fast = args.flag("fast");
    match what {
        "table1" => {
            let cals = calibrations(&model, &system, fast, 7);
            println!("{}", gps::report::table1(&cals));
        }
        "fig4" => {
            for cal in calibrations(&model, &system, fast, 7) {
                println!("{}", gps::report::figure4(&cal));
            }
        }
        "fig6" => {
            for sys in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
                let cals = calibrations(&model, &sys, fast, 7);
                let points = gps::skew_sweep(
                    &model,
                    &sys,
                    &cals,
                    &gps::sweep::figure6_skews(),
                    1,
                    512,
                );
                println!(
                    "{}",
                    gps::report::figure6(
                        &points,
                        &format!("Figure 6 — {}", sys.interconnect.name)
                    )
                );
            }
        }
        "fig7" => {
            let mut rows = Vec::new();
            for bw in [600.0, 300.0, 128.0, 64.0] {
                let sys = SystemSpec::four_a100_custom_bw(bw);
                let cals = calibrations(&model, &sys, fast, 7);
                for skew in [1.4, 2.0, 3.0, 4.0] {
                    rows.push(gps::strategy_savings(&model, &sys, &cals, skew, 1, 512));
                }
            }
            println!("{}", gps::report::figure7(&rows));
        }
        other => anyhow::bail!("unknown report `{other}` (table1|fig4|fig6|fig7)"),
    }
    Ok(())
}

fn cmd_bench_validate(args: &Args) -> Result<()> {
    let path = std::path::PathBuf::from(
        args.positionals
            .first()
            .map(String::as_str)
            .unwrap_or(moe_gps::bench::emit::DEFAULT_PATH),
    );
    let n = moe_gps::bench::emit::validate_serve_benches(
        &path,
        args.flag("require-results"),
    )?;
    println!(
        "{}: valid `{}` file with {n} record(s)",
        path.display(),
        moe_gps::bench::emit::SCHEMA
    );
    // ADR 006: forecast-accuracy regression gate. Reads a serve report
    // (`serve --horizon H --report F.json`) and fails when the realized
    // forecast L1 exceeds the bound — the CI bench-smoke check that the
    // load forecaster has not regressed.
    if let Some(report) = args.opt("forecast-report") {
        let bound = args.opt_f64("max-forecast-l1", 0.5)?;
        let l1 = moe_gps::bench::emit::validate_forecast_error(
            std::path::Path::new(report),
            bound,
        )?;
        println!("{report}: realized forecast L1 {l1:.4} within bound {bound}");
    }
    // ADR 007: kernel-speedup gate. Fails when a vector tier recorded by
    // `cargo bench --bench kernels` is under the bound on the dot/matmul
    // kernels; a forced-scalar file is reported loudly, never silently
    // passed.
    if let Some(s) = args.opt("min-kernel-speedup") {
        let bound = s.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("--min-kernel-speedup expects a number, got `{s}`")
        })?;
        let (_, msg) = moe_gps::bench::emit::validate_kernel_speedups(&path, bound)?;
        println!("{}: {msg}", path.display());
    }
    // ADR 008: chaos gate — a fault-injected serve report must record at
    // least one worker death and zero lost sequences.
    if let Some(report) = args.opt("chaos-report") {
        let (deaths, _) = moe_gps::bench::emit::validate_chaos_report(
            std::path::Path::new(report),
        )?;
        println!(
            "{report}: chaos gate passed — {deaths} worker death(s), \
             0 sequences lost"
        );
    }
    // ADR 009: copy-accounting gate — fail when the serve report's
    // data plane deep-copied more than the allowed fraction of the bytes
    // it moved (bytes_copied / (bytes_copied + bytes_shared)).
    if let Some(report) = args.opt("copy-report") {
        let bound = args.opt_f64("max-copied-frac", 0.5)?;
        let frac = moe_gps::bench::emit::validate_copied_frac(
            std::path::Path::new(report),
            bound,
        )?;
        println!("{report}: copied fraction {frac:.4} within bound {bound}");
    }
    // ADR 010: wavefront occupancy gate — fail when a serve report's
    // window-weighted worker idle fraction exceeds the bound (workers
    // starving through router/combine stalls).
    if let Some(report) = args.opt("wavefront-report") {
        let bound = args.opt_f64("max-idle-frac", 0.95)?;
        let (idle, stall) = moe_gps::bench::emit::validate_wavefront_report(
            std::path::Path::new(report),
            bound,
        )?;
        println!(
            "{report}: worker idle fraction {idle:.4} within bound {bound} \
             (leader stall {})",
            moe_gps::util::human_time(stall)
        );
    }
    // ADR 007: stored-baseline regression gate for serve_hotpath.
    if let Some(baseline) = args.opt("baseline") {
        let max_regression = args.opt_f64("max-regression", 0.2)?;
        let (_, msg) = moe_gps::bench::emit::validate_serve_baseline(
            &path,
            std::path::Path::new(baseline),
            max_regression,
        )?;
        println!("{}: {msg}", path.display());
    }
    Ok(())
}
