//! Token-count shape buckets.
//!
//! HLO executables are static-shaped; variable routed-token counts are
//! served by padding up to the nearest compiled bucket (standard serving
//! practice — the waste is the price of AOT compilation, and the bucket
//! ladder bounds it).

/// Smallest bucket ≥ `n`, or the largest bucket if `n` exceeds all
/// (callers must then split the batch — see [`split_into_buckets`]).
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets sorted");
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().unwrap()
}

/// Split `n` tokens into chunks, each assigned a bucket: greedy largest-
/// bucket-first so a 700-token slice over buckets [64,256,512] becomes
/// [512, 256] rather than many small calls.
pub fn split_into_buckets(buckets: &[usize], n: usize) -> Vec<(usize, usize)> {
    // Returns (chunk_tokens, bucket) pairs.
    let max = *buckets.last().unwrap();
    let mut out = Vec::new();
    let mut remaining = n;
    while remaining > max {
        out.push((max, max));
        remaining -= max;
    }
    if remaining > 0 {
        out.push((remaining, pick_bucket(buckets, remaining)));
    }
    out
}

/// Fraction of compute wasted on padding for `n` tokens.
pub fn padding_waste(buckets: &[usize], n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let padded: usize = split_into_buckets(buckets, n).iter().map(|&(_, b)| b).sum();
    (padded - n) as f64 / padded as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: [usize; 4] = [64, 128, 256, 512];

    #[test]
    fn picks_smallest_fitting() {
        assert_eq!(pick_bucket(&BUCKETS, 1), 64);
        assert_eq!(pick_bucket(&BUCKETS, 64), 64);
        assert_eq!(pick_bucket(&BUCKETS, 65), 128);
        assert_eq!(pick_bucket(&BUCKETS, 512), 512);
        assert_eq!(pick_bucket(&BUCKETS, 9999), 512);
    }

    #[test]
    fn splits_oversized() {
        assert_eq!(split_into_buckets(&BUCKETS, 700), vec![(512, 512), (188, 256)]);
        assert_eq!(split_into_buckets(&BUCKETS, 1200), vec![(512, 512), (512, 512), (176, 256)]);
        assert_eq!(split_into_buckets(&BUCKETS, 64), vec![(64, 64)]);
        assert_eq!(split_into_buckets(&BUCKETS, 0), vec![]);
    }

    #[test]
    fn split_conserves_tokens() {
        for n in [1usize, 63, 64, 65, 511, 512, 513, 2000] {
            let total: usize = split_into_buckets(&BUCKETS, n).iter().map(|&(c, _)| c).sum();
            assert_eq!(total, n);
        }
    }

    #[test]
    fn waste_bounded() {
        for n in 1..600 {
            let w = padding_waste(&BUCKETS, n);
            assert!((0.0..1.0).contains(&w));
        }
        assert_eq!(padding_waste(&BUCKETS, 512), 0.0);
        assert!(padding_waste(&BUCKETS, 1) > 0.9);
    }
}
