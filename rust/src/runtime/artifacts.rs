//! Artifact manifest + weight store.
//!
//! `python/compile/aot.py` writes `manifest.json` (artifact index, weight
//! offsets/shapes, model config) and `weights.bin` (little-endian f32,
//! concatenated in manifest order). This module loads both.
//!
//! When no artifacts directory exists (no python toolchain in the build
//! environment), [`synthetic_artifacts`] generates an equivalent in-memory
//! manifest + weight set for the tiny serving model, mirroring
//! `python/compile/model.py::init_weights` — including the
//! embedding-anchored routers that give the tiny model its skewed,
//! token-identity-driven routing. The reference backend executes directly
//! against these (DESIGN.md §6).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::tensor::HostTensor;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// One artifact entry (an HLO-text file).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    /// name → (offset_bytes, shape)
    pub weights: BTreeMap<String, (usize, Vec<usize>)>,
    pub weights_file: PathBuf,
    /// Model config as raw JSON (mirrors python TINY_CONFIG).
    pub config: Value,
    pub predictor_accuracy: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("artifacts") {
            for (name, entry) in map {
                artifacts.insert(
                    name.clone(),
                    ArtifactEntry {
                        name: name.clone(),
                        file: dir.join(entry.req_str("file")?),
                    },
                );
            }
        }

        let mut weights = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("weights") {
            for (name, entry) in map {
                let offset = entry.req_usize("offset")?;
                let shape: Vec<usize> = entry
                    .get("shape")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("weight {name}: missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect();
                weights.insert(name.clone(), (offset, shape));
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            weights,
            weights_file: dir.join(
                v.get("weights_file")
                    .and_then(Value::as_str)
                    .unwrap_or("weights.bin"),
            ),
            config: v
                .get("config")
                .cloned()
                .unwrap_or_else(Value::obj),
            predictor_accuracy: v
                .get("predictor_accuracy")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
        })
    }

    pub fn artifact_path(&self, name: &str) -> Result<&Path> {
        self.artifacts
            .get(name)
            .map(|a| a.file.as_path())
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))
    }

    /// Expert-FFN bucket sizes available, ascending.
    pub fn ffn_buckets(&self) -> Vec<usize> {
        let mut buckets: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("expert_ffn_b"))
            .filter_map(|s| s.parse().ok())
            .collect();
        buckets.sort_unstable();
        buckets
    }
}

/// All weights resident in host memory; hands out `HostTensor` copies.
#[derive(Clone)]
pub struct WeightStore {
    blob: std::sync::Arc<Vec<f32>>,
    index: BTreeMap<String, (usize, Vec<usize>)>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let bytes = std::fs::read(&manifest.weights_file)
            .with_context(|| format!("reading {}", manifest.weights_file.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let mut blob = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            blob.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(WeightStore {
            blob: std::sync::Arc::new(blob),
            index: manifest.weights.clone(),
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }

    pub fn get(&self, name: &str) -> Result<HostTensor> {
        let (offset, shape) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight `{name}` not found"))?;
        let n: usize = shape.iter().product();
        let start = offset / 4;
        anyhow::ensure!(
            start + n <= self.blob.len(),
            "weight `{name}` out of bounds"
        );
        Ok(HostTensor::new(
            self.blob[start..start + n].to_vec(),
            shape.clone(),
        ))
    }

    /// Bytes of one tensor (what a duplication transfer moves).
    pub fn nbytes(&self, name: &str) -> Result<usize> {
        let (_, shape) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight `{name}` not found"))?;
        Ok(shape.iter().product::<usize>() * 4)
    }
}

/// Dimensions and seed of a synthetically-generated artifact set. `tiny()`
/// matches `python/compile/model.py::TINY_CONFIG` / `ModelConfig::tiny_serve`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SyntheticSpec {
    pub seed: u64,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_layers: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub predictor_hidden: usize,
    pub ffn_buckets: Vec<usize>,
}

impl SyntheticSpec {
    /// The tiny serving model (TINY_CONFIG dims).
    pub fn tiny() -> SyntheticSpec {
        SyntheticSpec {
            seed: 0,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 512,
            n_experts: 8,
            top_k: 2,
            n_layers: 4,
            vocab_size: 4096,
            seq_len: 256,
            predictor_hidden: 128,
            ffn_buckets: vec![16, 32, 64, 128, 256, 512],
        }
    }

    /// A scaled-down spec for fast integration tests (same topology,
    /// ~30× fewer parameters).
    pub fn small_test() -> SyntheticSpec {
        SyntheticSpec {
            seed: 0,
            d_model: 64,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            d_ff: 128,
            n_experts: 8,
            top_k: 2,
            n_layers: 2,
            vocab_size: 512,
            seq_len: 64,
            predictor_hidden: 32,
            ffn_buckets: vec![8, 16, 32, 64],
        }
    }
}

/// Generate a synthetic (manifest, weight store) pair for `spec`.
///
/// Weight initialisation mirrors `python/compile/model.py::init_weights`:
/// normal embeddings, per-layer attention projections, and
/// *embedding-anchored* routers (each expert's router column points toward
/// the embeddings of an anchor token, with a mild geometric column scale) —
/// the two properties everything downstream relies on: predictable routing
/// and a skewed expert distribution.
pub fn synthetic_artifacts(spec: &SyntheticSpec) -> (Manifest, WeightStore) {
    assert_eq!(
        spec.d_model,
        spec.n_heads * spec.head_dim,
        "d_model must equal n_heads * head_dim"
    );
    let d = spec.d_model;
    let ff = spec.d_ff;
    let e = spec.n_experts;
    let kvw = spec.n_kv_heads * spec.head_dim;
    let qw = spec.n_heads * spec.head_dim;
    let h = spec.predictor_hidden;

    let mut rng = Rng::new(spec.seed ^ 0x5EED_A21F);
    let mut blob: Vec<f32> = Vec::new();
    let mut index: BTreeMap<String, (usize, Vec<usize>)> = BTreeMap::new();

    let push = |name: &str,
                shape: Vec<usize>,
                data: Vec<f32>,
                blob: &mut Vec<f32>,
                index: &mut BTreeMap<String, (usize, Vec<usize>)>| {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        index.insert(name.to_string(), (blob.len() * 4, shape));
        blob.extend(data);
    };
    let normal = |rng: &mut Rng, n: usize, scale: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    };

    let embed = normal(&mut rng, spec.vocab_size * d, 0.3);
    push("embed", vec![spec.vocab_size, d], embed.clone(), &mut blob, &mut index);

    for l in 0..spec.n_layers {
        let p = format!("layers.{l}");
        push(&format!("{p}.attn.ln"), vec![d], vec![1.0; d], &mut blob, &mut index);
        push(
            &format!("{p}.attn.wq"),
            vec![d, qw],
            normal(&mut rng, d * qw, (d as f64).powf(-0.5)),
            &mut blob,
            &mut index,
        );
        push(
            &format!("{p}.attn.wk"),
            vec![d, kvw],
            normal(&mut rng, d * kvw, (d as f64).powf(-0.5)),
            &mut blob,
            &mut index,
        );
        push(
            &format!("{p}.attn.wv"),
            vec![d, kvw],
            normal(&mut rng, d * kvw, (d as f64).powf(-0.5)),
            &mut blob,
            &mut index,
        );
        push(
            &format!("{p}.attn.wo"),
            vec![qw, d],
            normal(&mut rng, qw * d, 0.1 * (qw as f64).powf(-0.5)),
            &mut blob,
            &mut index,
        );
        push(&format!("{p}.moe.ln"), vec![d], vec![1.0; d], &mut blob, &mut index);

        // Embedding-anchored router [d, e], row-major.
        let mut router = vec![0.0f32; d * e];
        for x in 0..e {
            let anchor_id = rng.range(0, spec.vocab_size);
            let row = &embed[anchor_id * d..(anchor_id + 1) * d];
            let norm = (row.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()).sqrt() + 1e-8;
            let col_scale = 1.15f64.powi(-(x as i32));
            for i in 0..d {
                let anchored = row[i] as f64 / norm * 4.0 + rng.normal() * 0.02;
                router[i * e + x] = (anchored * col_scale) as f32;
            }
        }
        push(&format!("{p}.moe.router"), vec![d, e], router, &mut blob, &mut index);

        for x in 0..e {
            push(
                &format!("{p}.experts.{x}.w_gate"),
                vec![d, ff],
                normal(&mut rng, d * ff, (d as f64).powf(-0.5)),
                &mut blob,
                &mut index,
            );
            push(
                &format!("{p}.experts.{x}.w_up"),
                vec![d, ff],
                normal(&mut rng, d * ff, (d as f64).powf(-0.5)),
                &mut blob,
                &mut index,
            );
            push(
                &format!("{p}.experts.{x}.w_down"),
                vec![ff, d],
                normal(&mut rng, ff * d, (ff as f64).powf(-0.5)),
                &mut blob,
                &mut index,
            );
        }
    }
    push("final.ln", vec![d], vec![1.0; d], &mut blob, &mut index);
    push(
        "predictor.w1",
        vec![d, h],
        normal(&mut rng, d * h, (2.0 / d as f64).sqrt()),
        &mut blob,
        &mut index,
    );
    push("predictor.b1", vec![h], vec![0.0; h], &mut blob, &mut index);
    for l in 0..spec.n_layers {
        push(
            &format!("predictor.head.{l}"),
            vec![h, e],
            normal(&mut rng, h * e, (2.0 / h as f64).sqrt()),
            &mut blob,
            &mut index,
        );
    }

    let dir = PathBuf::from("synthetic://");
    let mut artifacts = BTreeMap::new();
    let mut artifact_names: Vec<String> = [
        "embed",
        "attention",
        "attention_prefill",
        "attention_step",
        "router",
        "predictor",
        "lm_head",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    for b in &spec.ffn_buckets {
        artifact_names.push(format!("expert_ffn_b{b}"));
    }
    for name in artifact_names {
        artifacts.insert(
            name.clone(),
            ArtifactEntry {
                file: dir.join(format!("{name}.hlo")),
                name,
            },
        );
    }

    let mut config = Value::obj();
    config
        .set("name", Value::Str("synthetic-tiny-moe".into()))
        .set("d_model", Value::Num(d as f64))
        .set("n_heads", Value::Num(spec.n_heads as f64))
        .set("n_kv_heads", Value::Num(spec.n_kv_heads as f64))
        .set("head_dim", Value::Num(spec.head_dim as f64))
        .set("d_ff", Value::Num(ff as f64))
        .set("n_experts", Value::Num(e as f64))
        .set("top_k", Value::Num(spec.top_k as f64))
        .set("n_layers", Value::Num(spec.n_layers as f64))
        .set("vocab_size", Value::Num(spec.vocab_size as f64))
        .set("seq_len", Value::Num(spec.seq_len as f64));

    let manifest = Manifest {
        dir: dir.clone(),
        artifacts,
        weights: index.clone(),
        weights_file: dir.join("weights.bin"),
        config,
        predictor_accuracy: 0.0,
    };
    let store = WeightStore {
        blob: std::sync::Arc::new(blob),
        index,
    };
    (manifest, store)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn with_manifest(f: impl FnOnce(Manifest)) {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        f(Manifest::load(&dir).unwrap());
    }

    #[test]
    fn manifest_lists_expected_artifacts() {
        with_manifest(|m| {
            for name in ["embed", "attention", "router", "predictor"] {
                assert!(m.artifacts.contains_key(name), "{name}");
                assert!(m.artifact_path(name).unwrap().exists());
            }
            assert!(!m.ffn_buckets().is_empty());
            assert!(m.ffn_buckets().windows(2).all(|w| w[0] < w[1]));
            assert!(m.config.req_usize("d_model").unwrap() == 256);
        });
    }

    #[test]
    fn synthetic_artifacts_consistent() {
        let spec = SyntheticSpec::small_test();
        let (m, ws) = synthetic_artifacts(&spec);
        assert_eq!(m.ffn_buckets(), spec.ffn_buckets);
        assert_eq!(m.config.req_usize("d_model").unwrap(), 64);
        assert_eq!(m.config.req_usize("seq_len").unwrap(), 64);
        let embed = ws.get("embed").unwrap();
        assert_eq!(embed.shape, vec![512, 64]);
        let router = ws.get("layers.0.moe.router").unwrap();
        assert_eq!(router.shape, vec![64, 8]);
        assert!(ws.get("layers.1.experts.7.w_down").is_ok());
        assert_eq!(ws.nbytes("layers.0.experts.0.w_gate").unwrap(), 64 * 128 * 4);
        // Routers must not be all-zero (anchored init).
        assert!(router.data.iter().any(|&v| v.abs() > 0.1));
    }

    #[test]
    fn synthetic_generation_is_deterministic() {
        let spec = SyntheticSpec::small_test();
        let (_, a) = synthetic_artifacts(&spec);
        let (_, b) = synthetic_artifacts(&spec);
        assert_eq!(a.get("embed").unwrap(), b.get("embed").unwrap());
    }

    #[test]
    fn weight_store_loads_and_indexes() {
        with_manifest(|m| {
            let ws = WeightStore::load(&m).unwrap();
            let embed = ws.get("embed").unwrap();
            assert_eq!(embed.shape, vec![4096, 256]);
            let router = ws.get("layers.0.moe.router").unwrap();
            assert_eq!(router.shape, vec![256, 8]);
            assert!(ws.get("nonexistent").is_err());
            assert_eq!(ws.nbytes("layers.0.experts.0.w_gate").unwrap(), 256 * 512 * 4);
        });
    }
}
