//! Artifact manifest + weight store.
//!
//! `python/compile/aot.py` writes `manifest.json` (artifact index, weight
//! offsets/shapes, model config) and `weights.bin` (little-endian f32,
//! concatenated in manifest order). This module loads both.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::tensor::HostTensor;
use crate::util::json::Value;

/// One artifact entry (an HLO-text file).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    /// name → (offset_bytes, shape)
    pub weights: BTreeMap<String, (usize, Vec<usize>)>,
    pub weights_file: PathBuf,
    /// Model config as raw JSON (mirrors python TINY_CONFIG).
    pub config: Value,
    pub predictor_accuracy: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("artifacts") {
            for (name, entry) in map {
                artifacts.insert(
                    name.clone(),
                    ArtifactEntry {
                        name: name.clone(),
                        file: dir.join(entry.req_str("file")?),
                    },
                );
            }
        }

        let mut weights = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("weights") {
            for (name, entry) in map {
                let offset = entry.req_usize("offset")?;
                let shape: Vec<usize> = entry
                    .get("shape")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("weight {name}: missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect();
                weights.insert(name.clone(), (offset, shape));
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            weights,
            weights_file: dir.join(
                v.get("weights_file")
                    .and_then(Value::as_str)
                    .unwrap_or("weights.bin"),
            ),
            config: v
                .get("config")
                .cloned()
                .unwrap_or_else(Value::obj),
            predictor_accuracy: v
                .get("predictor_accuracy")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
        })
    }

    pub fn artifact_path(&self, name: &str) -> Result<&Path> {
        self.artifacts
            .get(name)
            .map(|a| a.file.as_path())
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))
    }

    /// Expert-FFN bucket sizes available, ascending.
    pub fn ffn_buckets(&self) -> Vec<usize> {
        let mut buckets: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("expert_ffn_b"))
            .filter_map(|s| s.parse().ok())
            .collect();
        buckets.sort_unstable();
        buckets
    }
}

/// All weights resident in host memory; hands out `HostTensor` copies.
#[derive(Clone)]
pub struct WeightStore {
    blob: std::sync::Arc<Vec<f32>>,
    index: BTreeMap<String, (usize, Vec<usize>)>,
}

impl WeightStore {
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        let bytes = std::fs::read(&manifest.weights_file)
            .with_context(|| format!("reading {}", manifest.weights_file.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "weights.bin not a multiple of 4 bytes");
        let mut blob = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            blob.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(WeightStore {
            blob: std::sync::Arc::new(blob),
            index: manifest.weights.clone(),
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }

    pub fn get(&self, name: &str) -> Result<HostTensor> {
        let (offset, shape) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight `{name}` not found"))?;
        let n: usize = shape.iter().product();
        let start = offset / 4;
        anyhow::ensure!(
            start + n <= self.blob.len(),
            "weight `{name}` out of bounds"
        );
        Ok(HostTensor::new(
            self.blob[start..start + n].to_vec(),
            shape.clone(),
        ))
    }

    /// Bytes of one tensor (what a duplication transfer moves).
    pub fn nbytes(&self, name: &str) -> Result<usize> {
        let (_, shape) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight `{name}` not found"))?;
        Ok(shape.iter().product::<usize>() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn with_manifest(f: impl FnOnce(Manifest)) {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        f(Manifest::load(&dir).unwrap());
    }

    #[test]
    fn manifest_lists_expected_artifacts() {
        with_manifest(|m| {
            for name in ["embed", "attention", "router", "predictor"] {
                assert!(m.artifacts.contains_key(name), "{name}");
                assert!(m.artifact_path(name).unwrap().exists());
            }
            assert!(!m.ffn_buckets().is_empty());
            assert!(m.ffn_buckets().windows(2).all(|w| w[0] < w[1]));
            assert!(m.config.req_usize("d_model").unwrap() == 256);
        });
    }

    #[test]
    fn weight_store_loads_and_indexes() {
        with_manifest(|m| {
            let ws = WeightStore::load(&m).unwrap();
            let embed = ws.get("embed").unwrap();
            assert_eq!(embed.shape, vec![4096, 256]);
            let router = ws.get("layers.0.moe.router").unwrap();
            assert_eq!(router.shape, vec![256, 8]);
            assert!(ws.get("nonexistent").is_err());
            assert_eq!(ws.nbytes("layers.0.experts.0.w_gate").unwrap(), 256 * 512 * 4);
        });
    }
}
