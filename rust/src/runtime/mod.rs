//! Model execution runtime: artifact manifest + weights, and two
//! interchangeable backends behind one [`Engine`] facade (DESIGN.md §6):
//!
//! * **reference** (default) — pure-rust ops matching the python oracle's
//!   semantics, executing against on-disk artifacts *or* the in-memory
//!   synthetic weight set ([`artifacts::synthetic_artifacts`]). No PJRT,
//!   no python, no artifacts directory required.
//! * **pjrt** (`--features pjrt`) — loads the HLO-text artifacts that
//!   `python/compile/aot.py` produced (`make artifacts`) and executes them
//!   through the `xla` crate (PJRT C API, CPU plugin):
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `PjRtClient::compile` → `execute_b` with device-resident weights.
//!   HLO **text** is the interchange format — jax ≥ 0.5 serialised protos
//!   use 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids (see DESIGN.md §6).
//!
//! Python is build-time only in either case: it never runs on the request
//! path.

pub mod artifacts;
pub mod bucket;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod pool;
pub mod reference;
pub mod simd;
pub mod tensor;

pub use artifacts::{synthetic_artifacts, Manifest, SyntheticSpec, WeightStore};
pub use engine::{configure_compute_threads, configure_pool_pinning, Engine, EngineSource, In};
pub use tensor::HostTensor;
