//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! produced (`make artifacts`) and executes them on the request path.
//!
//! Python is build-time only; after artifacts exist, this module plus the
//! `xla` crate (PJRT C API, CPU plugin) is the entire execution stack:
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute_b` with device-resident weights.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serialised protos
//! use 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and /opt/xla-example/README.md).

pub mod artifacts;
pub mod bucket;
pub mod engine;
pub mod tensor;

pub use artifacts::{Manifest, WeightStore};
pub use engine::{Engine, In};
pub use tensor::HostTensor;
