//! PJRT execution backend (`--features pjrt`): compiles the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` through the `xla` crate
//! and executes them with device-resident weights:
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute_b`.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 serialised protos
//! use 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (DESIGN.md §6). The vendored `xla` crate is a
//! compile-only stub; swap it for the real bindings to execute artifacts.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::artifacts::{Manifest, WeightStore};
use super::engine::In;
use super::tensor::HostTensor;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    device_weights: HashMap<String, xla::PjRtBuffer>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            executables: HashMap::new(),
            device_weights: HashMap::new(),
        })
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text for `{name}`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{name}`"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Upload a weight tensor to a device buffer; returns the bytes moved.
    /// Residency caching happens in the `Engine` facade.
    pub fn upload_weight(&mut self, store: &WeightStore, name: &str) -> Result<u64> {
        if self.device_weights.contains_key(name) {
            return Ok(0);
        }
        let host = store.get(name)?;
        // NOTE: buffer_from_host_buffer copies synchronously
        // (kImmutableOnlyDuringCall); buffer_from_host_literal transfers
        // asynchronously and would read the literal after we drop it.
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&host.data, &host.shape, None)?;
        self.device_weights.insert(name.to_string(), buf);
        Ok((host.data.len() * 4) as u64)
    }

    pub fn evict(&mut self, name: &str) -> bool {
        self.device_weights.remove(name).is_some()
    }

    /// Execute a loaded artifact. Referenced weights must already be
    /// resident (the `Engine` facade uploads them before dispatching here).
    /// The AOT path lowers with `return_tuple=True`, so the single result
    /// buffer is a tuple that we decompose.
    pub fn call(&mut self, name: &str, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        // Upload activations, then assemble &PjRtBuffer args (weights by
        // reference — zero copies on the steady-state path).
        let mut owned: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (i, input) in inputs.iter().enumerate() {
            let buf = match input {
                In::W(_) => continue,
                In::T(t) => self
                    .client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?,
                In::I(t) => self
                    .client
                    .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)?,
                // A slab sub-range view (ADR 009): the device transfer is
                // the upload itself — no extra host-side staging copy.
                In::View { data, rows, cols } => self
                    .client
                    .buffer_from_host_buffer::<f32>(data, &[*rows, *cols], None)?,
            };
            owned.push((i, buf));
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut owned_iter = owned.iter().peekable();
        for (i, input) in inputs.iter().enumerate() {
            match input {
                In::W(weight_name) => {
                    let buf = self.device_weights.get(*weight_name).ok_or_else(|| {
                        anyhow::anyhow!("weight `{weight_name}` not resident")
                    })?;
                    args.push(buf);
                }
                _ => {
                    let (idx, buf) = owned_iter.next().expect("owned buffer");
                    debug_assert_eq!(*idx, i);
                    args.push(buf);
                }
            }
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not loaded"))?;
        let result = exe.execute_b(&args)?;
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}
