//! Runtime-dispatched f32 lane kernels for the reference backend's hot
//! loops (ADR 007).
//!
//! Three primitives dominate the serve hot path — the dot products of
//! attention scores and `lm_head` logits, the AXPY row updates of the
//! blocked matmul and the attention value accumulation, and the
//! max-reduce inside softmax. This module provides each in three tiers:
//!
//! * **portable** — plain rust, the canonical definition (below);
//! * **avx2+fma** — `std::arch` x86_64 intrinsics, gated on runtime
//!   `is_x86_feature_detected!("avx2")` + `("fma")`;
//! * **neon** — `std::arch` aarch64 intrinsics (NEON is baseline on
//!   aarch64, still detected for uniformity).
//!
//! The tier is resolved **once** per process ([`active_tier`], forced at
//! compute-pool init) from the CPU plus the `MOE_GPS_SIMD` escape hatch
//! (`scalar` forces the portable tier, `native` — the default — detects).
//!
//! ## Determinism contract (the safety rail)
//!
//! Every tier computes the **identical IEEE-754 operation sequence**, so
//! results are bitwise identical across tiers — not just across thread
//! counts. This is engineered, not accidental:
//!
//! 1. Reductions (`dot`, `max_reduce`) accumulate into a fixed
//!    [`LANES`]`= 8` virtual-lane layout: lane `j` owns elements
//!    `i` with `i % 8 == j` of each full 8-block, the sub-8 tail lands in
//!    lanes `0..r`, and the lanes combine in a fixed pairwise tree
//!    ([`reduce_sum`]/[`reduce_max`]). The portable tier implements this
//!    layout in scalar code; AVX2 maps it onto one 8-wide register and
//!    NEON onto two 4-wide registers — same lanes, same order.
//! 2. **No fused multiply-add.** The vector tiers use explicit
//!    mul-then-add (`_mm256_mul_ps` + `_mm256_add_ps`, `vmulq_f32` +
//!    `vaddq_f32`), never `fmadd`/`fmla`: fusion skips the intermediate
//!    rounding and would break cross-tier bitwise identity for a gain
//!    that is negligible on these load-bound kernels. (The x86 tier still
//!    requires the `fma` CPU flag so the choice can be revisited
//!    per-tier; the contract test in `tests/tiled_backend.rs` is what
//!    would have to change.)
//! 3. `max_reduce`'s lane op is `if m > v { m } else { v }` — exactly
//!    `_mm256_max_ps(m, v)` semantics (unordered compare picks `v`), and
//!    the NEON tier uses a compare+select (`vcgtq`/`vbslq`) instead of
//!    `vmaxq_f32` (IEEE maxNum), which would disagree on NaN inputs.
//! 4. `axpy` is elementwise (`y[i] += a * x[i]`): each output element's
//!    op sequence is one mul and one add in every tier, so it is bitwise
//!    identical even to the pre-SIMD scalar loop — which is why the
//!    AXPY-based matmul still bitwise-matches the seed's naive ikj
//!    kernel (`tests/tiled_backend.rs`).
//!
//! Note the canonical *dot* order differs from a plain sequential sum:
//! switching the attention/lm_head dots onto these kernels changed their
//! low bits once, at this PR — determinism is against the canonical
//! order, not against history.

use std::sync::OnceLock;

/// Virtual accumulator lanes of the canonical reduction layout. Fixed at
/// 8 (one AVX2 register, two NEON registers) on every tier and every
/// arch — changing it changes numerics.
pub const LANES: usize = 8;

/// Dispatch tier, resolved once per process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Portable scalar implementation of the canonical lane layout.
    Scalar,
    /// x86_64 AVX2 (8-wide f32) with the FMA CPU flag present (fusion
    /// deliberately unused — see the determinism contract).
    Avx2Fma,
    /// aarch64 NEON (2 × 4-wide f32).
    Neon,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2Fma => "avx2+fma",
            Tier::Neon => "neon",
        }
    }
}

static TIER: OnceLock<Tier> = OnceLock::new();

/// Parse the `MOE_GPS_SIMD` escape hatch: `Some(tier)` for a forced
/// tier, `None` for native detection.
fn parse_simd_env(v: &str) -> Result<Option<Tier>, ()> {
    match v.trim().to_ascii_lowercase().as_str() {
        "scalar" => Ok(Some(Tier::Scalar)),
        "native" | "" => Ok(None),
        _ => Err(()),
    }
}

fn detect() -> Tier {
    match std::env::var("MOE_GPS_SIMD") {
        Ok(v) => match parse_simd_env(&v) {
            Ok(Some(forced)) => return forced,
            Ok(None) => {}
            Err(()) => eprintln!(
                "warning: MOE_GPS_SIMD=`{v}` not recognised (scalar|native); \
                 using native detection"
            ),
        },
        Err(_) => {}
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Tier::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Tier::Neon;
        }
    }
    Tier::Scalar
}

/// The dispatch tier every kernel in this module routes through. Resolved
/// on first call (the compute pool forces it at init) and fixed for the
/// process — per-call dispatch is one predictable branch on a loaded
/// static.
pub fn active_tier() -> Tier {
    *TIER.get_or_init(detect)
}

// ---------------------------------------------------------------------
// Canonical (portable) kernels — the definition the vector tiers must
// reproduce bit-for-bit.
// ---------------------------------------------------------------------

/// Fold the sub-8 tail into lanes `0..tail.len()` (dot flavour).
#[inline]
fn tail_dot(lanes: &mut [f32; LANES], a: &[f32], b: &[f32]) {
    for (j, (&av, &bv)) in a.iter().zip(b).enumerate() {
        lanes[j] += av * bv;
    }
}

/// Fixed pairwise reduction tree over the 8 lanes — part of the
/// cross-tier bitwise contract.
#[inline]
fn reduce_sum(l: &[f32; LANES]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

/// The max lane op shared by every tier: strict greater-than select,
/// matching `_mm256_max_ps(m, v)` (an unordered compare picks `v`).
#[inline]
fn lane_max(m: f32, v: f32) -> f32 {
    if m > v {
        m
    } else {
        v
    }
}

#[inline]
fn reduce_max(l: &[f32; LANES]) -> f32 {
    let s0 = lane_max(l[0], l[4]);
    let s1 = lane_max(l[1], l[5]);
    let s2 = lane_max(l[2], l[6]);
    let s3 = lane_max(l[3], l[7]);
    lane_max(lane_max(s0, s2), lane_max(s1, s3))
}

/// Portable canonical dot product over `min(a.len(), b.len())` elements.
pub fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let blocks = n / LANES;
    let mut lanes = [0.0f32; LANES];
    for i in 0..blocks {
        let base = i * LANES;
        for j in 0..LANES {
            lanes[j] += a[base + j] * b[base + j];
        }
    }
    tail_dot(&mut lanes, &a[blocks * LANES..n], &b[blocks * LANES..n]);
    reduce_sum(&lanes)
}

/// Portable canonical AXPY: `y[i] += alpha * x[i]` over
/// `min(x.len(), y.len())` elements. Elementwise, so bitwise identical
/// in every tier by construction.
pub fn axpy_portable(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (o, &v) in y.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// Portable canonical max-reduce. Empty input yields `NEG_INFINITY`
/// (softmax over zero scores never happens on the hot path).
pub fn max_reduce_portable(xs: &[f32]) -> f32 {
    let blocks = xs.len() / LANES;
    let mut lanes = [f32::NEG_INFINITY; LANES];
    for i in 0..blocks {
        let base = i * LANES;
        for j in 0..LANES {
            lanes[j] = lane_max(lanes[j], xs[base + j]);
        }
    }
    for (j, &v) in xs[blocks * LANES..].iter().enumerate() {
        lanes[j] = lane_max(lanes[j], v);
    }
    reduce_max(&lanes)
}

// ---------------------------------------------------------------------
// x86_64 AVX2 tier.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{reduce_max, reduce_sum, tail_dot, LANES};
    use std::arch::x86_64::*;

    // SAFETY (all fns): caller guarantees AVX2 is available (the `fma`
    // flag is part of the tier gate but fused ops are never emitted —
    // see the module-level determinism contract).

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let blocks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for i in 0..blocks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * LANES));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * LANES));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_dot(&mut lanes, &a[blocks * LANES..n], &b[blocks * LANES..n]);
        reduce_sum(&lanes)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let blocks = n / LANES;
        let va = _mm256_set1_ps(alpha);
        for i in 0..blocks {
            let p = y.as_mut_ptr().add(i * LANES);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i * LANES));
            let vy = _mm256_loadu_ps(p);
            _mm256_storeu_ps(p, _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for (o, &v) in y[blocks * LANES..n].iter_mut().zip(&x[blocks * LANES..n]) {
            *o += alpha * v;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn max_reduce(xs: &[f32]) -> f32 {
        let blocks = xs.len() / LANES;
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        for i in 0..blocks {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i * LANES));
            // (acc > v) ? acc : v — the canonical lane op.
            acc = _mm256_max_ps(acc, v);
        }
        let mut lanes = [f32::NEG_INFINITY; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (j, &v) in xs[blocks * LANES..].iter().enumerate() {
            lanes[j] = super::lane_max(lanes[j], v);
        }
        reduce_max(&lanes)
    }
}

// ---------------------------------------------------------------------
// aarch64 NEON tier: the canonical 8 lanes as two 4-wide registers
// (acc0 = lanes 0..4, acc1 = lanes 4..8).
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{lane_max, reduce_max, reduce_sum, tail_dot, LANES};
    use std::arch::aarch64::*;

    // SAFETY (all fns): caller guarantees NEON is available. Fused
    // `fmla` (vfmaq/vmlaq) is never emitted — mul-then-add only, per the
    // module-level determinism contract.

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let blocks = n / LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        for i in 0..blocks {
            let pa = a.as_ptr().add(i * LANES);
            let pb = b.as_ptr().add(i * LANES);
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        tail_dot(&mut lanes, &a[blocks * LANES..n], &b[blocks * LANES..n]);
        reduce_sum(&lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let blocks = n / LANES;
        let va = vdupq_n_f32(alpha);
        for i in 0..blocks {
            let px = x.as_ptr().add(i * LANES);
            let py = y.as_mut_ptr().add(i * LANES);
            vst1q_f32(py, vaddq_f32(vld1q_f32(py), vmulq_f32(va, vld1q_f32(px))));
            let py4 = py.add(4);
            vst1q_f32(py4, vaddq_f32(vld1q_f32(py4), vmulq_f32(va, vld1q_f32(px.add(4)))));
        }
        for (o, &v) in y[blocks * LANES..n].iter_mut().zip(&x[blocks * LANES..n]) {
            *o += alpha * v;
        }
    }

    /// Canonical max lane op on a 4-wide register: strict greater-than
    /// compare + select (`vmaxq_f32` is IEEE maxNum and would disagree
    /// with the other tiers on NaN inputs).
    #[inline]
    unsafe fn vmax_sel(m: float32x4_t, v: float32x4_t) -> float32x4_t {
        vbslq_f32(vcgtq_f32(m, v), m, v)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn max_reduce(xs: &[f32]) -> f32 {
        let blocks = xs.len() / LANES;
        let mut acc0 = vdupq_n_f32(f32::NEG_INFINITY);
        let mut acc1 = vdupq_n_f32(f32::NEG_INFINITY);
        for i in 0..blocks {
            let p = xs.as_ptr().add(i * LANES);
            acc0 = vmax_sel(acc0, vld1q_f32(p));
            acc1 = vmax_sel(acc1, vld1q_f32(p.add(4)));
        }
        let mut lanes = [f32::NEG_INFINITY; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        for (j, &v) in xs[blocks * LANES..].iter().enumerate() {
            lanes[j] = lane_max(lanes[j], v);
        }
        reduce_max(&lanes)
    }
}

// ---------------------------------------------------------------------
// Dispatched entry points — what the reference backend calls.
// ---------------------------------------------------------------------

/// Dot product over `min(a.len(), b.len())` elements, canonical lane
/// order, dispatched to the active tier. Bitwise identical across tiers
/// and thread counts.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { x86::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { arm::dot(a, b) },
        _ => dot_portable(a, b),
    }
}

/// `y[i] += alpha * x[i]` over `min(x.len(), y.len())` elements,
/// dispatched. Elementwise — bitwise identical across tiers, thread
/// counts, and the pre-SIMD scalar loop.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { x86::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { arm::axpy(alpha, x, y) },
        _ => axpy_portable(alpha, x, y),
    }
}

/// Max over `xs` in the canonical lane order (`NEG_INFINITY` on empty),
/// dispatched. Bitwise identical across tiers — including the NaN select
/// semantics (see the module docs).
#[inline]
pub fn max_reduce(xs: &[f32]) -> f32 {
    match active_tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2Fma => unsafe { x86::max_reduce(xs) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { arm::max_reduce(xs) },
        _ => max_reduce_portable(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn buf(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Lengths straddling every block/tail boundary of the 8-lane layout.
    const GRID: &[usize] = &[
        0, 1, 2, 3, 5, 7, 8, 9, 13, 15, 16, 17, 23, 31, 32, 33, 63, 64, 65, 100, 127, 128, 129,
        1000, 4099,
    ];

    #[test]
    fn dispatched_dot_bitwise_matches_portable_on_grid() {
        let mut rng = Rng::new(0x51AD);
        for &n in GRID {
            let a = buf(&mut rng, n);
            let b = buf(&mut rng, n);
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_portable(&a, &b).to_bits(),
                "len {n} (tier {})",
                active_tier().name()
            );
        }
    }

    #[test]
    fn dispatched_axpy_bitwise_matches_portable_on_grid() {
        let mut rng = Rng::new(0xA390);
        for &n in GRID {
            let x = buf(&mut rng, n);
            let mut y1 = buf(&mut rng, n);
            let mut y2 = y1.clone();
            axpy(0.37, &x, &mut y1);
            axpy_portable(0.37, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert_eq!(a.to_bits(), b.to_bits(), "len {n}");
            }
        }
    }

    #[test]
    fn dispatched_max_bitwise_matches_portable_on_grid() {
        let mut rng = Rng::new(0x3A8);
        for &n in GRID {
            let xs = buf(&mut rng, n);
            assert_eq!(
                max_reduce(&xs).to_bits(),
                max_reduce_portable(&xs).to_bits(),
                "len {n}"
            );
        }
    }

    #[test]
    fn non_finite_inputs_stay_bitwise_identical_across_tiers() {
        // Garbage in, identical garbage out — the NaN/Inf select and
        // accumulate semantics are part of the cross-tier contract.
        let mut rng = Rng::new(99);
        let mut a = buf(&mut rng, 67);
        let b = buf(&mut rng, 67);
        a[3] = f32::NAN;
        a[20] = f32::INFINITY;
        a[66] = f32::NEG_INFINITY;
        assert_eq!(dot(&a, &b).to_bits(), dot_portable(&a, &b).to_bits());
        assert_eq!(max_reduce(&a).to_bits(), max_reduce_portable(&a).to_bits());
        let mut y1 = b.clone();
        let mut y2 = b.clone();
        axpy(f32::NAN, &a, &mut y1);
        axpy_portable(f32::NAN, &a, &mut y2);
        for (x, y) in y1.iter().zip(&y2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dot_matches_reference_value_to_tolerance() {
        // Lane reordering must not change the mathematical value beyond
        // f32 noise.
        let a: Vec<f32> = (0..100).map(|i| (i as f32) * 0.25).collect();
        let b: Vec<f32> = (0..100).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let exact: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        assert!((dot(&a, &b) as f64 - exact).abs() < 1e-2 * exact.abs().max(1.0));
    }

    #[test]
    fn empty_inputs_are_identities() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(max_reduce(&[]), f32::NEG_INFINITY);
        let mut y: [f32; 0] = [];
        axpy(2.0, &[], &mut y);
    }

    #[test]
    fn max_reduce_finds_the_max_wherever_it_hides() {
        for &n in GRID {
            if n == 0 {
                continue;
            }
            for pos in [0, n / 2, n - 1] {
                let mut xs = vec![-1.0f32; n];
                xs[pos] = 42.5;
                assert_eq!(max_reduce(&xs), 42.5, "len {n} pos {pos}");
            }
        }
    }

    #[test]
    fn nan_poisoning_is_transient_under_select_semantics() {
        // lane op (m > v) ? m : v: a NaN *candidate* poisons the lane
        // until the next real value replaces it (unordered picks v).
        assert_eq!(max_reduce(&[1.0, f32::NAN, 3.0]).to_bits(), 3.0f32.to_bits());
    }

    #[test]
    fn env_escape_hatch_parses() {
        assert_eq!(parse_simd_env("scalar"), Ok(Some(Tier::Scalar)));
        assert_eq!(parse_simd_env(" SCALAR "), Ok(Some(Tier::Scalar)));
        assert_eq!(parse_simd_env("native"), Ok(None));
        assert_eq!(parse_simd_env(""), Ok(None));
        assert_eq!(parse_simd_env("avx512"), Err(()));
    }

    #[test]
    fn tier_is_stable_and_named() {
        let t = active_tier();
        assert_eq!(t, active_tier(), "tier must resolve once");
        assert!(!t.name().is_empty());
        // The escape hatch must actually have taken effect when set.
        if std::env::var("MOE_GPS_SIMD").as_deref() == Ok("scalar") {
            assert_eq!(t, Tier::Scalar);
        }
    }
}
