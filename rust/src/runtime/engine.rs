//! The PJRT execution engine: compile-once, execute-many.
//!
//! One `Engine` wraps one `PjRtClient` (CPU). Executables are compiled from
//! HLO text on first use and cached; weights are uploaded to device-resident
//! buffers once and referenced by name afterwards, so the request path only
//! moves activations (`execute_b`).
//!
//! `PjRtClient` is not `Send` — each coordinator worker thread owns its own
//! `Engine`, which is exactly the "one engine per virtual GPU" topology the
//! serving driver simulates.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::{Manifest, WeightStore};
use super::tensor::{HostTensor, IntTensor};

/// An input to [`Engine::call`]: a named device-resident weight, a host
/// activation tensor, or host int tensor (token ids).
pub enum In<'a> {
    /// Device-resident weight, uploaded once via [`Engine::upload_weight`].
    W(&'a str),
    /// Host activation (uploaded per call).
    T(&'a HostTensor),
    /// Host int32 tensor (uploaded per call).
    I(&'a IntTensor),
}

pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    device_weights: HashMap<String, xla::PjRtBuffer>,
    manifest: Manifest,
    weights: WeightStore,
    /// Bytes uploaded as weights (duplication-transfer accounting).
    pub weight_bytes_uploaded: u64,
}

impl Engine {
    /// Create an engine over the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = WeightStore::load(&manifest)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            executables: HashMap::new(),
            device_weights: HashMap::new(),
            manifest,
            weights,
            weight_bytes_uploaded: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn weight_store(&self) -> &WeightStore {
        &self.weights
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text for `{name}`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{name}`"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Upload a weight tensor to the device (no-op if already resident).
    /// Returns the bytes moved (0 if cached) — the coordinator charges this
    /// as the duplication transfer.
    pub fn upload_weight(&mut self, name: &str) -> Result<u64> {
        if self.device_weights.contains_key(name) {
            return Ok(0);
        }
        let host = self.weights.get(name)?;
        // NOTE: buffer_from_host_buffer copies synchronously
        // (kImmutableOnlyDuringCall); buffer_from_host_literal transfers
        // asynchronously and would read the literal after we drop it.
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&host.data, &host.shape, None)?;
        self.device_weights.insert(name.to_string(), buf);
        let bytes = (host.data.len() * 4) as u64;
        self.weight_bytes_uploaded += bytes;
        Ok(bytes)
    }

    /// Drop a device-resident weight (capacity eviction).
    pub fn evict_weight(&mut self, name: &str) -> bool {
        self.device_weights.remove(name).is_some()
    }

    pub fn resident_weights(&self) -> usize {
        self.device_weights.len()
    }

    /// Execute an artifact. Outputs are returned as host tensors (the AOT
    /// path lowers with `return_tuple=True`, so the single result buffer is
    /// a tuple that we decompose).
    pub fn call(&mut self, name: &str, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        self.load(name)?;
        // First pass: make sure every referenced weight is resident.
        for input in inputs {
            if let In::W(weight_name) = input {
                self.upload_weight(weight_name)?;
            }
        }
        // Second pass: upload activations, then assemble &PjRtBuffer args
        // (weights by reference — zero copies on the steady-state path).
        let mut owned: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (i, input) in inputs.iter().enumerate() {
            let buf = match input {
                In::W(_) => continue,
                In::T(t) => self
                    .client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?,
                In::I(t) => self
                    .client
                    .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)?,
            };
            owned.push((i, buf));
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut owned_iter = owned.iter().peekable();
        for (i, input) in inputs.iter().enumerate() {
            match input {
                In::W(weight_name) => args.push(&self.device_weights[*weight_name]),
                _ => {
                    let (idx, buf) = owned_iter.next().expect("owned buffer");
                    debug_assert_eq!(*idx, i);
                    args.push(buf);
                }
            }
        }
        let exe = self.executables.get(name).expect("loaded above");
        let result = exe.execute_b(&args)?;
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn with_engine(f: impl FnOnce(Engine)) {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        f(Engine::new(&dir).unwrap());
    }

    #[test]
    fn engine_loads_and_runs_expert_ffn() {
        with_engine(|mut engine| {
            let bucket = engine.manifest().ffn_buckets()[0];
            let name = format!("expert_ffn_b{bucket}");
            let x = HostTensor::zeros(&[bucket, 256]);
            let out = engine
                .call(
                    &name,
                    &[
                        In::T(&x),
                        In::W("layers.0.experts.0.w_gate"),
                        In::W("layers.0.experts.0.w_up"),
                        In::W("layers.0.experts.0.w_down"),
                    ],
                )
                .unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].shape, vec![bucket, 256]);
            // Zero input → zero output for SwiGLU.
            assert!(out[0].data.iter().all(|&v| v == 0.0));
            // Weight upload accounting: 3 expert matrices resident.
            assert_eq!(engine.resident_weights(), 3);
            assert!(engine.weight_bytes_uploaded > 0);
        });
    }

    #[test]
    fn weight_upload_is_cached() {
        with_engine(|mut engine| {
            let first = engine.upload_weight("layers.0.experts.0.w_gate").unwrap();
            assert_eq!(first as usize, 256 * 512 * 4);
            let second = engine.upload_weight("layers.0.experts.0.w_gate").unwrap();
            assert_eq!(second, 0, "second upload must be a cache hit");
            assert!(engine.evict_weight("layers.0.experts.0.w_gate"));
            assert!(!engine.evict_weight("layers.0.experts.0.w_gate"));
        });
    }
}
