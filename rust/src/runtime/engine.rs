//! The execution engine: compile-once, execute-many.
//!
//! One `Engine` wraps one execution backend plus the manifest/weight store:
//!
//! * **Reference** (default) — the pure-rust op implementations in
//!   [`super::reference`], executing directly against host weights. Works
//!   with on-disk artifacts *or* the in-memory synthetic weight set, which
//!   is what lets serving run in environments without PJRT or python.
//! * **PJRT** (`--features pjrt`) — compiles the AOT HLO-text artifacts
//!   through the `xla` crate and executes them on device buffers
//!   (`runtime::pjrt`). `PjRtClient` is not `Send` — each coordinator
//!   worker thread owns its own `Engine`, which is exactly the "one engine
//!   per virtual GPU" topology the serving driver simulates.
//!
//! Weight-residency accounting is backend-independent: `upload_weight`
//! returns the bytes moved on a cold upload (0 on a cache hit) — the
//! coordinator charges this as the paper's duplication transfer.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::Result;

use super::artifacts::{synthetic_artifacts, Manifest, SyntheticSpec, WeightStore};
use super::reference::ReferenceBackend;
use super::tensor::{HostTensor, IntTensor};

/// Configure the reference backend's shared compute thread pool (ADR
/// 003): `n` total threads (helpers + caller), 0 = auto-detect. Must run
/// before the first engine op executes — the pool is created lazily on
/// first use and its size is then fixed for the process. The CLI plumbs
/// `serve --threads N` here; `MOE_GPS_THREADS` works for benches/tests.
/// Numerics are bitwise independent of the thread count (every parallel
/// op partitions its output rows and runs the identical serial kernel
/// per row).
pub fn configure_compute_threads(n: usize) {
    super::pool::configure_threads(n);
}

/// Enable core pinning for the compute pool (the CLI's `serve --pin`).
/// Must run before the pool's first use, like [`configure_compute_threads`];
/// degrades to a no-op where `sched_setaffinity` is unavailable (ADR 007).
pub fn configure_pool_pinning(on: bool) {
    super::pool::configure_pinning(on);
}

/// An input to [`Engine::call`]: a named device-resident weight, a host
/// activation tensor, or host int tensor (token ids).
#[derive(Clone, Copy)]
pub enum In<'a> {
    /// Device-resident weight, uploaded once via [`Engine::upload_weight`].
    W(&'a str),
    /// Host activation (uploaded per call).
    T(&'a HostTensor),
    /// Host int32 tensor (uploaded per call).
    I(&'a IntTensor),
    /// Borrowed row-major `[rows, cols]` activation view — a sub-range of
    /// a larger slab (one group of a coalesced `WorkerMsg::RunBatch`), so
    /// batched FFN calls need no per-group tensor copy (ADR 009).
    View {
        data: &'a [f32],
        rows: usize,
        cols: usize,
    },
}

/// Where an engine's model comes from. Cheap to clone and `Send`, so the
/// coordinator can hand one to every worker thread.
#[derive(Clone, Debug)]
pub enum EngineSource {
    /// An AOT artifacts directory (PJRT backend when the `pjrt` feature is
    /// enabled, reference backend otherwise).
    Artifacts(PathBuf),
    /// In-memory synthetic weights (always the reference backend).
    Synthetic(SyntheticSpec),
}

impl EngineSource {
    /// Prefer on-disk artifacts; fall back to the synthetic tiny model when
    /// `dir` holds no manifest (no python/PJRT toolchain in this build).
    pub fn detect(dir: &Path) -> EngineSource {
        if dir.join("manifest.json").exists() {
            EngineSource::Artifacts(dir.to_path_buf())
        } else {
            EngineSource::Synthetic(SyntheticSpec::tiny())
        }
    }

    pub fn is_synthetic(&self) -> bool {
        matches!(self, EngineSource::Synthetic(_))
    }
}

enum Backend {
    Reference(ReferenceBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::PjrtBackend),
}

pub struct Engine {
    manifest: Manifest,
    weights: WeightStore,
    backend: Backend,
    /// Weight names currently device-resident (duplication accounting).
    resident: HashSet<String>,
    /// Artifact names already compiled/validated.
    loaded: HashSet<String>,
    /// Bytes uploaded as weights (duplication-transfer accounting).
    pub weight_bytes_uploaded: u64,
}

/// The default tiny synthetic weight set, generated once per process and
/// shared by every engine (leader + all virtual-GPU workers) via `Arc`.
static TINY_SYNTH: OnceLock<(Manifest, WeightStore)> = OnceLock::new();

impl Engine {
    /// Create an engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = WeightStore::load(&manifest)?;
        Engine::assemble_for_artifacts(manifest, weights)
    }

    /// Create a reference-backend engine over synthetic weights.
    pub fn synthetic(spec: &SyntheticSpec) -> Result<Engine> {
        let (manifest, weights) = if *spec == SyntheticSpec::tiny() {
            TINY_SYNTH
                .get_or_init(|| synthetic_artifacts(spec))
                .clone()
        } else {
            synthetic_artifacts(spec)
        };
        Engine::assemble_reference(manifest, weights)
    }

    /// Create an engine from a resolved source.
    pub fn from_source(source: &EngineSource) -> Result<Engine> {
        match source {
            EngineSource::Artifacts(dir) => Engine::new(dir),
            EngineSource::Synthetic(spec) => Engine::synthetic(spec),
        }
    }

    #[cfg(feature = "pjrt")]
    fn assemble_for_artifacts(manifest: Manifest, weights: WeightStore) -> Result<Engine> {
        Ok(Engine {
            manifest,
            weights,
            backend: Backend::Pjrt(super::pjrt::PjrtBackend::new()?),
            resident: HashSet::new(),
            loaded: HashSet::new(),
            weight_bytes_uploaded: 0,
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn assemble_for_artifacts(manifest: Manifest, weights: WeightStore) -> Result<Engine> {
        Engine::assemble_reference(manifest, weights)
    }

    fn assemble_reference(manifest: Manifest, weights: WeightStore) -> Result<Engine> {
        let backend = Backend::Reference(ReferenceBackend::new(&manifest)?);
        Ok(Engine {
            manifest,
            weights,
            backend,
            resident: HashSet::new(),
            loaded: HashSet::new(),
            weight_bytes_uploaded: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn weight_store(&self) -> &WeightStore {
        &self.weights
    }

    /// Compile (and cache) an artifact by name. The reference backend
    /// resolves ops lazily, so this only validates eagerly under PJRT.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains(name) {
            return Ok(());
        }
        match &mut self.backend {
            Backend::Reference(_) => {}
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.load(&self.manifest, name)?,
        }
        self.loaded.insert(name.to_string());
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains(name)
    }

    /// Upload a weight tensor to the device (no-op if already resident).
    /// Returns the bytes moved (0 if cached) — the coordinator charges this
    /// as the duplication transfer.
    pub fn upload_weight(&mut self, name: &str) -> Result<u64> {
        if self.resident.contains(name) {
            return Ok(0);
        }
        let bytes = match &mut self.backend {
            Backend::Reference(_) => self.weights.nbytes(name)? as u64,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.upload_weight(&self.weights, name)?,
        };
        self.resident.insert(name.to_string());
        self.weight_bytes_uploaded += bytes;
        Ok(bytes)
    }

    /// Drop a device-resident weight (capacity eviction).
    pub fn evict_weight(&mut self, name: &str) -> bool {
        let was_resident = self.resident.remove(name);
        #[cfg(feature = "pjrt")]
        if let Backend::Pjrt(p) = &mut self.backend {
            p.evict(name);
        }
        was_resident
    }

    pub fn resident_weights(&self) -> usize {
        self.resident.len()
    }

    /// Execute an artifact. Outputs are returned as host tensors.
    pub fn call(&mut self, name: &str, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        self.load(name)?;
        // Make sure every referenced weight is resident first (this is the
        // duplication transfer when the planner routed a replica here).
        for input in inputs {
            if let In::W(weight_name) = input {
                self.upload_weight(weight_name)?;
            }
        }
        match &mut self.backend {
            Backend::Reference(r) => r.call(&self.weights, name, inputs),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.call(name, inputs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn with_engine(f: impl FnOnce(Engine)) {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        f(Engine::new(&dir).unwrap());
    }

    #[test]
    fn engine_loads_and_runs_expert_ffn() {
        with_engine(|mut engine| {
            let bucket = engine.manifest().ffn_buckets()[0];
            let name = format!("expert_ffn_b{bucket}");
            let x = HostTensor::zeros(&[bucket, 256]);
            let out = engine
                .call(
                    &name,
                    &[
                        In::T(&x),
                        In::W("layers.0.experts.0.w_gate"),
                        In::W("layers.0.experts.0.w_up"),
                        In::W("layers.0.experts.0.w_down"),
                    ],
                )
                .unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].shape, vec![bucket, 256]);
            // Zero input → zero output for SwiGLU.
            assert!(out[0].data.iter().all(|&v| v == 0.0));
            // Weight upload accounting: 3 expert matrices resident.
            assert_eq!(engine.resident_weights(), 3);
            assert!(engine.weight_bytes_uploaded > 0);
        });
    }

    #[test]
    fn weight_upload_is_cached() {
        with_engine(|mut engine| {
            let first = engine.upload_weight("layers.0.experts.0.w_gate").unwrap();
            assert_eq!(first as usize, 256 * 512 * 4);
            let second = engine.upload_weight("layers.0.experts.0.w_gate").unwrap();
            assert_eq!(second, 0, "second upload must be a cache hit");
            assert!(engine.evict_weight("layers.0.experts.0.w_gate"));
            assert!(!engine.evict_weight("layers.0.experts.0.w_gate"));
        });
    }

    #[test]
    fn synthetic_engine_serves_the_op_set() {
        let mut engine = Engine::synthetic(&SyntheticSpec::small_test()).unwrap();
        assert_eq!(engine.manifest().ffn_buckets(), vec![8, 16, 32, 64]);
        let ids = crate::runtime::tensor::IntTensor::new(vec![1, 2, 3], vec![1, 3]);
        let x0 = engine
            .call("embed", &[In::I(&ids), In::W("embed")])
            .unwrap()
            .remove(0);
        assert_eq!(x0.shape, vec![3, 64]);
        let h = engine
            .call(
                "attention",
                &[
                    In::T(&x0),
                    In::W("layers.0.attn.ln"),
                    In::W("layers.0.attn.wq"),
                    In::W("layers.0.attn.wk"),
                    In::W("layers.0.attn.wv"),
                    In::W("layers.0.attn.wo"),
                ],
            )
            .unwrap()
            .remove(0);
        assert_eq!(h.shape, vec![3, 64]);
        let out = engine
            .call(
                "router",
                &[In::T(&h), In::W("layers.0.moe.ln"), In::W("layers.0.moe.router")],
            )
            .unwrap();
        assert_eq!(out[1].shape, vec![3, 8]);
        // Upload accounting works for the reference backend too.
        assert!(engine.weight_bytes_uploaded > 0);
        let again = engine.upload_weight("embed").unwrap();
        assert_eq!(again, 0);
    }

    #[test]
    fn engine_source_detection_falls_back_to_synthetic() {
        let src = EngineSource::detect(Path::new("definitely/not/a/real/dir"));
        assert!(src.is_synthetic());
        let engine = Engine::from_source(&src).unwrap();
        assert_eq!(engine.manifest().config.req_usize("d_model").unwrap(), 256);
    }
}
