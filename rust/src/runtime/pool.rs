//! Shared persistent compute thread pool for the reference backend
//! (ADR 003).
//!
//! The pure-rust ops in [`super::reference`] parallelise their row/head
//! loops over this pool instead of spawning threads per call. Design
//! constraints, in order:
//!
//! 1. **Determinism** — every parallel op partitions its *output* into
//!    disjoint chunks and computes each chunk with the identical serial
//!    kernel, so results are bitwise independent of the thread count and
//!    of which thread ran which chunk. The pool only decides *where* a
//!    chunk runs, never *how* it accumulates.
//! 2. **No allocation on the steady path** beyond one job box per helper
//!    per call — work is distributed by an atomic task counter, not by
//!    queueing one closure per task.
//! 3. **No nesting deadlocks** — a task that (transitively) calls back
//!    into the pool runs its inner loop serially (`IN_POOL_TASK` guard),
//!    and the calling thread always participates in its own call's work,
//!    so a call can complete even if every helper is busy elsewhere.
//!    Concurrent calls from different threads (the leader engine plus the
//!    virtual-GPU workers) interleave safely: each call waits only on its
//!    own completion tokens.
//!
//! Thread count: [`configure_threads`] before first use (the CLI's
//! `serve --threads N`), else `MOE_GPS_THREADS`, else
//! `available_parallelism`. The pool is created lazily on first use and
//! lives for the process.
//!
//! **Placement (ADR 007).** With [`configure_pinning`] enabled before
//! first use, each helper thread pins itself to its own core via
//! `sched_setaffinity` (linux; no-op elsewhere), and the *leader* core —
//! the first allowed CPU — is left out of the helper assignment so the
//! calling thread ([`pin_leader`], the CLI's `serve --pin`) keeps a core
//! to itself instead of migrating under the helpers. Pinning decides
//! *where* threads run, never how chunks accumulate: outputs are bitwise
//! identical pinned or unpinned (`tests/pinned_pool.rs`). The SIMD
//! dispatch tier ([`super::simd`]) is also resolved here, once, at pool
//! init.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Thin wrappers over the glibc affinity calls. `std` already links
/// libc on linux, so the symbols resolve without a libc crate
/// dependency (the offline build bakes no registry).
#[cfg(target_os = "linux")]
mod affinity {
    /// 1024-bit `cpu_set_t` as 16 u64 words.
    const SET_WORDS: usize = 16;

    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }

    /// CPU ids the current thread may run on, ascending. `None` when the
    /// kernel refuses (seccomp sandboxes) or reports an empty set.
    pub fn allowed_cpus() -> Option<Vec<usize>> {
        let mut mask = [0u64; SET_WORDS];
        let rc = unsafe { sched_getaffinity(0, SET_WORDS * 8, mask.as_mut_ptr()) };
        if rc != 0 {
            return None;
        }
        let cpus: Vec<usize> = (0..SET_WORDS * 64)
            .filter(|&c| (mask[c / 64] >> (c % 64)) & 1 == 1)
            .collect();
        if cpus.is_empty() {
            None
        } else {
            Some(cpus)
        }
    }

    /// Pin the calling thread (pid 0) to a single CPU; true on success.
    pub fn pin_to(cpu: usize) -> bool {
        if cpu >= SET_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; SET_WORDS];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        unsafe { sched_setaffinity(0, SET_WORDS * 8, mask.as_ptr()) == 0 }
    }

    /// Restore the calling thread's affinity to the full `cores` set
    /// (undoes a probe [`pin_to`]); true on success.
    pub fn allow(cores: &[usize]) -> bool {
        let mut mask = [0u64; SET_WORDS];
        for &c in cores {
            if c < SET_WORDS * 64 {
                mask[c / 64] |= 1u64 << (c % 64);
            }
        }
        unsafe { sched_setaffinity(0, SET_WORDS * 8, mask.as_ptr()) == 0 }
    }
}

#[cfg(not(target_os = "linux"))]
mod affinity {
    pub fn allowed_cpus() -> Option<Vec<usize>> {
        None
    }

    pub fn pin_to(_cpu: usize) -> bool {
        false
    }

    pub fn allow(_cores: &[usize]) -> bool {
        false
    }
}

struct Pool {
    /// One channel per helper thread; the leader of each call is the
    /// calling thread itself.
    senders: Vec<Mutex<mpsc::Sender<Job>>>,
    /// Whether helper threads pinned themselves to cores at init.
    pinned: bool,
    /// The core reserved for leader threads (first allowed CPU) when
    /// pinning is active.
    leader_core: Option<usize>,
}

/// Desired total thread count (helpers + leader); 0 = auto.
static DESIRED: AtomicUsize = AtomicUsize::new(0);
/// Whether the pool should pin its helpers at init (ADR 007).
static DESIRED_PIN: AtomicBool = AtomicBool::new(false);
static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// Set while a helper runs a pool task: nested parallel calls from
    /// inside a task degrade to serial instead of risking a queue cycle.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// Set the compute thread count (total, including the calling thread).
/// Takes effect only before the pool's first use; later calls are
/// ignored (the pool is already running). 0 restores auto-detection.
pub fn configure_threads(n: usize) {
    DESIRED.store(n, Ordering::SeqCst);
}

/// Enable/disable core pinning for pool helpers (ADR 007). Takes effect
/// only before the pool's first use, like [`configure_threads`]. On
/// non-linux targets (or when `sched_setaffinity` is unavailable, e.g.
/// seccomp sandboxes) pinning degrades to a no-op and [`pinning`]
/// reports false.
pub fn configure_pinning(on: bool) {
    DESIRED_PIN.store(on, Ordering::SeqCst);
}

/// Whether the pool's helper threads actually pinned to cores.
pub fn pinning() -> bool {
    pool().pinned
}

/// Pin the calling (leader) thread to the reserved leader core — the
/// first allowed CPU, which the helper assignment skips. No-op unless
/// pinning is configured and supported; returns whether a pin applied.
/// The CLI calls this for the coordinator thread under `serve --pin`;
/// virtual-GPU worker threads deliberately float (they are dispatchers
/// whose compute fans out to the pinned helpers).
pub fn pin_leader() -> bool {
    match pool().leader_core {
        Some(core) => affinity::pin_to(core),
        None => false,
    }
}

/// Total compute threads a parallel region can use (helpers + caller).
pub fn threads() -> usize {
    pool().senders.len() + 1
}

/// A parallel task should move at least this many bytes — below it,
/// dispatch overhead beats the fan-out (the per-op chunk-size floor,
/// ADR 007).
pub const MIN_TASK_BYTES: usize = 16 * 1024;

/// Rows per chunk for fanning `rows` rows out over the pool, given an
/// estimate of the bytes one row's kernel touches. Targets ~4 chunks per
/// thread (a straggler chunk cannot serialise the tail) but floors the
/// chunk so every task moves at least [`MIN_TASK_BYTES`] — small ops
/// stop paying fan-out overhead. Chunking never affects numerics: every
/// chunk runs the identical serial kernel over disjoint rows.
pub fn chunk_rows(rows: usize, bytes_per_row: usize) -> usize {
    let balance = rows.div_ceil(threads() * 4).max(1);
    let floor = MIN_TASK_BYTES.div_ceil(bytes_per_row.max(1));
    balance.max(floor)
}

fn auto_threads() -> usize {
    if let Ok(v) = std::env::var("MOE_GPS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 16)
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        // Resolve the SIMD dispatch tier exactly once, before any kernel
        // can run on a pool thread (ADR 007).
        let _ = super::simd::active_tier();
        let desired = DESIRED.load(Ordering::SeqCst);
        let total = if desired == 0 { auto_threads() } else { desired };
        let helpers = total.saturating_sub(1);
        // Core plan: the first allowed CPU is reserved for leaders;
        // helpers cycle over the rest (wrapping when oversubscribed).
        // With a single allowed CPU everyone shares it — still correct,
        // pinning just buys nothing.
        let cores = if DESIRED_PIN.load(Ordering::SeqCst) {
            affinity::allowed_cpus()
        } else {
            None
        };
        let helper_core = |i: usize| -> Option<usize> {
            let cores = cores.as_ref()?;
            if cores.len() == 1 {
                return Some(cores[0]);
            }
            Some(cores[1 + i % (cores.len() - 1)])
        };
        let mut pinned = cores.is_some() && helpers > 0;
        let senders = (0..helpers)
            .map(|i| {
                let core = helper_core(i);
                let (tx, rx) = mpsc::channel::<Job>();
                std::thread::Builder::new()
                    .name(format!("compute-{i}"))
                    .spawn(move || {
                        if let Some(core) = core {
                            // Best-effort: a refused setaffinity (sandbox)
                            // leaves the thread floating, which is safe.
                            let _ = affinity::pin_to(core);
                        }
                        // Jobs catch their own panics, so this loop only
                        // ends when the sender side is dropped (never:
                        // the pool is static).
                        for job in rx {
                            job();
                        }
                    })
                    .expect("spawn compute pool thread");
                Mutex::new(tx)
            })
            .collect();
        // Probe that setaffinity actually works from this process before
        // reporting placement as active (helpers apply theirs async and
        // best-effort; seccomp sandboxes allow getaffinity but refuse
        // setaffinity). The probe pins the init thread to the leader
        // core, then releases it back to the full set — `pin_leader`
        // re-pins deliberately.
        if pinned {
            let cores = cores.as_ref().expect("cores present when pinned");
            pinned = affinity::pin_to(cores[0]);
            if pinned {
                let _ = affinity::allow(cores);
            }
        }
        Pool {
            senders,
            pinned,
            leader_core: if pinned { cores.map(|c| c[0]) } else { None },
        }
    })
}

/// Run `f(0..n_tasks)` across the pool. Blocks until every task has
/// completed; tasks are claimed from a shared atomic counter, and the
/// calling thread participates, so the call completes even with zero
/// helpers. Panics in any task are re-raised here after all tasks finish.
pub fn parallel_for<F>(n_tasks: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n_tasks == 0 {
        return;
    }
    let nested = IN_POOL_TASK.with(Cell::get);
    let pool = pool();
    if n_tasks == 1 || pool.senders.is_empty() || nested {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }

    let next = Arc::new(AtomicUsize::new(0));
    let helper_panicked = Arc::new(AtomicBool::new(false));
    let (done_tx, done_rx) = mpsc::channel::<()>();
    // SAFETY: the borrow of `f` is extended to 'static only for the
    // duration of this call — every helper job sends its done token
    // before returning, and we block on exactly `helpers` tokens below
    // (even if the leader's own work panics), so no job can outlive `f`.
    let f_ref: &(dyn Fn(usize) + Sync) = &f;
    let f_static: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute(f_ref) };

    let helpers = pool.senders.len().min(n_tasks - 1);
    for sender in pool.senders.iter().take(helpers) {
        let next = Arc::clone(&next);
        let flag = Arc::clone(&helper_panicked);
        let done = done_tx.clone();
        let job: Job = Box::new(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                IN_POOL_TASK.with(|t| t.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_tasks {
                        break;
                    }
                    f_static(i);
                }
            }));
            IN_POOL_TASK.with(|t| t.set(false));
            if result.is_err() {
                flag.store(true, Ordering::SeqCst);
            }
            let _ = done.send(());
        });
        sender
            .lock()
            .expect("compute pool sender")
            .send(job)
            .expect("compute pool thread alive");
    }
    drop(done_tx);

    // The leader claims tasks too; its panic (if any) is deferred until
    // the helpers are drained so the `f` borrow stays valid throughout.
    let leader = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n_tasks {
            break;
        }
        f(i);
    }));
    for _ in 0..helpers {
        done_rx.recv().expect("compute pool thread alive");
    }
    if let Err(panic) = leader {
        std::panic::resume_unwind(panic);
    }
    if helper_panicked.load(Ordering::SeqCst) {
        panic!("compute pool task panicked");
    }
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: only ever used to reconstruct *disjoint* sub-slices, one per
// task index (see `parallel_slices_mut`).
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Split `data` into consecutive chunks of `chunk_len` (the last chunk
/// may be shorter) and run `f(chunk_index, chunk)` for each across the
/// pool. Chunks are disjoint, so each task gets exclusive `&mut` access.
pub fn parallel_slices_mut<F>(data: &mut [f32], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let total = data.len();
    if total == 0 {
        return;
    }
    let n_tasks = total.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    parallel_for(n_tasks, move |i| {
        let start = i * chunk_len;
        let len = chunk_len.min(total - start);
        // SAFETY: [start, start+len) ranges are disjoint across task
        // indices and in-bounds; `parallel_for` joins before returning.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        f(i, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_exactly_once() {
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        parallel_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_for_handles_empty_and_single() {
        parallel_for(0, |_| panic!("must not run"));
        let ran = AtomicUsize::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_slices_are_disjoint_and_cover() {
        let mut data = vec![0.0f32; 1003];
        parallel_slices_mut(&mut data, 64, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += 1.0 + i as f32 * 0.0; // each element touched once
            }
        });
        assert!(data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn nested_calls_degrade_to_serial_without_deadlock() {
        let total = AtomicUsize::new(0);
        parallel_for(8, |_| {
            parallel_for(8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn concurrent_leaders_complete() {
        // Two non-pool threads driving the pool at once (the leader +
        // virtual-GPU-worker pattern).
        let a = std::thread::spawn(|| {
            let sum = AtomicUsize::new(0);
            parallel_for(100, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
            sum.load(Ordering::Relaxed)
        });
        let b = std::thread::spawn(|| {
            let sum = AtomicUsize::new(0);
            parallel_for(100, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
            sum.load(Ordering::Relaxed)
        });
        assert_eq!(a.join().unwrap(), 4950);
        assert_eq!(b.join().unwrap(), 4950);
    }

    #[test]
    fn threads_reports_at_least_one() {
        assert!(threads() >= 1);
    }

    #[test]
    fn chunk_rows_targets_four_chunks_per_thread_for_big_rows() {
        // Huge rows: the bytes floor is 1, so only the balance term
        // matters — expect ceil(rows / (threads * 4)).
        let rows = 10_000;
        let want = rows.div_ceil(threads() * 4).max(1);
        assert_eq!(chunk_rows(rows, MIN_TASK_BYTES * 4), want);
    }

    #[test]
    fn chunk_rows_floors_small_ops_to_min_task_bytes() {
        // Tiny rows (16 bytes each): a task must cover at least
        // MIN_TASK_BYTES / 16 rows no matter how many threads exist.
        let got = chunk_rows(1_000_000, 16);
        assert!(got >= MIN_TASK_BYTES / 16, "got {got}");
    }

    #[test]
    fn chunk_rows_is_at_least_one() {
        assert!(chunk_rows(1, 1) >= 1);
        assert!(chunk_rows(0, 0) >= 1);
        assert!(chunk_rows(7, usize::MAX) >= 1);
    }

    #[test]
    fn pinning_defaults_off_and_pin_leader_is_safe() {
        // This test binary never calls configure_pinning(true) before
        // first pool use, so placement must be inactive and pin_leader
        // a safe no-op (the pinned path is covered by
        // tests/pinned_pool.rs in its own process).
        assert!(!pinning());
        assert!(!pin_leader());
    }
}
