//! Pure-rust reference backend: executes the tiny serving model's artifact
//! ops directly against the [`WeightStore`], with semantics matching
//! `python/compile/model.py` (the numerics oracle) to float tolerance.
//!
//! This is the default execution backend: it needs no PJRT, no artifacts
//! directory and no python toolchain, which is what lets `moe-gps serve`
//! and the decode-serving benches run in any build environment (DESIGN.md
//! §6). The op set is the prefill set the AOT pipeline compiles (`embed`,
//! `attention`, `router`, `predictor`, `expert_ffn_b*`) plus the
//! decode-phase ops the coordinator's continuous-batching path needs
//! (`attention_prefill` / `attention_step` with explicit KV tensors, and
//! `lm_head` with tied embeddings).
//!
//! Hot loops (matmul, attention, lm_head) are blocked/tiled and fan out
//! over the shared persistent compute pool ([`super::pool`], ADR 003).
//! Every parallel op partitions its *output* into disjoint row/head
//! chunks and computes each with the identical serial kernel, so results
//! are bitwise independent of the thread count — the property
//! `tests/pipeline_parity.rs` and `tests/tiled_backend.rs` pin down.
//!
//! The serial kernels themselves are built on the [`super::simd`] lane
//! primitives (ADR 007): `dot` / `axpy` / `max_reduce` run AVX2 or NEON
//! where available, with a portable fallback that performs the *identical
//! IEEE operation sequence* — so outputs are also bitwise independent of
//! the dispatch tier, and chunk sizing ([`pool::chunk_rows`]) can change
//! freely without touching numerics.

use anyhow::Result;

use super::artifacts::{Manifest, WeightStore};
use super::engine::In;
use super::pool;
use super::simd;
use super::tensor::HostTensor;

/// Model geometry the attention ops need, read once from the manifest.
#[derive(Clone, Copy, Debug)]
struct RefDims {
    d_model: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
}

pub struct ReferenceBackend {
    dims: RefDims,
}

const RMSNORM_EPS: f32 = 1e-5;

impl ReferenceBackend {
    pub fn new(manifest: &Manifest) -> Result<ReferenceBackend> {
        let cfg = &manifest.config;
        let dims = RefDims {
            d_model: cfg.req_usize("d_model")?,
            n_heads: cfg.req_usize("n_heads")?,
            n_kv_heads: cfg.req_usize("n_kv_heads")?,
            head_dim: cfg.req_usize("head_dim")?,
        };
        anyhow::ensure!(
            dims.d_model == dims.n_heads * dims.head_dim,
            "reference backend requires d_model == n_heads * head_dim"
        );
        Ok(ReferenceBackend { dims })
    }

    /// Execute one artifact op. Input layout matches what the coordinator
    /// sends to the PJRT backend for the same artifact name.
    pub fn call(
        &self,
        weights: &WeightStore,
        name: &str,
        inputs: &[In<'_>],
    ) -> Result<Vec<HostTensor>> {
        match name {
            "embed" => self.op_embed(weights, inputs),
            "attention" => {
                let (h, _, _) = self.op_attention_prefill(weights, inputs)?;
                Ok(vec![h])
            }
            "attention_prefill" => {
                let (h, k, v) = self.op_attention_prefill(weights, inputs)?;
                Ok(vec![h, k, v])
            }
            "attention_step" => self.op_attention_step(weights, inputs),
            "router" => self.op_router(weights, inputs),
            "predictor" => self.op_predictor(weights, inputs),
            "lm_head" => self.op_lm_head(weights, inputs),
            other if other.starts_with("expert_ffn_b") => self.op_expert_ffn(weights, inputs),
            other => anyhow::bail!("reference backend: unknown artifact `{other}`"),
        }
    }

    fn op_embed(&self, weights: &WeightStore, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        let ids = int_arg(inputs, 0, "embed.ids")?;
        let table = weight_arg(weights, inputs, 1, "embed.table")?;
        let d = self.dims.d_model;
        let vocab = table.rows();
        let mut data = Vec::with_capacity(ids.len() * d);
        for &id in ids {
            let id = (id.max(0) as usize).min(vocab - 1);
            data.extend_from_slice(table.row(id));
        }
        Ok(vec![HostTensor::new(data, vec![ids.len(), d])])
    }

    /// Full-sequence causal GQA attention with residual; also returns the
    /// K/V projections so decode can seed its cache.
    fn op_attention_prefill(
        &self,
        weights: &WeightStore,
        inputs: &[In<'_>],
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let x = tensor_arg(inputs, 0, "attention.x")?;
        let ln = weight_arg(weights, inputs, 1, "attention.ln")?;
        let wq = weight_arg(weights, inputs, 2, "attention.wq")?;
        let wk = weight_arg(weights, inputs, 3, "attention.wk")?;
        let wv = weight_arg(weights, inputs, 4, "attention.wv")?;
        let wo = weight_arg(weights, inputs, 5, "attention.wo")?;
        let s = x.rows();
        let d = self.dims.d_model;
        let qw = self.dims.n_heads * self.dims.head_dim;
        let kvw = self.dims.n_kv_heads * self.dims.head_dim;

        let xn = rmsnorm(&x.data, s, d, &ln.data);
        let q = matmul(&xn, s, d, &wq.data, qw);
        let k = matmul(&xn, s, d, &wk.data, kvw);
        let v = matmul(&xn, s, d, &wv.data, kvw);
        // Queries at absolute positions 0..s over the same keys.
        let ctx = self.attend(&q, s, &k, &v, s, 0);
        let proj = matmul(&ctx, s, qw, &wo.data, d);
        let mut h = x.data.clone();
        for (a, &b) in h.iter_mut().zip(&proj) {
            *a += b;
        }
        Ok((
            HostTensor::new(h, vec![s, d]),
            HostTensor::new(k, vec![s, kvw]),
            HostTensor::new(v, vec![s, kvw]),
        ))
    }

    /// Single-token decode attention over an explicit KV cache. Inputs:
    /// `x [1,D], k_cache [T,KV], v_cache [T,KV], ln, wq, wk, wv, wo`;
    /// outputs `(h [1,D], k_new [1,KV], v_new [1,KV])` — the caller appends
    /// the new rows to its cache.
    fn op_attention_step(
        &self,
        weights: &WeightStore,
        inputs: &[In<'_>],
    ) -> Result<Vec<HostTensor>> {
        let x = tensor_arg(inputs, 0, "attention_step.x")?;
        let k_cache = tensor_arg(inputs, 1, "attention_step.k_cache")?;
        let v_cache = tensor_arg(inputs, 2, "attention_step.v_cache")?;
        let ln = weight_arg(weights, inputs, 3, "attention_step.ln")?;
        let wq = weight_arg(weights, inputs, 4, "attention_step.wq")?;
        let wk = weight_arg(weights, inputs, 5, "attention_step.wk")?;
        let wv = weight_arg(weights, inputs, 6, "attention_step.wv")?;
        let wo = weight_arg(weights, inputs, 7, "attention_step.wo")?;
        anyhow::ensure!(x.rows() == 1, "attention_step expects a single token row");
        let d = self.dims.d_model;
        let qw = self.dims.n_heads * self.dims.head_dim;
        let kvw = self.dims.n_kv_heads * self.dims.head_dim;
        let t_prev = k_cache.rows();

        let xn = rmsnorm(&x.data, 1, d, &ln.data);
        let q = matmul(&xn, 1, d, &wq.data, qw);
        let k_new = matmul(&xn, 1, d, &wk.data, kvw);
        let v_new = matmul(&xn, 1, d, &wv.data, kvw);
        // Keys = cache plus the new token's own row — attended as two
        // segments, so the cache is never copied (the naive concat would
        // make per-token cost quadratic in context length).
        let ctx = self.attend_step(&q, &k_cache.data, &v_cache.data, &k_new, &v_new, t_prev);
        let proj = matmul(&ctx, 1, qw, &wo.data, d);
        let mut h = x.data.clone();
        for (a, &b) in h.iter_mut().zip(&proj) {
            *a += b;
        }
        Ok(vec![
            HostTensor::new(h, vec![1, d]),
            HostTensor::new(k_new, vec![1, kvw]),
            HostTensor::new(v_new, vec![1, kvw]),
        ])
    }

    /// Causal GQA attention core: `sq` query rows at absolute positions
    /// `offset..offset+sq` over `tk` key/value rows. Query row `i` attends
    /// keys `0..=offset+i`.
    fn attend(
        &self,
        q: &[f32],
        sq: usize,
        k_all: &[f32],
        v_all: &[f32],
        tk: usize,
        offset: usize,
    ) -> Vec<f32> {
        let nh = self.dims.n_heads;
        let nkv = self.dims.n_kv_heads;
        let hd = self.dims.head_dim;
        let group = nh / nkv;
        let qw = nh * hd;
        let kvw = nkv * hd;
        let scale = 1.0 / (hd as f32).sqrt();

        // The per-(row, head) kernel is shared by the serial and parallel
        // paths below: one query row's context depends only on its own
        // scores, so parallelising over query rows (ADR 003) cannot change
        // any output bit.
        let row_kernel = |i: usize, out_row: &mut [f32], scores: &mut Vec<f32>| {
            let attended = (offset + i + 1).min(tk);
            for h in 0..nh {
                let kvh = h / group;
                let q_vec = &q[i * qw + h * hd..i * qw + (h + 1) * hd];
                scores.clear();
                for j in 0..attended {
                    let k_vec = &k_all[j * kvw + kvh * hd..j * kvw + (kvh + 1) * hd];
                    scores.push(simd::dot(q_vec, k_vec) * scale);
                }
                let max = simd::max_reduce(scores);
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                let out = &mut out_row[h * hd..(h + 1) * hd];
                for (j, &p) in scores.iter().enumerate() {
                    let v_vec = &v_all[j * kvw + kvh * hd..j * kvw + (kvh + 1) * hd];
                    simd::axpy(p / denom, v_vec, out);
                }
            }
        };

        let mut ctx = vec![0.0f32; sq * qw];
        if sq < 2 || sq * tk * qw < ATTEND_PAR_WORK {
            let mut scores: Vec<f32> = Vec::with_capacity(tk);
            for (i, out_row) in ctx.chunks_mut(qw).enumerate() {
                row_kernel(i, out_row, &mut scores);
            }
            return ctx;
        }
        // Per query row: every head streams its K and V panels once —
        // ~2 × 4 bytes × qw × tk. The floor keeps tiny prefills from
        // paying fan-out overhead (ADR 007).
        let rows_per_chunk = pool::chunk_rows(sq, 8 * qw * tk);
        pool::parallel_slices_mut(&mut ctx, rows_per_chunk * qw, |chunk_idx, chunk| {
            let i0 = chunk_idx * rows_per_chunk;
            let mut scores: Vec<f32> = Vec::with_capacity(tk);
            for (r, out_row) in chunk.chunks_mut(qw).enumerate() {
                row_kernel(i0 + r, out_row, &mut scores);
            }
        });
        ctx
    }

    /// Single-query causal GQA attention over a segmented key/value store:
    /// `t_prev` cached rows plus the new token's own K/V row, without
    /// materialising their concatenation.
    fn attend_step(
        &self,
        q: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        k_new: &[f32],
        v_new: &[f32],
        t_prev: usize,
    ) -> Vec<f32> {
        let nh = self.dims.n_heads;
        let nkv = self.dims.n_kv_heads;
        let hd = self.dims.head_dim;
        let group = nh / nkv;
        let qw = nh * hd;
        let kvw = nkv * hd;
        let scale = 1.0 / (hd as f32).sqrt();

        let k_row = |j: usize, kvh: usize| -> &[f32] {
            if j < t_prev {
                &k_cache[j * kvw + kvh * hd..j * kvw + (kvh + 1) * hd]
            } else {
                &k_new[kvh * hd..(kvh + 1) * hd]
            }
        };
        let v_row = |j: usize, kvh: usize| -> &[f32] {
            if j < t_prev {
                &v_cache[j * kvw + kvh * hd..j * kvw + (kvh + 1) * hd]
            } else {
                &v_new[kvh * hd..(kvh + 1) * hd]
            }
        };

        // Each head writes its own `hd`-wide slice of the context — the
        // natural parallel axis for a single-query step (ADR 003). The
        // per-head kernel is shared by both paths, so outputs are bitwise
        // independent of the thread count.
        let head_kernel = |h: usize, out: &mut [f32], scores: &mut Vec<f32>| {
            let kvh = h / group;
            let q_vec = &q[h * hd..(h + 1) * hd];
            scores.clear();
            for j in 0..=t_prev {
                let k_vec = k_row(j, kvh);
                scores.push(simd::dot(q_vec, k_vec) * scale);
            }
            let max = simd::max_reduce(scores);
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                denom += *sc;
            }
            for (j, &p) in scores.iter().enumerate() {
                let v_vec = v_row(j, kvh);
                simd::axpy(p / denom, v_vec, out);
            }
        };

        let mut ctx = vec![0.0f32; qw];
        if nh < 2 || nh * (t_prev + 1) * hd < ATTEND_PAR_WORK {
            let mut scores: Vec<f32> = Vec::with_capacity(t_prev + 1);
            for (h, out) in ctx.chunks_mut(hd).enumerate() {
                head_kernel(h, out, &mut scores);
            }
            return ctx;
        }
        pool::parallel_slices_mut(&mut ctx, hd, |h, out| {
            let mut scores: Vec<f32> = Vec::with_capacity(t_prev + 1);
            head_kernel(h, out, &mut scores);
        });
        ctx
    }

    fn op_router(&self, weights: &WeightStore, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        let h = tensor_arg(inputs, 0, "router.h")?;
        let ln = weight_arg(weights, inputs, 1, "router.ln")?;
        let wr = weight_arg(weights, inputs, 2, "router.w")?;
        let s = h.rows();
        let d = self.dims.d_model;
        let e = wr.shape[1];
        let xn = rmsnorm(&h.data, s, d, &ln.data);
        let logits = matmul(&xn, s, d, &wr.data, e);
        Ok(vec![
            HostTensor::new(xn, vec![s, d]),
            HostTensor::new(logits, vec![s, e]),
        ])
    }

    fn op_predictor(&self, weights: &WeightStore, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        let x0 = tensor_arg(inputs, 0, "predictor.x0")?;
        let w1 = weight_arg(weights, inputs, 1, "predictor.w1")?;
        let b1 = weight_arg(weights, inputs, 2, "predictor.b1")?;
        anyhow::ensure!(inputs.len() > 3, "predictor needs at least one head");
        let s = x0.rows();
        let d = self.dims.d_model;
        let hid = w1.shape[1];
        let mut hidden = matmul(&x0.data, s, d, &w1.data, hid);
        for i in 0..s {
            for (hv, &bv) in hidden[i * hid..(i + 1) * hid].iter_mut().zip(&b1.data) {
                *hv = (*hv + bv).max(0.0);
            }
        }
        let n_heads = inputs.len() - 3;
        let mut e = 0;
        let mut out = Vec::new();
        for l in 0..n_heads {
            let head = weight_arg(weights, inputs, 3 + l, "predictor.head")?;
            e = head.shape[1];
            out.extend(matmul(&hidden, s, hid, &head.data, e));
        }
        Ok(vec![HostTensor::new(out, vec![n_heads, s, e])])
    }

    fn op_lm_head(&self, weights: &WeightStore, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        let h = tensor_arg(inputs, 0, "lm_head.h")?;
        let ln = weight_arg(weights, inputs, 1, "lm_head.final_ln")?;
        let embed = weight_arg(weights, inputs, 2, "lm_head.embed")?;
        let n = h.rows();
        let d = self.dims.d_model;
        let vocab = embed.rows();
        let xn = rmsnorm(&h.data, n, d, &ln.data);
        // Tied embeddings: logits = xn @ embed^T. Usually a single row
        // (the last token of each sequence), so the parallel axis is the
        // vocab: disjoint logit spans per chunk, each element a single
        // dot product — bitwise independent of the chunking (ADR 003).
        let mut logits = vec![0.0f32; n * vocab];
        let fill = |i: usize, v0: usize, orow: &mut [f32]| {
            let xrow = &xn[i * d..(i + 1) * d];
            for (dv, o) in orow.iter_mut().enumerate() {
                *o = simd::dot(xrow, embed.row(v0 + dv));
            }
        };
        if n * vocab * d < MATMUL_PAR_FLOPS {
            for i in 0..n {
                fill(i, 0, &mut logits[i * vocab..(i + 1) * vocab]);
            }
        } else {
            for i in 0..n {
                let row = &mut logits[i * vocab..(i + 1) * vocab];
                // Per logit: one d-wide dot against an embedding row.
                let chunk = pool::chunk_rows(vocab, 8 * d);
                pool::parallel_slices_mut(row, chunk, |c, span| {
                    fill(i, c * chunk, span);
                });
            }
        }
        Ok(vec![HostTensor::new(logits, vec![n, vocab])])
    }

    fn op_expert_ffn(&self, weights: &WeightStore, inputs: &[In<'_>]) -> Result<Vec<HostTensor>> {
        // Accepts an owned tensor or a borrowed slab view (a batched
        // group's sub-range — ADR 009); the kernel only needs rows+data.
        let (xn, t) = rows_arg(inputs, 0, self.dims.d_model, "expert_ffn.xn")?;
        let wg = weight_arg(weights, inputs, 1, "expert_ffn.w_gate")?;
        let wu = weight_arg(weights, inputs, 2, "expert_ffn.w_up")?;
        let wd = weight_arg(weights, inputs, 3, "expert_ffn.w_down")?;
        let d = self.dims.d_model;
        let ff = wg.shape[1];
        let mut gate = matmul(xn, t, d, &wg.data, ff);
        let up = matmul(xn, t, d, &wu.data, ff);
        for (g, &u) in gate.iter_mut().zip(&up) {
            *g = silu(*g) * u;
        }
        let out = matmul(&gate, t, ff, &wd.data, d);
        Ok(vec![HostTensor::new(out, vec![t, d])])
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RMSNorm over the last axis of a row-major `[m, d]` buffer.
fn rmsnorm(x: &[f32], m: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * d];
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        // Canonical lane-accumulated self-dot (ADR 007): the same value
        // on every dispatch tier.
        let ms: f32 = simd::dot(row, row) / d as f32;
        let scale = 1.0 / (ms + RMSNORM_EPS).sqrt();
        for (o, (&v, &gv)) in out[i * d..(i + 1) * d].iter_mut().zip(row.iter().zip(g)) {
            *o = v * scale * gv;
        }
    }
    out
}

/// Mul-add count below which a matmul is not worth fanning out to the
/// compute pool (dispatch overhead dominates — e.g. decode matvecs).
const MATMUL_PAR_FLOPS: usize = 1 << 15;

/// Work estimate (`rows × keys × width`) below which attention stays
/// serial; single-row decode steps and tiny prefills land here.
const ATTEND_PAR_WORK: usize = 1 << 14;

/// k-dimension tile: the `b` panel touched by one tile fits in L1/L2 and
/// is reused across the rows of a chunk. Tiling only partitions the `kk`
/// loop — the accumulation order within a row is exactly the plain ikj
/// order, so tiled output is bitwise identical to the untiled kernel.
const MATMUL_K_TILE: usize = 64;

/// The serial per-row kernel: blocked ikj over one output row. Every
/// execution path (serial, tiled, pool-parallel) funnels through this,
/// which is what keeps results bitwise independent of the thread count.
/// The inner `orow += av * brow` is an elementwise AXPY, so the SIMD
/// tiers perform the identical IEEE op per element and the output is
/// bitwise independent of the dispatch tier too (ADR 007).
#[inline]
fn matmul_row(a: &[f32], k: usize, b: &[f32], n: usize, i: usize, orow: &mut [f32]) {
    let arow = &a[i * k..(i + 1) * k];
    for k0 in (0..k).step_by(MATMUL_K_TILE) {
        let k1 = (k0 + MATMUL_K_TILE).min(k);
        for (kk, &av) in arow[k0..k1].iter().enumerate() {
            let brow = &b[(k0 + kk) * n..(k0 + kk + 1) * n];
            simd::axpy(av, brow, orow);
        }
    }
}

/// Row-major `[m,k] @ [k,n] -> [m,n]`: blocked/tiled ikj kernel with
/// row-chunk parallelism over the shared compute pool (ADR 003). Each
/// output row is produced by the identical serial kernel regardless of
/// chunking, so results are bitwise independent of the thread count.
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    if m * k * n < MATMUL_PAR_FLOPS || m < 2 {
        for i in 0..m {
            matmul_row(a, k, b, n, i, &mut out[i * n..(i + 1) * n]);
        }
        return out;
    }
    // Chunk rows ~4× finer than the thread count so a straggler chunk
    // cannot serialise the tail, floored so each task moves real bytes
    // (per row: read `k` of `a`, write `n` of out — the shared `b` panel
    // amortises across rows). Chunking never changes per-row numerics.
    let rows_per_chunk = pool::chunk_rows(m, 4 * (k + n));
    pool::parallel_slices_mut(&mut out, rows_per_chunk * n, |chunk_idx, chunk| {
        let row0 = chunk_idx * rows_per_chunk;
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            matmul_row(a, k, b, n, row0 + r, orow);
        }
    });
    out
}

fn tensor_arg<'a>(inputs: &'a [In<'_>], i: usize, what: &str) -> Result<&'a HostTensor> {
    match inputs.get(i) {
        Some(In::T(t)) => Ok(t),
        _ => anyhow::bail!("reference backend: input {i} ({what}) must be a host tensor"),
    }
}

/// Row-major activation data + row count from either an owned tensor or
/// a borrowed `In::View` slab sub-range (ADR 009). The view's column
/// width must match the expected width.
fn rows_arg<'a>(
    inputs: &'a [In<'_>],
    i: usize,
    want_cols: usize,
    what: &str,
) -> Result<(&'a [f32], usize)> {
    match inputs.get(i) {
        Some(In::T(t)) => Ok((&t.data, t.rows())),
        Some(In::View { data, rows, cols }) => {
            anyhow::ensure!(
                *cols == want_cols && data.len() == rows * cols,
                "reference backend: input {i} ({what}) view shape mismatch \
                 ({rows}x{cols}, {} elems, want width {want_cols})",
                data.len()
            );
            Ok((data, *rows))
        }
        _ => anyhow::bail!("reference backend: input {i} ({what}) must be an activation"),
    }
}

fn int_arg<'a>(inputs: &'a [In<'_>], i: usize, what: &str) -> Result<&'a [i32]> {
    match inputs.get(i) {
        Some(In::I(t)) => Ok(&t.data),
        _ => anyhow::bail!("reference backend: input {i} ({what}) must be an int tensor"),
    }
}

fn weight_arg(
    weights: &WeightStore,
    inputs: &[In<'_>],
    i: usize,
    what: &str,
) -> Result<HostTensor> {
    match inputs.get(i) {
        Some(In::W(name)) => weights.get(name),
        _ => anyhow::bail!("reference backend: input {i} ({what}) must be a weight name"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{synthetic_artifacts, SyntheticSpec};
    use crate::runtime::tensor::IntTensor;

    fn backend() -> (ReferenceBackend, WeightStore) {
        let (manifest, weights) = synthetic_artifacts(&SyntheticSpec::small_test());
        (ReferenceBackend::new(&manifest).unwrap(), weights)
    }

    #[test]
    fn matmul_matches_manual() {
        // [2,3] @ [3,2]
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, 2, 3, &b, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let g = [1.0f32, 1.0];
        let out = rmsnorm(&x, 1, 2, &g);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-4);
        assert!((out[1] - 4.0 / rms).abs() < 1e-4);
    }

    #[test]
    fn expert_ffn_zero_in_zero_out() {
        let (be, ws) = backend();
        let x = HostTensor::zeros(&[8, 64]);
        let out = be
            .call(
                &ws,
                "expert_ffn_b8",
                &[
                    In::T(&x),
                    In::W("layers.0.experts.0.w_gate"),
                    In::W("layers.0.experts.0.w_up"),
                    In::W("layers.0.experts.0.w_down"),
                ],
            )
            .unwrap()
            .remove(0);
        assert_eq!(out.shape, vec![8, 64]);
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn embed_gathers_rows() {
        let (be, ws) = backend();
        let ids = IntTensor::new(vec![5, 5, 9], vec![1, 3]);
        let out = be
            .call(&ws, "embed", &[In::I(&ids), In::W("embed")])
            .unwrap()
            .remove(0);
        assert_eq!(out.shape, vec![3, 64]);
        assert_eq!(out.row(0), out.row(1));
        assert_ne!(out.row(0), out.row(2));
    }

    #[test]
    fn attention_is_causal() {
        // Changing a later token must not change earlier outputs.
        let (be, ws) = backend();
        let args = |x: &HostTensor| {
            vec![
                In::T(x),
                In::W("layers.0.attn.ln"),
                In::W("layers.0.attn.wq"),
                In::W("layers.0.attn.wk"),
                In::W("layers.0.attn.wv"),
                In::W("layers.0.attn.wo"),
            ]
        };
        let mut data: Vec<f32> = (0..4 * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let x1 = HostTensor::new(data.clone(), vec![4, 64]);
        let h1 = {
            let a = args(&x1);
            be.call(&ws, "attention", &a).unwrap().remove(0)
        };
        // Perturb the last token only.
        for v in data[3 * 64..].iter_mut() {
            *v += 1.0;
        }
        let x2 = HostTensor::new(data, vec![4, 64]);
        let h2 = {
            let a = args(&x2);
            be.call(&ws, "attention", &a).unwrap().remove(0)
        };
        for t in 0..3 {
            for (a, b) in h1.row(t).iter().zip(h2.row(t)) {
                assert!((a - b).abs() < 1e-6, "token {t} leaked future info");
            }
        }
        assert!(h1.row(3).iter().zip(h2.row(3)).any(|(a, b)| (a - b).abs() > 1e-4));
    }

    #[test]
    fn decode_step_matches_prefill() {
        // attention_prefill over [t0..t3] row 3 must equal: prefill [t0..t2]
        // to seed the cache, then attention_step on t3.
        let (be, ws) = backend();
        let data: Vec<f32> = (0..4 * 64).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let x_full = HostTensor::new(data.clone(), vec![4, 64]);
        let weight_args = [
            In::W("layers.1.attn.ln"),
            In::W("layers.1.attn.wq"),
            In::W("layers.1.attn.wk"),
            In::W("layers.1.attn.wv"),
            In::W("layers.1.attn.wo"),
        ];
        let mut full_args = vec![In::T(&x_full)];
        full_args.extend(weight_args.clone());
        let full = be.call(&ws, "attention_prefill", &full_args).unwrap();
        let h_full = &full[0];

        let x_prefix = x_full.gather_rows(&[0, 1, 2]);
        let mut prefix_args = vec![In::T(&x_prefix)];
        prefix_args.extend(weight_args.clone());
        let mut prefix = be.call(&ws, "attention_prefill", &prefix_args).unwrap();
        let v_cache = prefix.remove(2);
        let k_cache = prefix.remove(1);

        let x_last = x_full.gather_rows(&[3]);
        let mut step_args = vec![In::T(&x_last), In::T(&k_cache), In::T(&v_cache)];
        step_args.extend(weight_args);
        let step = be.call(&ws, "attention_step", &step_args).unwrap();
        let h_step = &step[0];
        for (a, b) in h_full.row(3).iter().zip(h_step.row(0)) {
            assert!((a - b).abs() < 1e-5, "decode step diverged: {a} vs {b}");
        }
        // The returned K row must match the full-prefill K at position 3.
        for (a, b) in full[1].row(3).iter().zip(step[1].row(0)) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn router_outputs_norm_and_logits() {
        let (be, ws) = backend();
        let x = HostTensor::new((0..2 * 64).map(|i| i as f32 * 0.01).collect(), vec![2, 64]);
        let out = be
            .call(
                &ws,
                "router",
                &[In::T(&x), In::W("layers.0.moe.ln"), In::W("layers.0.moe.router")],
            )
            .unwrap();
        assert_eq!(out[0].shape, vec![2, 64]);
        assert_eq!(out[1].shape, vec![2, 8]);
    }

    #[test]
    fn predictor_shape_is_layers_tokens_experts() {
        let (be, ws) = backend();
        let x = HostTensor::new(vec![0.1; 3 * 64], vec![3, 64]);
        let out = be
            .call(
                &ws,
                "predictor",
                &[
                    In::T(&x),
                    In::W("predictor.w1"),
                    In::W("predictor.b1"),
                    In::W("predictor.head.0"),
                    In::W("predictor.head.1"),
                ],
            )
            .unwrap()
            .remove(0);
        assert_eq!(out.shape, vec![2, 3, 8]);
    }

    #[test]
    fn lm_head_prefers_embedding_aligned_token() {
        let (be, ws) = backend();
        // Hidden state equal to a token's embedding row should score that
        // token highly (tied embeddings).
        let embed = ws.get("embed").unwrap();
        let target = 17usize;
        let h = HostTensor::new(embed.row(target).to_vec(), vec![1, 64]);
        let logits = be
            .call(&ws, "lm_head", &[In::T(&h), In::W("final.ln"), In::W("embed")])
            .unwrap()
            .remove(0);
        assert_eq!(logits.shape, vec![1, 512]);
        let argmax = logits
            .data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(argmax, target);
    }
}
