//! Host-side tensors (bridged to XLA literals under `--features pjrt`).

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// A dense row-major f32 tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl HostTensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> HostTensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length must match shape"
        );
        HostTensor { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    /// A zero-row placeholder (`[0, 0]`) — what `mem::replace` leaves
    /// behind when a tensor is moved into an `Arc` for read-shared
    /// fan-out (ADR 009). Allocates nothing.
    pub fn empty() -> HostTensor {
        HostTensor {
            data: Vec::new(),
            shape: vec![0, 0],
        }
    }

    pub fn rows(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    pub fn row_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// Borrow row `i` (first-axis slice).
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_len();
        &self.data[i * w..(i + 1) * w]
    }

    /// Gather a sub-tensor of the given first-axis rows.
    pub fn gather_rows(&self, rows: &[usize]) -> HostTensor {
        let mut data = Vec::with_capacity(rows.len() * self.row_len());
        self.gather_rows_into(rows, &mut data);
        let mut shape = self.shape.clone();
        shape[0] = rows.len();
        HostTensor::new(data, shape)
    }

    /// Gather the given first-axis rows straight into a caller-owned
    /// buffer (appended) — the slab-filling variant `gather_rows`
    /// delegates to, so FFN dispatch can pack pooled arena slabs without
    /// an intermediate tensor (ADR 009).
    pub fn gather_rows_into(&self, rows: &[usize], out: &mut Vec<f32>) {
        out.reserve(rows.len() * self.row_len());
        for &r in rows {
            out.extend_from_slice(self.row(r));
        }
    }

    /// Pad the first axis with zero rows up to `n` (bucket padding).
    pub fn pad_rows_to(&self, n: usize) -> HostTensor {
        assert!(n >= self.rows());
        let w = self.row_len();
        // One pre-sized allocation: clone-then-resize would allocate for
        // the clone and may reallocate again growing to `n * w`.
        let mut data = Vec::with_capacity(n * w);
        data.extend_from_slice(&self.data);
        data.resize(n * w, 0.0);
        let mut shape = self.shape.clone();
        shape[0] = n;
        HostTensor::new(data, shape)
    }

    /// Append another tensor's rows along the first axis (KV-cache growth
    /// on the decode path). Row widths must match.
    pub fn append_rows(&mut self, other: &HostTensor) {
        assert_eq!(self.row_len(), other.row_len(), "row width mismatch");
        self.data.extend_from_slice(&other.data);
        self.shape[0] += other.rows();
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor::new(data, dims))
    }
}

/// An int32 host tensor (token ids).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub data: Vec<i32>,
    pub shape: Vec<usize>,
}

impl IntTensor {
    pub fn new(data: Vec<i32>, shape: Vec<usize>) -> IntTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        IntTensor { data, shape }
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accessors() {
        let t = HostTensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]);
        assert_eq!(t.rows(), 3);
        assert_eq!(t.row_len(), 4);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn gather_and_pad() {
        let t = HostTensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.shape, vec![2, 4]);
        assert_eq!(g.row(0), &[8.0, 9.0, 10.0, 11.0]);
        let p = g.pad_rows_to(4);
        assert_eq!(p.shape, vec![4, 4]);
        assert_eq!(p.row(3), &[0.0; 4]);
    }

    #[test]
    fn gather_rows_into_appends_without_reshaping() {
        let t = HostTensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]);
        // Pre-existing contents stay in place: dispatch packs several
        // groups into one slab by appending.
        let mut slab = vec![-1.0f32; 2];
        t.gather_rows_into(&[1, 1, 2], &mut slab);
        assert_eq!(slab.len(), 2 + 3 * 4);
        assert_eq!(&slab[..2], &[-1.0, -1.0]);
        assert_eq!(&slab[2..6], t.row(1));
        assert_eq!(&slab[6..10], t.row(1));
        assert_eq!(&slab[10..14], t.row(2));
        // The owned variant produces the identical bytes.
        let owned = t.gather_rows(&[1, 1, 2]);
        assert_eq!(&slab[2..], &owned.data[..]);
    }

    #[test]
    fn pad_rows_to_allocates_once_and_zero_fills() {
        let t = HostTensor::new((0..8).map(|x| x as f32).collect(), vec![2, 4]);
        let p = t.pad_rows_to(5);
        assert_eq!(p.shape, vec![5, 4]);
        assert_eq!(p.row(0), t.row(0));
        assert_eq!(p.row(1), t.row(1));
        for r in 2..5 {
            assert_eq!(p.row(r), &[0.0; 4]);
        }
        // The buffer is sized exactly once (no clone-then-grow slack).
        assert_eq!(p.data.capacity(), 5 * 4);
        // Padding to the current row count is an allocation-exact copy.
        let same = t.pad_rows_to(2);
        assert_eq!(same, t);
    }

    #[test]
    fn empty_placeholder_is_allocation_free() {
        let e = HostTensor::empty();
        assert_eq!(e.rows(), 0);
        assert_eq!(e.data.capacity(), 0);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![1.0; 5], vec![2, 3]);
    }

    #[test]
    fn append_rows_grows_first_axis() {
        let mut t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let extra = HostTensor::new(vec![5.0, 6.0], vec![1, 2]);
        t.append_rows(&extra);
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.row(2), &[5.0, 6.0]);
    }

    // The literal round-trip needs the real xla bindings; under the stub
    // crate it would error by construction, so it is exercised only by
    // pjrt-enabled builds with real bindings (see DESIGN.md §6).
    #[cfg(feature = "pjrt")]
    #[test]
    #[ignore = "requires real xla bindings (vendor/xla is a stub)"]
    fn literal_round_trip() {
        let t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
