//! Figure 6/8/9 sweep machinery: per-(skew, strategy, accuracy) latency
//! breakdowns from the simulator, using calibrated DOP error and TEP
//! overhead fits.

use super::calibrate::{interpolate_for_skew, WorkloadCalibration};
use crate::model::ModelConfig;
use crate::sim::hardware::SystemSpec;
use crate::sim::moe::Strategy;
use crate::sim::{LayerBreakdown, LayerSim};

/// One evaluated configuration in the sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub skewness: f64,
    pub strategy_name: String,
    /// Accuracy for TEP points; NaN otherwise.
    pub accuracy: f64,
    pub breakdown: LayerBreakdown,
    pub total_s: f64,
    /// baseline_total / total (≥ 1 means the strategy helps).
    pub normalized_perf: f64,
}

/// The accuracy grid the TEP curves are evaluated on (Figure 6's x points).
pub fn accuracy_grid() -> Vec<f64> {
    vec![0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99]
}

/// Produce the Figure-6 family for one (model, system): for each skewness,
/// the baseline, the Distribution-Only point, and the TEP accuracy curve
/// (with overhead from the calibrated exponential fit, interpolated in
/// skew exactly as the paper does, §4).
pub fn skew_sweep(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skews: &[f64],
    batch: usize,
    seq: usize,
) -> Vec<SweepPoint> {
    let sim = LayerSim::new(model.clone(), system.clone()).with_workload(batch, seq);
    let mut out = Vec::new();
    for &skew in skews {
        let baseline = sim.breakdown(skew, Strategy::NoPrediction);
        let baseline_total = baseline.total();
        out.push(SweepPoint {
            skewness: skew,
            strategy_name: "baseline".into(),
            accuracy: f64::NAN,
            total_s: baseline_total,
            normalized_perf: 1.0,
            breakdown: baseline,
        });

        let (dop_error, overhead_fit) = interpolate_for_skew(cals, skew);
        let dop = sim.breakdown(skew, Strategy::DistributionOnly { error_rate: dop_error });
        out.push(SweepPoint {
            skewness: skew,
            strategy_name: "distribution-only".into(),
            accuracy: f64::NAN,
            total_s: dop.total(),
            normalized_perf: baseline_total / dop.total(),
            breakdown: dop,
        });

        for &acc in &accuracy_grid() {
            let overhead_ratio = overhead_fit.0 * (overhead_fit.1 * acc).exp();
            let overhead_s = overhead_ratio * baseline_total;
            let tep = sim.breakdown(
                skew,
                Strategy::TokenToExpert {
                    accuracy: acc,
                    overhead_s,
                },
            );
            out.push(SweepPoint {
                skewness: skew,
                strategy_name: "token-to-expert".into(),
                accuracy: acc,
                total_s: tep.total(),
                normalized_perf: baseline_total / tep.total(),
                breakdown: tep,
            });
        }
    }
    out
}

/// The skewness levels Figure 6 plots.
pub fn figure6_skews() -> Vec<f64> {
    vec![1.0, 1.4, 2.0, 3.0, 4.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::calibrate::{calibrate, CalibrationOptions};
    use crate::trace::datasets;

    fn fast_cals(model: &ModelConfig, system: &SystemSpec) -> Vec<WorkloadCalibration> {
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        vec![
            calibrate(datasets::mmlu_like(71), model, system, &opts),
            calibrate(datasets::sst2_like(72), model, system, &opts),
        ]
    }

    #[test]
    fn sweep_has_expected_shape() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let cals = fast_cals(&model, &system);
        let points = skew_sweep(&model, &system, &cals, &[1.4, 2.0], 1, 512);
        // Per skew: 1 baseline + 1 DOP + |grid| TEP points.
        assert_eq!(points.len(), 2 * (2 + accuracy_grid().len()));
        let baselines: Vec<&SweepPoint> = points
            .iter()
            .filter(|p| p.strategy_name == "baseline")
            .collect();
        assert!(baselines.iter().all(|p| p.normalized_perf == 1.0));
        // Higher skew → slower baseline.
        assert!(baselines[1].total_s > baselines[0].total_s);
    }

    #[test]
    fn dop_wins_at_low_skew_on_nvlink() {
        // The paper's headline: at skew ~1.4 on NVLink, Distribution-Only
        // beats the best Token-to-Expert configuration.
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let cals = fast_cals(&model, &system);
        let points = skew_sweep(&model, &system, &cals, &[1.4], 1, 512);
        let dop = points
            .iter()
            .find(|p| p.strategy_name == "distribution-only")
            .unwrap();
        let best_tep = points
            .iter()
            .filter(|p| p.strategy_name == "token-to-expert")
            .min_by(|a, b| a.total_s.total_cmp(&b.total_s))
            .unwrap();
        assert!(
            dop.total_s < best_tep.total_s,
            "dop={} best_tep={} (acc={})",
            dop.total_s,
            best_tep.total_s,
            best_tep.accuracy
        );
    }
}
