//! Table/figure emitters shared by the benches and the CLI `bench-report`
//! subcommand. Each function renders the paper's rows/series as an aligned
//! ASCII table plus a CSV block.

use super::calibrate::WorkloadCalibration;
use super::select::SavingsComparison;
use super::sweep::SweepPoint;
use crate::util::tablefmt::{f, pct, Align, Table};

/// Table 1: dataset × skewness × DOP error rate.
pub fn table1(cals: &[WorkloadCalibration]) -> String {
    let mut t = Table::new(&["Dataset", "Skewness", "Error rate (%)"]).align(&[
        Align::Left,
        Align::Right,
        Align::Right,
    ]);
    for c in cals {
        t.row(&[
            c.workload.clone(),
            f(c.skewness, 2),
            f(c.dop_error * 100.0, 2),
        ]);
    }
    format!("{}\nCSV:\n{}", t.render(), t.render_csv())
}

/// Figure 4: per-predictor accuracy / overhead / normalized performance,
/// plus the fitted curves.
pub fn figure4(cal: &WorkloadCalibration) -> String {
    let mut t = Table::new(&[
        "Predictor",
        "Accuracy",
        "Top-k hit",
        "L1 err",
        "Overhead (ratio)",
        "Norm. perf",
    ])
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for p in &cal.points {
        t.row(&[
            p.name.clone(),
            f(p.accuracy, 3),
            f(p.topk_accuracy, 3),
            f(p.dist_l1, 3),
            f(p.overhead_ratio, 4),
            f(p.normalized_perf, 3),
        ]);
    }
    format!(
        "{} (skew {:.2}, DOP err {})\n{}\nfits: overhead(a) = {:.4}·exp({:.2}·a); \
         perf(a) = {:.3} + {:.3}a + {:.3}a²\nCSV:\n{}",
        cal.workload,
        cal.skewness,
        pct(cal.dop_error),
        t.render(),
        cal.overhead_fit.0,
        cal.overhead_fit.1,
        cal.perf_fit.first().copied().unwrap_or(0.0),
        cal.perf_fit.get(1).copied().unwrap_or(0.0),
        cal.perf_fit.get(2).copied().unwrap_or(0.0),
        t.render_csv()
    )
}

/// Figure 6/8/9: latency breakdown per (skew, strategy, accuracy).
pub fn figure6(points: &[SweepPoint], title: &str) -> String {
    let mut t = Table::new(&[
        "Skew",
        "Strategy",
        "Acc",
        "Attn (ms)",
        "Comm (ms)",
        "FFN (ms)",
        "Ovh (ms)",
        "Total (ms)",
        "Norm perf",
    ])
    .align(&[
        Align::Right,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for p in points {
        let b = &p.breakdown;
        t.row(&[
            f(p.skewness, 1),
            p.strategy_name.clone(),
            if p.accuracy.is_nan() {
                "-".into()
            } else {
                f(p.accuracy, 2)
            },
            f((b.attention_s + b.router_s) * 1e3, 3),
            f(b.comm_s() * 1e3, 3),
            f(b.ffn_s * 1e3, 3),
            f((b.overhead_s + b.movement_s) * 1e3, 3),
            f(p.total_s * 1e3, 3),
            f(p.normalized_perf, 3),
        ]);
    }
    format!("{title}\n{}\nCSV:\n{}", t.render(), t.render_csv())
}

/// Figure 7: savings difference per (interconnect, skew).
pub fn figure7(rows: &[SavingsComparison]) -> String {
    let mut t = Table::new(&[
        "BW (GB/s)",
        "Skew",
        "Baseline (ms)",
        "DOP saving (ms)",
        "TEP best saving (ms)",
        "TEP acc",
        "Diff (ms)",
        "Winner",
    ])
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Left,
    ]);
    for r in rows {
        t.row(&[
            f(r.interconnect_gbs, 0),
            f(r.skewness, 1),
            f(r.baseline_s * 1e3, 3),
            f(r.dop_saving_s * 1e3, 3),
            f(r.tep_best_saving_s * 1e3, 3),
            f(r.tep_best_accuracy, 2),
            f(r.difference_s * 1e3, 3),
            if r.difference_s >= 0.0 {
                "distribution-only".into()
            } else {
                "token-to-expert".into()
            },
        ]);
    }
    format!("{}\nCSV:\n{}", t.render(), t.render_csv())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::calibrate::{calibrate, CalibrationOptions};
    use crate::gps::sweep::skew_sweep;
    use crate::model::ModelConfig;
    use crate::sim::hardware::SystemSpec;
    use crate::trace::datasets;

    #[test]
    fn reports_render_nonempty() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        let cals = vec![
            calibrate(datasets::mmlu_like(95), &model, &system, &opts),
            calibrate(datasets::sst2_like(96), &model, &system, &opts),
        ];
        let t1 = table1(&cals);
        assert!(t1.contains("mmlu-like"));
        assert!(t1.contains("CSV:"));
        let f4 = figure4(&cals[0]);
        assert!(f4.contains("probability"));
        assert!(f4.contains("exp("));
        let points = skew_sweep(&model, &system, &cals, &[1.4], 1, 512);
        let f6 = figure6(&points, "fig6 test");
        assert!(f6.contains("distribution-only"));
        let rows = vec![crate::gps::select::strategy_savings(
            &model, &system, &cals, 1.4, 1, 512,
        )];
        let f7 = figure7(&rows);
        assert!(f7.contains("Winner"));
    }
}
