//! Workload calibration: the Figure-4 pipeline.
//!
//! Trains every Token-to-Expert predictor on a dataset-like trace, measures
//! accuracy on the held-out split, prices each predictor's request-path
//! overhead on the simulated hardware, and fits the paper's curves:
//! exponential `overhead(accuracy)` and polynomial `perf(accuracy)`.
//! Also measures the Distribution-Only MLE error rate (Table 1).

use crate::model::ModelConfig;
use crate::predictor::conditional::{ConditionalModel, Conditioning};
use crate::predictor::distribution::DistributionEstimator;
use crate::predictor::markov::BigramModel;
use crate::predictor::neural::{MlpConfig, MlpPredictor};
use crate::predictor::overhead::{self, PredictorKind};
use crate::predictor::probability::ProbabilityModel;
use crate::predictor::{accuracy, Predictor};
use crate::sim::hardware::SystemSpec;
use crate::sim::moe::Strategy;
use crate::sim::LayerSim;
use crate::trace::{Trace, TraceSpec};
use crate::util::stats;

/// One trained predictor's measured point (a dot in Figure 4).
#[derive(Clone, Debug)]
pub struct PredictorPoint {
    pub name: String,
    pub accuracy: f64,
    /// Top-k set hit rate at the model's routed `top_k` (ADR 005): the
    /// probability a routed slot's expert appears anywhere in the
    /// predicted set — what the speculative scatter's confirm rate
    /// realises at serve time.
    pub topk_accuracy: f64,
    /// L1 error between the predictor's share distribution and the test
    /// trace's empirical shares (the Table-1 metric, scored for TEP
    /// predictors too).
    pub dist_l1: f64,
    pub overhead_s: f64,
    /// Overhead as a ratio to the baseline layer runtime (Figure 4's
    /// overhead axis).
    pub overhead_ratio: f64,
    /// Simulated end-to-end normalized performance with this predictor
    /// driving Token-to-Expert duplication (Figure 4's performance axis).
    pub normalized_perf: f64,
}

/// Calibration result for one workload (dataset × model × system).
#[derive(Clone, Debug)]
pub struct WorkloadCalibration {
    pub workload: String,
    /// Measured average per-batch skewness of the trace.
    pub skewness: f64,
    /// Distribution-Only MLE error rate on the test split (Table 1).
    pub dop_error: f64,
    pub points: Vec<PredictorPoint>,
    /// Exponential fit `overhead_ratio(a) = fit.0 · exp(fit.1 · a)`.
    pub overhead_fit: (f64, f64),
    /// Polynomial fit (degree 2) of normalized perf vs accuracy.
    pub perf_fit: Vec<f64>,
    /// Baseline (no-prediction) layer latency at this skewness, seconds.
    pub baseline_s: f64,
}

impl WorkloadCalibration {
    /// Fitted overhead (seconds) at a given accuracy.
    pub fn overhead_s_at(&self, accuracy: f64) -> f64 {
        self.overhead_fit.0 * (self.overhead_fit.1 * accuracy).exp() * self.baseline_s
    }
}

/// Knobs for the calibration run.
#[derive(Clone, Debug)]
pub struct CalibrationOptions {
    pub batch: usize,
    pub seq: usize,
    /// Train/test split fraction (paper: 80/20).
    pub train_frac: f64,
    /// Reduced trace + MLP budget for tests/smoke runs.
    pub fast: bool,
}

impl Default for CalibrationOptions {
    fn default() -> Self {
        CalibrationOptions {
            batch: 1,
            seq: 512,
            train_frac: 0.8,
            fast: false,
        }
    }
}

/// Run the full calibration pipeline on one trace spec.
pub fn calibrate(
    mut spec: TraceSpec,
    model: &ModelConfig,
    system: &SystemSpec,
    opts: &CalibrationOptions,
) -> WorkloadCalibration {
    if opts.fast {
        spec.n_batches = spec.n_batches.min(16);
        spec.sequences_per_batch = spec.sequences_per_batch.min(4);
        spec.seq_len = spec.seq_len.min(128);
        spec.vocab_size = spec.vocab_size.min(512);
    }
    let trace = Trace::generate(spec.clone());
    let skew = trace.avg_skewness();
    let (train, test) = trace.split(opts.train_frac);

    // Distribution-Only error (Table 1).
    let mut est = DistributionEstimator::new(spec.n_experts);
    est.fit(&train);
    let dop_error = est.error_rate(&test);

    let sim = LayerSim::new(model.clone(), system.clone())
        .with_workload(opts.batch, opts.seq);
    let baseline_s = sim.baseline_total(skew);

    // Predictor zoo: (trained predictor, overhead kind it is priced as).
    // The bigram context model stands in for the paper's LSTM (it captures
    // the same context signal) and is priced at the LSTM's serial-scan
    // cost; the MLP stands in for the paper's FFN net (see DESIGN.md §3).
    let mlp_cfg = |hidden: usize| MlpConfig {
        d_emb: 16,
        hidden,
        epochs: if opts.fast { 2 } else { 3 },
        lr: 2e-3,
        seed: spec.seed ^ hidden as u64,
    };
    let mut zoo: Vec<(Box<dyn Predictor>, PredictorKind)> = vec![
        (
            Box::new(ProbabilityModel::new()),
            PredictorKind::Probability,
        ),
        (
            Box::new(ConditionalModel::new(Conditioning::Position)),
            PredictorKind::ConditionalPosition,
        ),
        (
            Box::new(ConditionalModel::new(Conditioning::TokenId)),
            PredictorKind::ConditionalToken,
        ),
        (
            Box::new(MlpPredictor::new(mlp_cfg(64))),
            PredictorKind::PaperFfn,
        ),
        (
            Box::new(BigramModel::new()),
            PredictorKind::PaperLstm,
        ),
    ];

    let mut points = Vec::new();
    let k = model.top_k.clamp(1, spec.n_experts);
    for (predictor, kind) in zoo.iter_mut() {
        // The Figure-4 zoo prices Token-to-Expert predictors; a DOP
        // estimator slipping in would be scored through the broadcast
        // fallback and silently mis-priced as a per-token classifier.
        assert_eq!(
            predictor.family(),
            crate::predictor::PredictorFamily::TokenToExpert,
            "calibration zoo entry {} is not a TEP predictor",
            predictor.name()
        );
        predictor.fit(&train);
        let ev = accuracy::evaluate(predictor.as_ref(), &test, k);
        let acc = ev.top1;
        let ovh = overhead::overhead_s(*kind, model, system, opts.batch, opts.seq);
        let perf = sim.normalized_performance(
            skew,
            Strategy::TokenToExpert {
                accuracy: acc,
                overhead_s: ovh,
            },
        );
        points.push(PredictorPoint {
            name: predictor.name(),
            accuracy: acc,
            topk_accuracy: ev.topk,
            dist_l1: ev.dist_l1,
            overhead_s: ovh,
            overhead_ratio: ovh / baseline_s,
            normalized_perf: perf,
        });
    }

    // Paper fits: exponential overhead(accuracy), polynomial perf(accuracy).
    points.sort_by(|a, b| a.accuracy.total_cmp(&b.accuracy));
    let xs: Vec<f64> = points.iter().map(|p| p.accuracy).collect();
    let ratio_ys: Vec<f64> = points.iter().map(|p| p.overhead_ratio.max(1e-9)).collect();
    let overhead_fit = stats::fit_exponential(&xs, &ratio_ys);
    let perf_ys: Vec<f64> = points.iter().map(|p| p.normalized_perf).collect();
    let perf_fit = stats::fit_polynomial(&xs, &perf_ys, 2.min(xs.len() - 1));

    WorkloadCalibration {
        workload: spec.name.clone(),
        skewness: skew,
        dop_error,
        points,
        overhead_fit,
        perf_fit,
        baseline_s,
    }
}

/// Calibrate all three dataset emulators (the standard bench preamble).
pub fn calibrate_all(
    model: &ModelConfig,
    system: &SystemSpec,
    fast: bool,
    seed: u64,
) -> Vec<WorkloadCalibration> {
    let opts = CalibrationOptions {
        fast,
        ..Default::default()
    };
    crate::trace::datasets::all(seed)
        .into_iter()
        .map(|spec| calibrate(spec, model, system, &opts))
        .collect()
}

/// Interpolate calibrations to an arbitrary skewness: DOP error and the
/// overhead-fit parameters vary with skew (the paper interpolates between
/// measured datasets the same way, §4).
pub fn interpolate_for_skew(cals: &[WorkloadCalibration], skew: f64) -> (f64, (f64, f64)) {
    assert!(!cals.is_empty());
    let mut sorted: Vec<&WorkloadCalibration> = cals.iter().collect();
    sorted.sort_by(|a, b| a.skewness.total_cmp(&b.skewness));
    if skew <= sorted[0].skewness {
        return (sorted[0].dop_error, sorted[0].overhead_fit);
    }
    if skew >= sorted.last().unwrap().skewness {
        let last = sorted.last().unwrap();
        return (last.dop_error, last.overhead_fit);
    }
    for pair in sorted.windows(2) {
        let (lo, hi) = (pair[0], pair[1]);
        if skew >= lo.skewness && skew <= hi.skewness {
            let t = (skew - lo.skewness) / (hi.skewness - lo.skewness).max(1e-9);
            let err = lo.dop_error * (1.0 - t) + hi.dop_error * t;
            // Interpolate ln(a) and b of the exponential.
            let ln_a =
                lo.overhead_fit.0.max(1e-12).ln() * (1.0 - t) + hi.overhead_fit.0.max(1e-12).ln() * t;
            let b = lo.overhead_fit.1 * (1.0 - t) + hi.overhead_fit.1 * t;
            return (err, (ln_a.exp(), b));
        }
    }
    unreachable!()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::datasets;

    fn fast_opts() -> CalibrationOptions {
        CalibrationOptions {
            fast: true,
            ..Default::default()
        }
    }

    #[test]
    fn calibration_produces_ordered_points() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let cal = calibrate(datasets::mmlu_like(61), &model, &system, &fast_opts());
        assert_eq!(cal.points.len(), 5);
        assert!(cal.skewness > 1.0);
        assert!(cal.dop_error >= 0.0 && cal.dop_error < 1.0);
        assert!(cal.baseline_s > 0.0);
        // Points sorted by accuracy; all within [0,1].
        for w in cal.points.windows(2) {
            assert!(w[0].accuracy <= w[1].accuracy);
        }
        // Conditional-token must beat plain probability on these traces.
        let acc_of = |name: &str| {
            cal.points
                .iter()
                .find(|p| p.name == name)
                .unwrap()
                .accuracy
        };
        assert!(acc_of("conditional-token") > acc_of("probability"));
    }

    #[test]
    fn overhead_fit_is_increasing_in_accuracy() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let cal = calibrate(datasets::mmlu_like(62), &model, &system, &fast_opts());
        // The exponential fit should produce higher overhead at higher
        // accuracy (b > 0) — the paper's core trade-off.
        assert!(
            cal.overhead_fit.1 > 0.0,
            "fit={:?} points={:?}",
            cal.overhead_fit,
            cal.points
                .iter()
                .map(|p| (p.accuracy, p.overhead_ratio))
                .collect::<Vec<_>>()
        );
        assert!(cal.overhead_s_at(0.9) > cal.overhead_s_at(0.5));
    }

    #[test]
    fn interpolation_brackets_inputs() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c1 = calibrate(datasets::mmlu_like(63), &model, &system, &fast_opts());
        let c2 = calibrate(datasets::sst2_like(64), &model, &system, &fast_opts());
        let cals = vec![c1.clone(), c2.clone()];
        let mid_skew = 0.5 * (c1.skewness + c2.skewness);
        let (err, _fit) = interpolate_for_skew(&cals, mid_skew);
        let (lo, hi) = (
            c1.dop_error.min(c2.dop_error),
            c1.dop_error.max(c2.dop_error),
        );
        assert!(err >= lo - 1e-12 && err <= hi + 1e-12);
        // Out-of-range clamps.
        let (err_low, _) = interpolate_for_skew(&cals, 0.5);
        assert!((err_low - cals[0].dop_error.min(cals[1].dop_error)).abs() < 1.0);
    }
}
