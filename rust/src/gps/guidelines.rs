//! The Figure-1 guideline output: a decision map over (skewness ×
//! interconnect bandwidth) telling a system designer which prediction
//! strategy minimises end-to-end latency.

use super::calibrate::WorkloadCalibration;
use super::select::{recommend, strategy_savings_in, Recommendation, Regime};
use crate::model::ModelConfig;
use crate::sim::hardware::SystemSpec;

/// One cell of the guideline decision map.
#[derive(Clone, Debug)]
pub struct GuidelineCell {
    pub skewness: f64,
    pub bandwidth_gbs: f64,
    pub recommendation: Recommendation,
    /// Relative saving of the winning strategy vs baseline.
    pub saving_frac: f64,
}

/// Compute the decision map over a (skew × bandwidth) grid under the
/// paper's plain regime ([`Regime::default`]).
pub fn decision_map(
    model: &ModelConfig,
    cals: &[WorkloadCalibration],
    skews: &[f64],
    bandwidths_gbs: &[f64],
    batch: usize,
    seq: usize,
) -> Vec<GuidelineCell> {
    decision_map_in(
        model,
        cals,
        skews,
        bandwidths_gbs,
        batch,
        seq,
        Regime::default(),
    )
}

/// The fully-general decision map, priced under an explicit [`Regime`]:
/// `overlap` re-derives every cell's DOP-vs-TEP crossover under the
/// ADR-002 lookahead engine (`advise --overlap`); `speculative` hides
/// TEP's repair scatter under the confirmed tiles' FFN compute, shifting
/// the frontier toward TEP (`advise --speculative`); `memory_cap_bytes`
/// is the ADR-004 constrained-HBM budget (`advise --memory-cap`) — a cap
/// below the duplicated working set charges the prediction strategies
/// exposed refetch transfer, shifting low-saving cells toward
/// no-prediction and re-drawing the DOP/TEP frontier for memory-starved
/// systems; `horizon`/`forecast_drift` price ADR-006 proactive
/// replanning (`advise --horizon`) — DOP's duplication movement prewarms
/// fully ahead of the boundary but the plan runs `drift × horizon`
/// staler, so the horizon shifts movement-bound cells toward DOP and
/// drift-sensitive cells away from it.
pub fn decision_map_in(
    model: &ModelConfig,
    cals: &[WorkloadCalibration],
    skews: &[f64],
    bandwidths_gbs: &[f64],
    batch: usize,
    seq: usize,
    regime: Regime,
) -> Vec<GuidelineCell> {
    let mut cells = Vec::new();
    for &bw in bandwidths_gbs {
        let system = SystemSpec::four_a100_custom_bw(bw);
        for &skew in skews {
            let cmp = strategy_savings_in(model, &system, cals, skew, batch, seq, regime);
            let rec = recommend(&cmp);
            let best_saving = cmp.dop_saving_s.max(cmp.tep_best_saving_s).max(0.0);
            cells.push(GuidelineCell {
                skewness: skew,
                bandwidth_gbs: bw,
                recommendation: rec,
                saving_frac: best_saving / cmp.baseline_s,
            });
        }
    }
    cells
}

/// Describe where two decision maps over the same grid disagree — the
/// cells lookahead overlap flips (rendered by `advise --overlap`).
pub fn render_flips(base: &[GuidelineCell], overlap: &[GuidelineCell]) -> String {
    debug_assert_eq!(base.len(), overlap.len());
    let flips: Vec<String> = base
        .iter()
        .zip(overlap)
        .filter(|(a, b)| a.recommendation != b.recommendation)
        .map(|(a, b)| {
            format!(
                "  skew {:.1} @ {:.0} GB/s: {} -> {}",
                a.skewness,
                a.bandwidth_gbs,
                a.recommendation.name(),
                b.recommendation.name()
            )
        })
        .collect();
    if flips.is_empty() {
        "overlap flips no cells on this grid".to_string()
    } else {
        format!(
            "overlap flips {} of {} cells vs the non-overlap map:\n{}",
            flips.len(),
            base.len(),
            flips.join("\n")
        )
    }
}

/// Render the decision map as the Figure-1-style ASCII chart
/// (rows = bandwidth, columns = skewness; D = Distribution-Only,
/// T = Token-to-Expert, - = no prediction).
pub fn render_map(cells: &[GuidelineCell], skews: &[f64], bandwidths: &[f64]) -> String {
    let mut out = String::new();
    out.push_str("MoE-GPS guideline (D = Distribution-Only, T = Token-to-Expert, . = none)\n");
    out.push_str("bandwidth \\ skew |");
    for s in skews {
        out.push_str(&format!("{s:>6.1}"));
    }
    out.push('\n');
    for &bw in bandwidths {
        out.push_str(&format!("{bw:>9.0} GB/s   |"));
        for &s in skews {
            let cell = cells
                .iter()
                .find(|c| c.bandwidth_gbs == bw && c.skewness == s)
                .expect("cell must exist");
            let ch = match cell.recommendation {
                Recommendation::DistributionOnly => 'D',
                Recommendation::TokenToExpert => 'T',
                Recommendation::NoPrediction => '.',
            };
            out.push_str(&format!("{ch:>6}"));
        }
        out.push('\n');
    }
    out
}

/// The paper's Figure-1 prose guidance, derived from the map: where each
/// strategy dominates.
pub fn summarize(cells: &[GuidelineCell]) -> String {
    let dop: Vec<&GuidelineCell> = cells
        .iter()
        .filter(|c| c.recommendation == Recommendation::DistributionOnly)
        .collect();
    let tep: Vec<&GuidelineCell> = cells
        .iter()
        .filter(|c| c.recommendation == Recommendation::TokenToExpert)
        .collect();
    let mean = |xs: &[&GuidelineCell], f: fn(&GuidelineCell) -> f64| -> f64 {
        if xs.is_empty() {
            return f64::NAN;
        }
        xs.iter().map(|c| f(c)).sum::<f64>() / xs.len() as f64
    };
    format!(
        "Distribution-Only wins in {}/{} cells (mean skew {:.2}, mean bw {:.0} GB/s);\n\
         Token-to-Expert wins in {}/{} cells (mean skew {:.2}, mean bw {:.0} GB/s).\n\
         Guideline: prefer Distribution-Only when communication is fast or skew is low;\n\
         prefer Token-to-Expert under slow interconnects and high skew (paper Figure 1).",
        dop.len(),
        cells.len(),
        mean(&dop, |c| c.skewness),
        mean(&dop, |c| c.bandwidth_gbs),
        tep.len(),
        cells.len(),
        mean(&tep, |c| c.skewness),
        mean(&tep, |c| c.bandwidth_gbs),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::calibrate::{calibrate, CalibrationOptions};
    use crate::trace::datasets;

    #[test]
    fn map_covers_grid_and_renders() {
        let model = ModelConfig::mixtral_8x7b();
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        let system = SystemSpec::four_a100_nvlink();
        let cals = vec![
            calibrate(datasets::mmlu_like(91), &model, &system, &opts),
            calibrate(datasets::sst2_like(92), &model, &system, &opts),
        ];
        let skews = [1.0, 2.0, 4.0];
        let bws = [600.0, 64.0];
        let cells = decision_map(&model, &cals, &skews, &bws, 1, 512);
        assert_eq!(cells.len(), 6);
        let chart = render_map(&cells, &skews, &bws);
        assert!(chart.contains("600 GB/s"));
        assert!(chart.contains('D') || chart.contains('T'));
        let summary = summarize(&cells);
        assert!(summary.contains("Distribution-Only wins"));
    }

    #[test]
    fn overlap_map_same_grid_and_flips_render() {
        let model = ModelConfig::mixtral_8x7b();
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        let system = SystemSpec::four_a100_nvlink();
        let cals = vec![
            calibrate(datasets::mmlu_like(93), &model, &system, &opts),
            calibrate(datasets::sst2_like(94), &model, &system, &opts),
        ];
        let skews = [1.2, 2.0];
        let bws = [600.0, 64.0];
        let base = decision_map(&model, &cals, &skews, &bws, 1, 512);
        let over = decision_map_in(
            &model,
            &cals,
            &skews,
            &bws,
            1,
            512,
            Regime { overlap: true, ..Regime::default() },
        );
        assert_eq!(base.len(), over.len());
        for (a, b) in base.iter().zip(&over) {
            assert_eq!(a.skewness, b.skewness);
            assert_eq!(a.bandwidth_gbs, b.bandwidth_gbs);
        }
        let flips = render_flips(&base, &over);
        assert!(flips.contains("flips"), "flips text: {flips}");
    }
}
