//! Online cost-model calibration (ADR 005): turn measured serving
//! metrics into the fitted constants the GPS decision machinery prices
//! strategies with — closing the sim-vs-measured gap the ROADMAP flagged
//! (LRU refetch calibration, overlap-guidance validation).
//!
//! The flow: every serving round / decode step reduces to one
//! [`WindowSample`]; a rolling [`OnlineCalibrator`] over the last N
//! samples fits [`MeasuredConstants`] — mean routing skew, effective
//! interconnect bandwidth (moved bytes over transfer seconds), the live
//! Table-1 share error, realized Token-to-Expert top-k accuracy, hidden/
//! refetch transfer fractions, and the per-token cost. The constants plug
//! straight back into the *existing* `gps::select` pricing
//! ([`MeasuredConstants::savings`] overrides the workload calibrations and
//! the system spec, then calls `strategy_savings_in` /
//! `decode_strategy_savings_in`), so the strategy controller re-decides
//! DOP/TEP/speculative from measurements through the same code path
//! `advise` prices statically.
//!
//! [`calibration_check`] is the drift gate: fit the per-token cost on the
//! run's first half, predict the second half's throughput, report the
//! relative delta — `advise --from-serve --max-delta` turns silent
//! cost-model rot into a CI failure.

use std::collections::VecDeque;

use anyhow::Result;

use crate::gps::calibrate::WorkloadCalibration;
use crate::gps::select::{
    decode_strategy_savings_in, strategy_savings_in, Regime, SavingsComparison, ServePhase,
};
use crate::model::ModelConfig;
use crate::sim::hardware::{InterconnectSpec, SystemSpec};
use crate::util::json::Value;
use crate::util::stats;

/// One serving round's / decode step's calibration-relevant measurements
/// — the reduction of `RoundMetrics` / `DecodeStepMetrics` the estimator
/// windows over (`From` impls live here so the metrics structs stay
/// measurement-only).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowSample {
    /// Tokens processed (prompt tokens for prefill; prefill + decode rows
    /// for a decode step).
    pub tokens: f64,
    /// Prompt tokens a decode step carried for newly admitted sequences
    /// (0 for steady-state decode steps and for prefill rounds, which
    /// are phase-homogeneous). Lets the calibration check score drift on
    /// like-for-like samples instead of the prefill/decode phase mix.
    pub prefill_tokens: f64,
    pub total_s: f64,
    pub routing_skew: f64,
    pub upload_bytes: f64,
    pub hidden_upload_bytes: f64,
    pub exposed_upload_bytes: f64,
    pub hidden_transfer_s: f64,
    pub exposed_transfer_s: f64,
    pub refetch_upload_bytes: f64,
    pub predictor_s: f64,
    pub pred_slots: f64,
    pub pred_tokens: f64,
    pub pred_topk_hits: f64,
    pub pred_top1_hits: f64,
    pub pred_share_l1: f64,
    pub pred_share_layers: f64,
    /// Realized horizon-forecast L1 error over the forecasts that matured
    /// this window (ADR 006; 0 with no matured forecast — weight by
    /// `forecast_layers`).
    pub forecast_l1: f64,
    /// Matured (layer, forecast) pairs this window (0 at horizon 0).
    pub forecast_layers: f64,
    /// Host bytes deep-copied on the data plane this window (ADR 009).
    pub bytes_copied: f64,
    /// Host bytes moved by `Arc` reference instead of copied (ADR 009).
    pub bytes_shared: f64,
}

impl From<&crate::coordinator::metrics::RoundMetrics> for WindowSample {
    fn from(m: &crate::coordinator::metrics::RoundMetrics) -> WindowSample {
        WindowSample {
            tokens: m.n_tokens as f64,
            prefill_tokens: 0.0,
            total_s: m.total_s,
            routing_skew: m.routing_skew,
            upload_bytes: m.upload_bytes as f64,
            hidden_upload_bytes: m.hidden_upload_bytes as f64,
            exposed_upload_bytes: m.exposed_upload_bytes as f64,
            hidden_transfer_s: m.hidden_transfer_s,
            exposed_transfer_s: m.exposed_transfer_s,
            refetch_upload_bytes: m.refetch_upload_bytes as f64,
            predictor_s: m.predictor_s,
            pred_slots: m.pred_slots as f64,
            pred_tokens: m.pred_tokens as f64,
            pred_topk_hits: m.pred_topk_hits as f64,
            pred_top1_hits: m.pred_top1_hits as f64,
            pred_share_l1: m.pred_share_l1,
            pred_share_layers: m.pred_share_layers as f64,
            forecast_l1: m.forecast_l1,
            forecast_layers: m.forecast_layers as f64,
            bytes_copied: m.bytes_copied as f64,
            bytes_shared: m.bytes_shared as f64,
        }
    }
}

impl From<&crate::coordinator::metrics::DecodeStepMetrics> for WindowSample {
    fn from(m: &crate::coordinator::metrics::DecodeStepMetrics) -> WindowSample {
        WindowSample {
            tokens: (m.n_prefill_tokens + m.n_decode_tokens) as f64,
            prefill_tokens: m.n_prefill_tokens as f64,
            total_s: m.total_s,
            routing_skew: m.routing_skew,
            upload_bytes: m.upload_bytes as f64,
            hidden_upload_bytes: m.hidden_upload_bytes as f64,
            exposed_upload_bytes: m.exposed_upload_bytes as f64,
            hidden_transfer_s: m.hidden_transfer_s,
            exposed_transfer_s: m.exposed_transfer_s,
            refetch_upload_bytes: m.refetch_upload_bytes as f64,
            predictor_s: m.predictor_s,
            pred_slots: m.pred_slots as f64,
            pred_tokens: m.pred_tokens as f64,
            pred_topk_hits: m.pred_topk_hits as f64,
            pred_top1_hits: m.pred_top1_hits as f64,
            pred_share_l1: m.pred_share_l1,
            pred_share_layers: m.pred_share_layers as f64,
            forecast_l1: m.forecast_l1,
            forecast_layers: m.forecast_layers as f64,
            bytes_copied: m.bytes_copied as f64,
            bytes_shared: m.bytes_shared as f64,
        }
    }
}

impl WindowSample {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("tokens", Value::Num(self.tokens))
            .set("prefill_tokens", Value::Num(self.prefill_tokens))
            .set("total_s", Value::Num(self.total_s))
            .set("routing_skew", Value::Num(self.routing_skew))
            .set("upload_bytes", Value::Num(self.upload_bytes))
            .set("hidden_upload_bytes", Value::Num(self.hidden_upload_bytes))
            .set(
                "exposed_upload_bytes",
                Value::Num(self.exposed_upload_bytes),
            )
            .set("hidden_transfer_s", Value::Num(self.hidden_transfer_s))
            .set("exposed_transfer_s", Value::Num(self.exposed_transfer_s))
            .set(
                "refetch_upload_bytes",
                Value::Num(self.refetch_upload_bytes),
            )
            .set("predictor_s", Value::Num(self.predictor_s))
            .set("pred_slots", Value::Num(self.pred_slots))
            .set("pred_tokens", Value::Num(self.pred_tokens))
            .set("pred_topk_hits", Value::Num(self.pred_topk_hits))
            .set("pred_top1_hits", Value::Num(self.pred_top1_hits))
            .set("pred_share_l1", Value::Num(self.pred_share_l1))
            .set("pred_share_layers", Value::Num(self.pred_share_layers))
            .set("forecast_l1", Value::Num(self.forecast_l1))
            .set("forecast_layers", Value::Num(self.forecast_layers))
            .set("bytes_copied", Value::Num(self.bytes_copied))
            .set("bytes_shared", Value::Num(self.bytes_shared));
        v
    }

    pub fn from_json(v: &Value) -> Option<WindowSample> {
        Some(WindowSample {
            tokens: v.get("tokens")?.as_f64()?,
            prefill_tokens: v.get("prefill_tokens")?.as_f64()?,
            total_s: v.get("total_s")?.as_f64()?,
            routing_skew: v.get("routing_skew")?.as_f64()?,
            upload_bytes: v.get("upload_bytes")?.as_f64()?,
            hidden_upload_bytes: v.get("hidden_upload_bytes")?.as_f64()?,
            exposed_upload_bytes: v.get("exposed_upload_bytes")?.as_f64()?,
            hidden_transfer_s: v.get("hidden_transfer_s")?.as_f64()?,
            exposed_transfer_s: v.get("exposed_transfer_s")?.as_f64()?,
            refetch_upload_bytes: v.get("refetch_upload_bytes")?.as_f64()?,
            predictor_s: v.get("predictor_s")?.as_f64()?,
            pred_slots: v.get("pred_slots")?.as_f64()?,
            pred_tokens: v.get("pred_tokens")?.as_f64()?,
            pred_topk_hits: v.get("pred_topk_hits")?.as_f64()?,
            pred_top1_hits: v.get("pred_top1_hits")?.as_f64()?,
            pred_share_l1: v.get("pred_share_l1")?.as_f64()?,
            pred_share_layers: v.get("pred_share_layers")?.as_f64()?,
            // Absent in pre-ADR-006 reports: default to "no forecast".
            forecast_l1: v.get("forecast_l1").and_then(Value::as_f64).unwrap_or(0.0),
            forecast_layers: v
                .get("forecast_layers")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
            // Absent in pre-ADR-009 reports: default to "not measured".
            bytes_copied: v.get("bytes_copied").and_then(Value::as_f64).unwrap_or(0.0),
            bytes_shared: v.get("bytes_shared").and_then(Value::as_f64).unwrap_or(0.0),
        })
    }
}

/// The fitted cost-model constants a measurement window implies — what
/// the controller re-prices strategies with, and what the serve report
/// records for `advise --from-serve`.
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredConstants {
    /// Samples (rounds / steps) the window held.
    pub samples: usize,
    pub tokens: f64,
    pub tokens_per_s: f64,
    /// Fitted per-token wall cost (the predictive constant the
    /// calibration check scores).
    pub per_token_s: f64,
    /// Mean observed routing skewness — the x-axis of every guideline map.
    pub mean_skew: f64,
    /// Total duplication-transfer bytes the window moved (prewarms, cold
    /// uploads and refetches alike) — 0 once the working set is warm.
    pub upload_bytes: f64,
    /// Effective duplication-transfer bandwidth: moved bytes over
    /// (hidden + exposed) transfer seconds. `None` when the window moved
    /// no replica bytes (static placement, warm cache) — or moved them
    /// only as cold uploads inside `RunBatch`, which carry no transfer-stall
    /// seconds (check `upload_bytes` for that case).
    pub effective_bandwidth_gbs: Option<f64>,
    /// Live Table-1 share error (predicted vs routed shares, layer-
    /// weighted). `None` under NoPrediction.
    pub dop_error: Option<f64>,
    /// Realized TEP top-k set hit rate. `None` when no slot carried a
    /// per-token prediction.
    pub tep_topk_hit: Option<f64>,
    /// Realized TEP argmax accuracy.
    pub tep_top1: Option<f64>,
    /// Fraction of duplication bytes hidden under the lookahead window.
    pub hidden_frac: f64,
    /// Fraction of duplication bytes that were cap-forced refetches — the
    /// measured input the sim's LRU refetch model is calibrated against
    /// (the ROADMAP follow-up this module closes).
    pub refetch_frac: f64,
    /// Fraction of wall time spent in the predictor forward.
    pub predictor_frac: f64,
    /// Realized horizon-forecast L1 error (matured forecasts, layer-
    /// weighted). `None` when no forecast matured in the window — e.g.
    /// horizon 0 (ADR 006). The controller's fallback signal, and the
    /// measured drift [`MeasuredConstants::savings`] substitutes for the
    /// sim's default.
    pub forecast_error: Option<f64>,
}

impl MeasuredConstants {
    /// Re-anchor the offline workload calibrations on measured values:
    /// every calibration's DOP error is scaled by the ratio of the *live*
    /// share error to the prior's interpolated error at the measured skew
    /// — so the skew-dependence the offline fits learned is preserved,
    /// the measured operating point is matched exactly, and an undrifted
    /// workload (measurement == prior) passes the calibrations through
    /// untouched (the `advise --from-serve` map-parity acceptance).
    /// Windows with no prediction signal also pass through.
    pub fn apply_to_cals(&self, cals: &[WorkloadCalibration]) -> Vec<WorkloadCalibration> {
        let Some(err) = self.dop_error else {
            return cals.to_vec();
        };
        if cals.is_empty() {
            return Vec::new();
        }
        let (prior_err, _) = crate::gps::calibrate::interpolate_for_skew(cals, self.mean_skew);
        if prior_err <= 0.0 {
            return cals.to_vec();
        }
        let ratio = err / prior_err;
        cals.iter()
            .cloned()
            .map(|mut c| {
                c.dop_error = (c.dop_error * ratio).clamp(0.0, 2.0);
                c
            })
            .collect()
    }

    /// Override the system spec's interconnect with the measured
    /// effective bandwidth (the duplication path's *achieved* rate, which
    /// is what duplication transfers will actually see — not the nominal
    /// link rate). Passes `base` through when nothing was measured.
    pub fn system_spec(&self, base: &SystemSpec) -> SystemSpec {
        match self.effective_bandwidth_gbs {
            Some(bw) if bw > 0.0 => SystemSpec {
                interconnect: InterconnectSpec::custom(bw),
                ..base.clone()
            },
            _ => base.clone(),
        }
    }

    /// Price the strategy trade-off on the *calibrated* regime: measured
    /// skew, measured bandwidth, measured DOP error — through the same
    /// `gps::select` entry points the static `advise` map uses (ADR 005's
    /// "one code path" requirement).
    pub fn savings(
        &self,
        phase: ServePhase,
        model: &ModelConfig,
        base_system: &SystemSpec,
        cals: &[WorkloadCalibration],
        batch: usize,
        seq_or_ctx: usize,
        regime: Regime,
    ) -> SavingsComparison {
        let sys = self.system_spec(base_system);
        let cals = self.apply_to_cals(cals);
        // Substitute the measured realized forecast error for the sim's
        // default drift: the error was scored at maturation (h steps
        // out), so per-step drift is err / h (ADR 006).
        let mut regime = regime;
        if regime.horizon > 0 && regime.forecast_drift.is_none() {
            regime.forecast_drift = self
                .forecast_error
                .map(|err| err / regime.horizon as f64);
        }
        match phase {
            ServePhase::Prefill => strategy_savings_in(
                model,
                &sys,
                &cals,
                self.mean_skew,
                batch,
                seq_or_ctx,
                regime,
            ),
            ServePhase::Decode => decode_strategy_savings_in(
                model,
                &sys,
                &cals,
                self.mean_skew,
                batch,
                seq_or_ctx,
                regime,
            ),
        }
    }

    pub fn to_json(&self) -> Value {
        let opt = |o: Option<f64>| match o {
            Some(x) => Value::Num(x),
            None => Value::Null,
        };
        let mut v = Value::obj();
        v.set("samples", Value::Num(self.samples as f64))
            .set("tokens", Value::Num(self.tokens))
            .set("tokens_per_s", Value::Num(self.tokens_per_s))
            .set("per_token_s", Value::Num(self.per_token_s))
            .set("mean_skew", Value::Num(self.mean_skew))
            .set("upload_bytes", Value::Num(self.upload_bytes))
            .set(
                "effective_bandwidth_gbs",
                opt(self.effective_bandwidth_gbs),
            )
            .set("dop_error", opt(self.dop_error))
            .set("tep_topk_hit", opt(self.tep_topk_hit))
            .set("tep_top1", opt(self.tep_top1))
            .set("hidden_frac", Value::Num(self.hidden_frac))
            .set("refetch_frac", Value::Num(self.refetch_frac))
            .set("predictor_frac", Value::Num(self.predictor_frac))
            .set("forecast_error", opt(self.forecast_error));
        v
    }

    pub fn from_json(v: &Value) -> Result<MeasuredConstants> {
        let opt = |key: &str| v.get(key).and_then(Value::as_f64);
        Ok(MeasuredConstants {
            samples: v.req_usize("samples")?,
            tokens: v.req_f64("tokens")?,
            tokens_per_s: v.req_f64("tokens_per_s")?,
            per_token_s: v.req_f64("per_token_s")?,
            mean_skew: v.req_f64("mean_skew")?,
            upload_bytes: v.req_f64("upload_bytes")?,
            effective_bandwidth_gbs: opt("effective_bandwidth_gbs"),
            dop_error: opt("dop_error"),
            tep_topk_hit: opt("tep_topk_hit"),
            tep_top1: opt("tep_top1"),
            hidden_frac: v.req_f64("hidden_frac")?,
            refetch_frac: v.req_f64("refetch_frac")?,
            predictor_frac: v.req_f64("predictor_frac")?,
            forecast_error: opt("forecast_error"),
        })
    }
}

/// Rolling-window estimator over serving measurements: push one
/// [`WindowSample`] per round / step, read fitted [`MeasuredConstants`]
/// back. The window bounds how far back the controller trusts — expert-
/// load drift ages out of the estimate after `cap` samples.
#[derive(Clone, Debug)]
pub struct OnlineCalibrator {
    window: VecDeque<WindowSample>,
    cap: usize,
}

impl OnlineCalibrator {
    pub fn new(cap: usize) -> OnlineCalibrator {
        OnlineCalibrator {
            window: VecDeque::with_capacity(cap.max(1)),
            cap: cap.max(1),
        }
    }

    pub fn push(&mut self, sample: WindowSample) {
        if self.window.len() == self.cap {
            self.window.pop_front();
        }
        self.window.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Fit the window into measured constants. `None` until the window
    /// holds at least one sample with tokens and wall time.
    pub fn constants(&self) -> Option<MeasuredConstants> {
        let tokens: f64 = self.window.iter().map(|s| s.tokens).sum();
        let total_s: f64 = self.window.iter().map(|s| s.total_s).sum();
        if tokens <= 0.0 || total_s <= 0.0 {
            return None;
        }
        let skews: Vec<f64> = self
            .window
            .iter()
            .filter(|s| s.tokens > 0.0)
            .map(|s| s.routing_skew)
            .collect();
        let upload: f64 = self.window.iter().map(|s| s.upload_bytes).sum();
        let hidden: f64 = self.window.iter().map(|s| s.hidden_upload_bytes).sum();
        let refetch: f64 = self.window.iter().map(|s| s.refetch_upload_bytes).sum();
        let transfer_s: f64 = self
            .window
            .iter()
            .map(|s| s.hidden_transfer_s + s.exposed_transfer_s)
            .sum();
        let effective_bandwidth_gbs = if upload > 0.0 && transfer_s > 0.0 {
            Some(upload / transfer_s / 1e9)
        } else {
            None
        };
        let share_weight: f64 = self.window.iter().map(|s| s.pred_share_layers).sum();
        let dop_error = if share_weight > 0.0 {
            Some(
                self.window
                    .iter()
                    .map(|s| s.pred_share_l1 * s.pred_share_layers)
                    .sum::<f64>()
                    / share_weight,
            )
        } else {
            None
        };
        let pred_slots: f64 = self.window.iter().map(|s| s.pred_slots).sum();
        let pred_tokens: f64 = self.window.iter().map(|s| s.pred_tokens).sum();
        let tep_topk_hit = if pred_slots > 0.0 {
            Some(self.window.iter().map(|s| s.pred_topk_hits).sum::<f64>() / pred_slots)
        } else {
            None
        };
        // Top-1 is per token (at most one of a token's routed slots can
        // match the argmax), matching the offline harness's definition.
        let tep_top1 = if pred_tokens > 0.0 {
            Some(self.window.iter().map(|s| s.pred_top1_hits).sum::<f64>() / pred_tokens)
        } else {
            None
        };
        let predictor_s: f64 = self.window.iter().map(|s| s.predictor_s).sum();
        let forecast_weight: f64 = self.window.iter().map(|s| s.forecast_layers).sum();
        let forecast_error = if forecast_weight > 0.0 {
            Some(
                self.window
                    .iter()
                    .map(|s| s.forecast_l1 * s.forecast_layers)
                    .sum::<f64>()
                    / forecast_weight,
            )
        } else {
            None
        };
        Some(MeasuredConstants {
            samples: self.window.len(),
            tokens,
            tokens_per_s: tokens / total_s,
            per_token_s: total_s / tokens,
            mean_skew: stats::mean(&skews),
            upload_bytes: upload,
            effective_bandwidth_gbs,
            dop_error,
            tep_topk_hit,
            tep_top1,
            hidden_frac: if upload > 0.0 { hidden / upload } else { 0.0 },
            refetch_frac: if upload > 0.0 { refetch / upload } else { 0.0 },
            predictor_frac: predictor_s / total_s,
            forecast_error,
        })
    }
}

/// The fit-vs-holdout drift check: fit the per-token cost on the first
/// half of the run, predict the second half's throughput, report the
/// relative delta. A small delta means the fitted cost model transfers
/// across the run (undrifted workload); a blown-out delta is the
/// cost-model rot the CI smoke gate catches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationCheck {
    /// Throughput predicted from the first-half fit.
    pub fit_tokens_per_s: f64,
    /// Throughput actually measured on the second half.
    pub holdout_tokens_per_s: f64,
    /// `|fit − holdout| / holdout`.
    pub delta_frac: f64,
}

impl CalibrationCheck {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("fit_tokens_per_s", Value::Num(self.fit_tokens_per_s))
            .set(
                "holdout_tokens_per_s",
                Value::Num(self.holdout_tokens_per_s),
            )
            .set("delta_frac", Value::Num(self.delta_frac));
        v
    }

    pub fn from_json(v: &Value) -> Option<CalibrationCheck> {
        Some(CalibrationCheck {
            fit_tokens_per_s: v.get("fit_tokens_per_s")?.as_f64()?,
            holdout_tokens_per_s: v.get("holdout_tokens_per_s")?.as_f64()?,
            delta_frac: v.get("delta_frac")?.as_f64()?,
        })
    }
}

/// Run the fit-vs-holdout check over a run's samples. `None` below 4
/// usable samples (each half needs ≥ 2 to mean anything).
///
/// Decode runs interleave prefill-heavy admission steps (many prompt
/// rows batch-parallel in one step) with steady one-row decode steps —
/// and admissions cluster at the start, so a naive temporal split would
/// compare the phase mix, not the cost model. When the run has enough
/// steady (no-prefill) samples the check scores only those; phase-
/// homogeneous runs (prefill rounds) use everything.
pub fn calibration_check(samples: &[WindowSample]) -> Option<CalibrationCheck> {
    let steady: Vec<&WindowSample> = samples
        .iter()
        .filter(|s| s.prefill_tokens == 0.0)
        .collect();
    let scored: Vec<&WindowSample> = if steady.len() >= 4 {
        steady
    } else {
        samples.iter().collect()
    };
    if scored.len() < 4 {
        return None;
    }
    let mid = scored.len() / 2;
    let tps = |xs: &[&WindowSample]| -> Option<f64> {
        let t: f64 = xs.iter().map(|s| s.total_s).sum();
        let tok: f64 = xs.iter().map(|s| s.tokens).sum();
        if t > 0.0 && tok > 0.0 {
            Some(tok / t)
        } else {
            None
        }
    };
    let fit = tps(&scored[..mid])?;
    let holdout = tps(&scored[mid..])?;
    Some(CalibrationCheck {
        fit_tokens_per_s: fit,
        holdout_tokens_per_s: holdout,
        delta_frac: (fit - holdout).abs() / holdout,
    })
}

/// The parsed essentials of a `moe-gps/serve-report/v1` file — what
/// `advise --from-serve` consumes.
#[derive(Clone, Debug)]
pub struct ServedReport {
    pub phase: ServePhase,
    pub strategy: String,
    pub tokens_per_s: f64,
    pub measured: MeasuredConstants,
    pub check: Option<CalibrationCheck>,
    /// The engine regime the measurements were produced under.
    pub regime: Regime,
    pub adaptive: bool,
    /// Controller decisions evaluated / actually switched.
    pub decisions: usize,
    pub switches: usize,
    /// Compute pool threads (None for pre-ADR-007 reports).
    pub threads: Option<usize>,
    /// Whether pool helpers were core-pinned.
    pub pinned: bool,
    /// SIMD dispatch tier the kernels ran under (None for old reports).
    pub simd_tier: Option<String>,
    /// Worker deaths over the run (ADR 008; None for old reports).
    pub worker_deaths: Option<u64>,
    /// Rounds/steps served degraded — short-handed or mid-failover
    /// (ADR 008; None for old reports).
    pub degraded_samples: Option<u64>,
    /// Host bytes deep-copied on the data plane (ADR 009; None for old
    /// reports).
    pub bytes_copied: Option<f64>,
    /// Host bytes moved by `Arc` reference (ADR 009; None for old
    /// reports).
    pub bytes_shared: Option<f64>,
    /// Window-weighted worker idle fraction under the wavefront
    /// (ADR 010; None for old reports).
    pub worker_idle_frac: Option<f64>,
    /// Seconds the leader spent blocked on FFN replies with no routing
    /// work left (ADR 010; None for old reports).
    pub leader_stall_s: Option<f64>,
}

/// Parse a serve-report JSON file (see `ServeReport::to_json`). Fails
/// with a diagnostic when the schema tag mismatches or the run recorded
/// no measured constants (an empty serve).
pub fn parse_serve_report(text: &str) -> Result<ServedReport> {
    let v = Value::parse(text).map_err(|e| anyhow::anyhow!("invalid report JSON: {e}"))?;
    let schema = v
        .get("schema")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing `schema` field"))?;
    anyhow::ensure!(
        schema == crate::coordinator::metrics::REPORT_SCHEMA,
        "schema mismatch: got `{schema}`, want `{}`",
        crate::coordinator::metrics::REPORT_SCHEMA
    );
    let meta = v
        .get("meta")
        .ok_or_else(|| anyhow::anyhow!("missing `meta`"))?;
    let phase = match meta.get("phase").and_then(Value::as_str) {
        Some("prefill") => ServePhase::Prefill,
        Some("decode") => ServePhase::Decode,
        other => anyhow::bail!("unknown report phase {other:?}"),
    };
    let lookahead = meta.get("lookahead").and_then(Value::as_usize).unwrap_or(0);
    let speculative = meta
        .get("speculative")
        .and_then(Value::as_bool)
        .unwrap_or(false);
    let memory_cap_bytes = meta.get("memory_cap_bytes").and_then(Value::as_f64);
    let measured = v
        .get("measured")
        .filter(|m| !matches!(m, Value::Null))
        .ok_or_else(|| {
            anyhow::anyhow!("report carries no measured constants (empty serve run?)")
        })?;
    let controller = v.get("controller").filter(|c| !matches!(c, Value::Null));
    let (decisions, switches) = controller
        .and_then(|c| c.get("decisions"))
        .and_then(Value::as_arr)
        .map(|arr| {
            let switched = arr
                .iter()
                .filter(|d| d.get("switched").and_then(Value::as_bool) == Some(true))
                .count();
            (arr.len(), switched)
        })
        .unwrap_or((0, 0));
    Ok(ServedReport {
        phase,
        strategy: v
            .get("strategy")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string(),
        tokens_per_s: v.req_f64("tokens_per_s")?,
        measured: MeasuredConstants::from_json(measured)?,
        check: v
            .get("calibration_check")
            .and_then(CalibrationCheck::from_json),
        regime: Regime {
            overlap: lookahead > 0,
            speculative,
            memory_cap_bytes,
            horizon: meta.get("horizon").and_then(Value::as_usize).unwrap_or(0),
            forecast_drift: None,
            // Pre-ADR-010 reports lack the meta field: 0 means "not
            // recorded", which prices identically to serial (K = 1).
            microbatch: meta
                .get("microbatch")
                .and_then(Value::as_usize)
                .unwrap_or(0),
            // Derived by the caller from `bytes_copied` / tokens when the
            // report measured the data plane (ADR 009 follow-up).
            copied_bytes_per_token: None,
        },
        adaptive: meta
            .get("adaptive")
            .and_then(Value::as_bool)
            .unwrap_or(false),
        decisions,
        switches,
        // Kernel-regime fields (ADR 007) are parsed leniently: reports
        // written before this schema addition simply lack them.
        threads: meta.get("threads").and_then(Value::as_usize).filter(|&t| t > 0),
        pinned: meta.get("pinned").and_then(Value::as_bool).unwrap_or(false),
        simd_tier: meta
            .get("simd_tier")
            .and_then(Value::as_str)
            .filter(|s| !s.is_empty())
            .map(str::to_string),
        // Fault-tolerance fields (ADR 008), equally lenient: pre-ADR-008
        // reports lack them, which is distinct from a clean zero.
        worker_deaths: v.get("worker_deaths").and_then(Value::as_f64).map(|x| x as u64),
        degraded_samples: v
            .get("degraded_samples")
            .and_then(Value::as_f64)
            .map(|x| x as u64),
        // Data-plane copy accounting (ADR 009), same lenient contract.
        bytes_copied: v.get("bytes_copied").and_then(Value::as_f64),
        bytes_shared: v.get("bytes_shared").and_then(Value::as_f64),
        // Wavefront occupancy (ADR 010), same lenient contract.
        worker_idle_frac: v.get("worker_idle_frac").and_then(Value::as_f64),
        leader_stall_s: v.get("leader_stall_s").and_then(Value::as_f64),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(tokens: f64, total_s: f64, skew: f64) -> WindowSample {
        WindowSample {
            tokens,
            total_s,
            routing_skew: skew,
            ..Default::default()
        }
    }

    #[test]
    fn empty_window_has_no_constants() {
        let cal = OnlineCalibrator::new(8);
        assert!(cal.constants().is_none());
    }

    #[test]
    fn constants_fit_throughput_and_skew() {
        let mut cal = OnlineCalibrator::new(8);
        cal.push(sample(100.0, 1.0, 2.0));
        cal.push(sample(300.0, 3.0, 4.0));
        let c = cal.constants().unwrap();
        assert_eq!(c.samples, 2);
        assert!((c.tokens_per_s - 100.0).abs() < 1e-9);
        assert!((c.per_token_s - 0.01).abs() < 1e-12);
        assert!((c.mean_skew - 3.0).abs() < 1e-12);
        assert!(c.effective_bandwidth_gbs.is_none(), "no bytes moved");
        assert!(c.dop_error.is_none());
        assert!(c.tep_topk_hit.is_none());
    }

    #[test]
    fn window_ages_out_old_samples() {
        let mut cal = OnlineCalibrator::new(2);
        cal.push(sample(1000.0, 1.0, 9.0));
        cal.push(sample(100.0, 1.0, 2.0));
        cal.push(sample(100.0, 1.0, 2.0));
        let c = cal.constants().unwrap();
        assert_eq!(c.samples, 2);
        assert!((c.mean_skew - 2.0).abs() < 1e-12, "old skew aged out");
    }

    #[test]
    fn bandwidth_and_fractions_from_transfer_bytes() {
        let mut cal = OnlineCalibrator::new(4);
        let mut s = sample(100.0, 1.0, 2.0);
        s.upload_bytes = 4e9;
        s.hidden_upload_bytes = 3e9;
        s.exposed_upload_bytes = 1e9;
        s.hidden_transfer_s = 1.5;
        s.exposed_transfer_s = 0.5;
        s.refetch_upload_bytes = 1e9;
        cal.push(s);
        let c = cal.constants().unwrap();
        assert!((c.effective_bandwidth_gbs.unwrap() - 2.0).abs() < 1e-9);
        assert!((c.hidden_frac - 0.75).abs() < 1e-12);
        assert!((c.refetch_frac - 0.25).abs() < 1e-12);
    }

    #[test]
    fn prediction_signals_are_weighted_rates() {
        let mut cal = OnlineCalibrator::new(4);
        let mut a = sample(10.0, 1.0, 2.0);
        a.pred_slots = 10.0;
        a.pred_tokens = 5.0;
        a.pred_topk_hits = 8.0;
        a.pred_top1_hits = 4.0;
        a.pred_share_l1 = 0.1;
        a.pred_share_layers = 2.0;
        let mut b = sample(10.0, 1.0, 2.0);
        b.pred_slots = 30.0;
        b.pred_tokens = 15.0;
        b.pred_topk_hits = 12.0;
        b.pred_top1_hits = 6.0;
        b.pred_share_l1 = 0.4;
        b.pred_share_layers = 2.0;
        cal.push(a);
        cal.push(b);
        let c = cal.constants().unwrap();
        // Top-k is per slot; top-1 is per token (the offline harness's
        // definition, so the two columns stay comparable).
        assert!((c.tep_topk_hit.unwrap() - 0.5).abs() < 1e-12);
        assert!((c.tep_top1.unwrap() - 0.5).abs() < 1e-12);
        assert!((c.dop_error.unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn forecast_error_is_layer_weighted_and_optional() {
        let mut cal = OnlineCalibrator::new(4);
        cal.push(sample(10.0, 1.0, 2.0)); // horizon 0: nothing matured
        assert!(cal.constants().unwrap().forecast_error.is_none());
        let mut a = sample(10.0, 1.0, 2.0);
        a.forecast_l1 = 0.2;
        a.forecast_layers = 1.0;
        let mut b = sample(10.0, 1.0, 2.0);
        b.forecast_l1 = 0.5;
        b.forecast_layers = 3.0;
        cal.push(a);
        cal.push(b);
        // (0.2·1 + 0.5·3) / 4 = 0.425
        let c = cal.constants().unwrap();
        assert!((c.forecast_error.unwrap() - 0.425).abs() < 1e-12);
    }

    #[test]
    fn calibration_check_fits_undrifted_runs() {
        let samples: Vec<WindowSample> = (0..8).map(|_| sample(100.0, 0.5, 2.0)).collect();
        let c = calibration_check(&samples).unwrap();
        assert!(c.delta_frac < 1e-12, "steady run: fit == holdout");
        // Drifted second half shows up in the delta.
        let mut drifted = samples.clone();
        for s in drifted.iter_mut().skip(4) {
            s.total_s = 1.0;
        }
        let d = calibration_check(&drifted).unwrap();
        assert!((d.delta_frac - 1.0).abs() < 1e-9, "2x slowdown = 100% delta");
        assert!(calibration_check(&samples[..3]).is_none(), "too short");
    }

    #[test]
    fn calibration_check_ignores_prefill_phase_mix() {
        // Admission steps (prefill-heavy, far higher rows/s) cluster at
        // the start of a decode run; the check must score steady decode
        // steps against each other, not the phase mix.
        let mut samples: Vec<WindowSample> = Vec::new();
        for _ in 0..2 {
            let mut s = sample(200.0, 0.2, 2.0); // 1000 rows/s admission
            s.prefill_tokens = 192.0;
            samples.push(s);
        }
        for _ in 0..8 {
            samples.push(sample(6.0, 0.1, 2.0)); // 60 rows/s steady
        }
        let c = calibration_check(&samples).unwrap();
        assert!(
            c.delta_frac < 1e-12,
            "steady-only scoring must see no drift: {}",
            c.delta_frac
        );
        // Too few steady samples: fall back to scoring everything.
        let c2 = calibration_check(&samples[..5]).unwrap();
        assert!(c2.delta_frac > 0.5, "phase mix shows when unavoidable");
    }

    #[test]
    fn constants_json_round_trip() {
        let mut cal = OnlineCalibrator::new(4);
        let mut s = sample(100.0, 1.0, 2.5);
        s.upload_bytes = 1e9;
        s.hidden_transfer_s = 1.0;
        s.pred_slots = 10.0;
        s.pred_topk_hits = 9.0;
        s.pred_top1_hits = 7.0;
        s.pred_share_l1 = 0.2;
        s.pred_share_layers = 2.0;
        s.bytes_copied = 4096.0;
        s.bytes_shared = 8192.0;
        cal.push(s.clone());
        let c = cal.constants().unwrap();
        let rt = MeasuredConstants::from_json(&c.to_json()).unwrap();
        assert_eq!(c, rt);
        // WindowSample round-trips too.
        assert_eq!(WindowSample::from_json(&s.to_json()).unwrap(), s);
    }

    #[test]
    fn measured_overrides_plug_into_static_machinery() {
        let base = SystemSpec::four_a100_nvlink();
        let c = MeasuredConstants {
            samples: 4,
            tokens: 100.0,
            tokens_per_s: 10.0,
            per_token_s: 0.1,
            mean_skew: 2.0,
            upload_bytes: 1e9,
            effective_bandwidth_gbs: Some(64.0),
            dop_error: Some(0.05),
            tep_topk_hit: Some(0.9),
            tep_top1: Some(0.8),
            hidden_frac: 0.5,
            refetch_frac: 0.0,
            predictor_frac: 0.01,
            forecast_error: None,
        };
        let sys = c.system_spec(&base);
        assert!((sys.interconnect.link_bw_gbs - 64.0).abs() < 1e-12);
        assert_eq!(sys.n_devices, base.n_devices);
        // No measurement → base passes through.
        let none = MeasuredConstants {
            effective_bandwidth_gbs: None,
            ..c.clone()
        };
        assert!(
            (none.system_spec(&base).interconnect.link_bw_gbs
                - base.interconnect.link_bw_gbs)
                .abs()
                < 1e-12
        );
    }
}
