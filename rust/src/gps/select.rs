//! Strategy selection (the decision MoE-GPS exists to make) and the
//! Figure-7 savings-difference series — for both serving phases: the
//! paper's prefill setting and the decode (autoregressive) regime, where
//! the trade-off tilts (DESIGN.md §5: memory-bound FFN, per-step TEP
//! overhead).

use super::calibrate::{interpolate_for_skew, WorkloadCalibration};
use super::sweep::accuracy_grid;
use crate::model::ModelConfig;
use crate::predictor::overhead::{self, PredictorKind};
use crate::sim::hardware::SystemSpec;
use crate::sim::moe::Strategy;
use crate::sim::{DecodeSim, LayerSim};

/// Which serving phase a recommendation is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePhase {
    /// The paper's setting: whole-prompt batches, compute-bound FFN.
    Prefill,
    /// Continuous-batching autoregressive generation: one token per
    /// sequence per step, memory-bound FFN, prediction re-priced per step.
    Decode,
}

impl ServePhase {
    pub fn name(self) -> &'static str {
        match self {
            ServePhase::Prefill => "prefill",
            ServePhase::Decode => "decode",
        }
    }

    pub fn by_name(s: &str) -> anyhow::Result<ServePhase> {
        match s {
            "prefill" => Ok(ServePhase::Prefill),
            "decode" => Ok(ServePhase::Decode),
            other => anyhow::bail!("unknown phase `{other}` (prefill|decode)"),
        }
    }
}

/// Best Token-to-Expert configuration at a skewness: the bottom of the
/// U-shape over the accuracy grid. Returns (accuracy, total_s).
pub fn best_tep(
    sim: &LayerSim,
    skew: f64,
    overhead_fit: (f64, f64),
    baseline_s: f64,
) -> (f64, f64) {
    accuracy_grid()
        .into_iter()
        .map(|acc| {
            let overhead_s = overhead_fit.0 * (overhead_fit.1 * acc).exp() * baseline_s;
            let total = sim
                .breakdown(
                    skew,
                    Strategy::TokenToExpert {
                        accuracy: acc,
                        overhead_s,
                    },
                )
                .total();
            (acc, total)
        })
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap()
}

/// The serving-engine regime a savings comparison is priced under: the
/// ADR-002 lookahead overlap, the ADR-003 speculative scatter riding it,
/// and the ADR-004 constrained-HBM budget. `Regime::default()` is the
/// paper's plain setting (no overlap, no speculation, unbounded memory).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Regime {
    pub overlap: bool,
    pub speculative: bool,
    /// Per-device HBM available for expert weights (ADR 004); `None` =
    /// unbounded.
    pub memory_cap_bytes: Option<f64>,
    /// Proactive forecast horizon in replan steps (ADR 006); 0 = reactive.
    /// Planning for the forecast distribution prewarms DOP's replica
    /// movement ahead of the boundary (hiding it like the overlap window
    /// does) at the price of serving a plan whose distribution is
    /// `forecast_drift × horizon` staler in L1 by maturation.
    pub horizon: usize,
    /// Per-step forecast drift (L1 share error accrued per horizon step).
    /// `None` = the sim's default; `advise --from-serve` substitutes the
    /// measured realized forecast error.
    pub forecast_drift: Option<f64>,
    /// Micro-batch wavefront depth (ADR 010): leader routing for
    /// micro-batches 2..K hides under the previous micro-batch's FFN
    /// window. 0 or 1 = serial (no overlap priced).
    pub microbatch: usize,
    /// Measured data-plane copy traffic in bytes per token (ADR 009):
    /// priced as a host-memory-bandwidth charge on every strategy.
    /// `None` = not measured (no charge); `advise --from-serve`
    /// substitutes the serve report's `bytes_copied / tokens`.
    pub copied_bytes_per_token: Option<f64>,
}

/// Figure-7 row: savings of each strategy vs baseline, and their difference
/// (positive ⇒ Distribution-Only wins).
#[derive(Clone, Debug)]
pub struct SavingsComparison {
    pub skewness: f64,
    pub interconnect_gbs: f64,
    pub baseline_s: f64,
    pub dop_saving_s: f64,
    pub tep_best_saving_s: f64,
    pub tep_best_accuracy: f64,
    /// `dop_saving − tep_saving` (the paper's Figure 7 bar height).
    pub difference_s: f64,
}

/// Compute the savings comparison for one (system, skew) under the
/// paper's plain regime ([`Regime::default`]).
pub fn strategy_savings(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    seq: usize,
) -> SavingsComparison {
    strategy_savings_in(model, system, cals, skew, batch, seq, Regime::default())
}

/// The fully-general savings comparison, priced under an explicit
/// [`Regime`]: `overlap` prices the ADR-002 lookahead engine (prediction
/// + duplication transfers hide under the compute window — TEP's
/// per-batch overhead hides while DOP's transfer is charged where the
/// window is too small); `speculative` additionally hides TEP's
/// misprediction repair scatter under the confirmed tiles' FFN compute
/// (requires `overlap`; DOP and the baseline are untouched); and
/// `memory_cap_bytes` is the ADR-004 constrained-HBM budget — under a
/// tight cap the duplicated replica overflows the per-device weight
/// working set and evicted-then-refetched experts pay exposed transfer.
/// `advise --overlap/--speculative/--memory-cap` re-derive the guideline
/// map through this one entry point.
pub fn strategy_savings_in(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    seq: usize,
    regime: Regime,
) -> SavingsComparison {
    let sim = LayerSim::new(model.clone(), system.clone())
        .with_workload(batch, seq)
        .with_overlap(regime.overlap)
        .with_speculative(regime.speculative && regime.overlap)
        .with_memory_cap(regime.memory_cap_bytes)
        .with_horizon(regime.horizon, regime.forecast_drift)
        .with_microbatch(regime.microbatch.max(1))
        .with_copied_bytes(regime.copied_bytes_per_token.unwrap_or(0.0));
    let baseline_s = sim.baseline_total(skew);
    let (dop_error, overhead_fit) = interpolate_for_skew(cals, skew);
    let dop_s = sim
        .breakdown(skew, Strategy::DistributionOnly { error_rate: dop_error })
        .total();
    let (tep_acc, tep_s) = best_tep(&sim, skew, overhead_fit, baseline_s);
    SavingsComparison {
        skewness: skew,
        interconnect_gbs: system.interconnect.link_bw_gbs,
        baseline_s,
        dop_saving_s: baseline_s - dop_s,
        tep_best_saving_s: baseline_s - tep_s,
        tep_best_accuracy: tep_acc,
        difference_s: (baseline_s - dop_s) - (baseline_s - tep_s),
    }
}

/// Decode-phase savings comparison: the same contract as
/// [`strategy_savings`], priced on the decode-step simulator instead
/// (memory-bound FFN regime, per-step Token-to-Expert overhead — ADR 001).
///
/// TEP's per-step predictor cost is derived from the workload calibration:
/// the exponential fit prices the predictor on the prefill batch
/// (`1 × 512` tokens), so the bandwidth-bound part scales down to the
/// decode batch's token count — but never below the physical floor of
/// running the paper's FFN predictor on `batch` tokens (launch-bound
/// matvecs that do not shrink with the batch).
pub fn decode_strategy_savings(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    ctx_len: usize,
) -> SavingsComparison {
    decode_strategy_savings_in(model, system, cals, skew, batch, ctx_len, Regime::default())
}

/// The decode analogue of [`strategy_savings_in`] (ADR 002/003/004).
pub fn decode_strategy_savings_in(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    ctx_len: usize,
    regime: Regime,
) -> SavingsComparison {
    let sim = DecodeSim::new(model.clone(), system.clone())
        .with_workload(batch, ctx_len)
        .with_overlap(regime.overlap)
        .with_speculative(regime.speculative && regime.overlap)
        .with_memory_cap(regime.memory_cap_bytes)
        .with_horizon(regime.horizon, regime.forecast_drift)
        .with_microbatch(regime.microbatch.max(1))
        .with_copied_bytes(regime.copied_bytes_per_token.unwrap_or(0.0));
    let baseline_s = sim.baseline_step(skew);
    let (dop_error, overhead_fit) = interpolate_for_skew(cals, skew);
    let dop_s = sim.step_total(skew, Strategy::DistributionOnly { error_rate: dop_error });

    let prefill_sim = LayerSim::new(model.clone(), system.clone());
    let prefill_baseline = prefill_sim.baseline_total(skew);
    let prefill_tokens = (prefill_sim.batch * prefill_sim.seq) as f64;
    let floor = overhead::overhead_s(PredictorKind::PaperFfn, model, system, batch, 1);
    let (tep_acc, tep_s) = accuracy_grid()
        .into_iter()
        .map(|acc| {
            let scaled = overhead_fit.0 * (overhead_fit.1 * acc).exp() * prefill_baseline
                * (batch as f64 / prefill_tokens);
            let overhead_s = scaled.max(floor);
            let total = sim.step_total(
                skew,
                Strategy::TokenToExpert {
                    accuracy: acc,
                    overhead_s,
                },
            );
            (acc, total)
        })
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();

    SavingsComparison {
        skewness: skew,
        interconnect_gbs: system.interconnect.link_bw_gbs,
        baseline_s,
        dop_saving_s: baseline_s - dop_s,
        tep_best_saving_s: baseline_s - tep_s,
        tep_best_accuracy: tep_acc,
        difference_s: (baseline_s - dop_s) - (baseline_s - tep_s),
    }
}

/// Phase-dispatching wrapper: `seq_or_ctx` is the prefill sequence length
/// or the decode context depth.
pub fn strategy_savings_for_phase(
    phase: ServePhase,
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    seq_or_ctx: usize,
) -> SavingsComparison {
    match phase {
        ServePhase::Prefill => strategy_savings(model, system, cals, skew, batch, seq_or_ctx),
        ServePhase::Decode => {
            decode_strategy_savings(model, system, cals, skew, batch, seq_or_ctx)
        }
    }
}

/// Which strategy MoE-GPS recommends for a configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recommendation {
    DistributionOnly,
    TokenToExpert,
    /// Neither beats the baseline (rare; e.g. skew 1 with costly predictor).
    NoPrediction,
}

impl Recommendation {
    pub fn name(self) -> &'static str {
        match self {
            Recommendation::DistributionOnly => "distribution-only",
            Recommendation::TokenToExpert => "token-to-expert",
            Recommendation::NoPrediction => "no-prediction",
        }
    }
}

/// The selection rule: the strategy with the largest positive saving.
pub fn recommend(cmp: &SavingsComparison) -> Recommendation {
    let eps = 1e-12;
    if cmp.dop_saving_s <= eps && cmp.tep_best_saving_s <= eps {
        Recommendation::NoPrediction
    } else if cmp.dop_saving_s >= cmp.tep_best_saving_s {
        Recommendation::DistributionOnly
    } else {
        Recommendation::TokenToExpert
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::calibrate::{calibrate, CalibrationOptions};
    use crate::trace::datasets;

    fn cals(model: &ModelConfig, system: &SystemSpec) -> Vec<WorkloadCalibration> {
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        vec![
            calibrate(datasets::mmlu_like(81), model, system, &opts),
            calibrate(datasets::sst2_like(82), model, system, &opts),
        ]
    }

    #[test]
    fn dop_recommended_on_nvlink_low_skew() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let cmp = strategy_savings(&model, &system, &c, 1.4, 1, 512);
        assert!(cmp.dop_saving_s > 0.0);
        assert_eq!(recommend(&cmp), Recommendation::DistributionOnly);
        assert!(cmp.difference_s > 0.0, "Figure 7 bar must be positive");
    }

    #[test]
    fn tep_gains_ground_on_slow_interconnect() {
        // Paper §4 takeaway: TEP becomes more effective when communication
        // is expensive. Its *relative* position vs DOP must improve when
        // moving from NVLink to PCIe (at high skew where accuracy is cheap).
        let model = ModelConfig::mixtral_8x7b();
        let nv = SystemSpec::four_a100_nvlink();
        let pcie = SystemSpec::four_a100_pcie();
        let c_nv = cals(&model, &nv);
        let c_pcie = cals(&model, &pcie);
        let skew = 4.0;
        let on_nv = strategy_savings(&model, &nv, &c_nv, skew, 1, 512);
        let on_pcie = strategy_savings(&model, &pcie, &c_pcie, skew, 1, 512);
        // Normalised difference (relative to baseline) must shrink or flip.
        let rel_nv = on_nv.difference_s / on_nv.baseline_s;
        let rel_pcie = on_pcie.difference_s / on_pcie.baseline_s;
        assert!(
            rel_pcie < rel_nv,
            "TEP should gain on PCIe: nv={rel_nv} pcie={rel_pcie}"
        );
    }

    #[test]
    fn best_tep_is_on_grid_and_finite() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let sim = LayerSim::new(model.clone(), system.clone());
        let baseline = sim.baseline_total(2.0);
        let (acc, total) = best_tep(&sim, 2.0, (0.01, 3.0), baseline);
        assert!(accuracy_grid().contains(&acc));
        assert!(total.is_finite() && total > 0.0);
    }

    const OVERLAP: Regime = Regime {
        overlap: true,
        speculative: false,
        memory_cap_bytes: None,
        horizon: 0,
        forecast_drift: None,
        microbatch: 0,
        copied_bytes_per_token: None,
    };
    const SPECULATIVE: Regime = Regime {
        overlap: true,
        speculative: true,
        memory_cap_bytes: None,
        horizon: 0,
        forecast_drift: None,
        microbatch: 0,
        copied_bytes_per_token: None,
    };

    #[test]
    fn overlap_moves_the_difference_toward_tep() {
        // Both strategies pay the same explicit exposed-transfer charge
        // under overlap, but only TEP additionally hides (part of) its
        // prediction overhead — so the Figure-7 difference (dop − tep
        // saving) can only shrink or hold. The baseline itself never moves
        // (no prediction, no duplication to overlap).
        let model = ModelConfig::mixtral_8x7b();
        for bw in [600.0, 64.0] {
            let system = SystemSpec::four_a100_custom_bw(bw);
            let c = cals(&model, &system);
            for skew in [1.4, 2.0, 3.0] {
                let plain = strategy_savings(&model, &system, &c, skew, 1, 512);
                let over =
                    strategy_savings_in(&model, &system, &c, skew, 1, 512, OVERLAP);
                assert!(
                    (plain.baseline_s - over.baseline_s).abs() < 1e-12,
                    "baseline unchanged"
                );
                assert!(
                    over.difference_s <= plain.difference_s + 1e-12,
                    "difference must move toward TEP at bw={bw} skew={skew}: \
                     {} -> {}",
                    plain.difference_s,
                    over.difference_s
                );
            }
        }
    }

    #[test]
    fn speculative_regime_moves_the_difference_further_toward_tep() {
        // ADR 003: speculation only ever hides more TEP scatter, so vs
        // plain overlap the tep saving can only grow and the Figure-7
        // difference can only shrink; DOP and the baseline never move.
        let model = ModelConfig::mixtral_8x7b();
        for bw in [600.0, 64.0] {
            let system = SystemSpec::four_a100_custom_bw(bw);
            let c = cals(&model, &system);
            for skew in [1.4, 2.0, 3.0] {
                let over = strategy_savings_in(&model, &system, &c, skew, 1, 512, OVERLAP);
                let spec =
                    strategy_savings_in(&model, &system, &c, skew, 1, 512, SPECULATIVE);
                assert!((spec.baseline_s - over.baseline_s).abs() < 1e-15);
                assert!((spec.dop_saving_s - over.dop_saving_s).abs() < 1e-15);
                assert!(
                    spec.tep_best_saving_s >= over.tep_best_saving_s - 1e-15,
                    "speculation must not hurt TEP at bw={bw} skew={skew}"
                );
                assert!(spec.difference_s <= over.difference_s + 1e-15);
            }
        }
        // Decode regime obeys the same ordering.
        let system = SystemSpec::four_a100_pcie();
        let c = cals(&model, &system);
        let over = decode_strategy_savings_in(&model, &system, &c, 2.0, 16, 512, OVERLAP);
        let spec =
            decode_strategy_savings_in(&model, &system, &c, 2.0, 16, 512, SPECULATIVE);
        assert!(spec.tep_best_saving_s >= over.tep_best_saving_s - 1e-15);
        // Without overlap the flag is inert (speculation rides lookahead).
        let plain = strategy_savings(&model, &system, &c, 2.0, 1, 512);
        let spec_no_overlap = strategy_savings_in(
            &model,
            &system,
            &c,
            2.0,
            1,
            512,
            Regime { overlap: false, ..SPECULATIVE },
        );
        assert!((plain.tep_best_saving_s - spec_no_overlap.tep_best_saving_s).abs() < 1e-15);
    }

    #[test]
    fn overlap_flips_a_crossover_cell_somewhere() {
        // The acceptance check behind `advise --overlap`: over a grid
        // spanning the decision boundary, at least one cell's
        // recommendation must differ between the two regimes.
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let mut flipped = 0usize;
        for bw in [600.0, 300.0, 128.0, 64.0, 32.0, 16.0] {
            let sys = SystemSpec::four_a100_custom_bw(bw);
            for skew in [1.0, 1.2, 1.4, 1.7, 2.0, 2.5, 3.0, 4.0, 5.0] {
                let plain = strategy_savings(&model, &sys, &c, skew, 1, 512);
                let over = strategy_savings_in(&model, &sys, &c, skew, 1, 512, OVERLAP);
                if recommend(&plain) != recommend(&over) {
                    flipped += 1;
                }
            }
        }
        assert!(flipped > 0, "overlap must flip at least one guideline cell");
    }

    #[test]
    fn memory_cap_shrinks_prediction_savings_and_flips_a_cell() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        // Cap sized between the baseline working set (no replicas) and the
        // duplicated one: prediction strategies pay refetch, baseline not.
        let base_needed = model.n_layers as f64
            * (model.n_experts as f64 / system.n_devices as f64)
            * model.expert_bytes();
        let capped = Regime {
            memory_cap_bytes: Some(base_needed),
            ..Regime::default()
        };
        let mut flipped = 0usize;
        for bw in [600.0, 300.0, 128.0, 64.0] {
            let sys = SystemSpec::four_a100_custom_bw(bw);
            for skew in [1.0, 1.05, 1.1, 1.2, 1.4, 2.0, 3.0, 4.0] {
                let plain = strategy_savings(&model, &sys, &c, skew, 1, 512);
                let tight = strategy_savings_in(&model, &sys, &c, skew, 1, 512, capped);
                assert!(
                    (plain.baseline_s - tight.baseline_s).abs() < 1e-12,
                    "baseline fits under this cap and must not move"
                );
                assert!(
                    tight.dop_saving_s <= plain.dop_saving_s + 1e-12,
                    "refetch can only shrink DOP's saving (bw={bw} skew={skew})"
                );
                assert!(tight.tep_best_saving_s <= plain.tep_best_saving_s + 1e-12);
                if recommend(&plain) != recommend(&tight) {
                    flipped += 1;
                }
            }
        }
        assert!(
            flipped > 0,
            "a cap below the duplicated working set must flip ≥ 1 cell"
        );
        // Decode regime obeys the same ordering.
        let plain = decode_strategy_savings(&model, &system, &c, 2.0, 16, 512);
        let tight = decode_strategy_savings_in(&model, &system, &c, 2.0, 16, 512, capped);
        assert!(tight.dop_saving_s <= plain.dop_saving_s + 1e-12);
        // A roomy cap is a no-op in both phases.
        let roomy = Regime {
            memory_cap_bytes: Some(base_needed * 10.0),
            ..Regime::default()
        };
        let same = strategy_savings_in(&model, &system, &c, 2.0, 1, 512, roomy);
        let plain_prefill = strategy_savings(&model, &system, &c, 2.0, 1, 512);
        assert!((same.dop_saving_s - plain_prefill.dop_saving_s).abs() < 1e-12);
        assert!((same.tep_best_saving_s - plain_prefill.tep_best_saving_s).abs() < 1e-12);
    }

    #[test]
    fn horizon_trades_prewarm_hiding_against_staleness() {
        // ADR 006: a perfect forecast (drift 0) only ever helps DOP — the
        // replica prewarms off the serving step — while a drifting one
        // erodes the win as the horizon grows; TEP and the baseline never
        // move, so the Figure-7 frontier shifts through DOP alone.
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let at = |h: usize, drift: Option<f64>| {
            strategy_savings_in(
                &model,
                &system,
                &c,
                2.0,
                1,
                512,
                Regime { horizon: h, forecast_drift: drift, ..OVERLAP },
            )
        };
        let reactive = at(0, None);
        let perfect = at(4, Some(0.0));
        assert!((perfect.baseline_s - reactive.baseline_s).abs() < 1e-15);
        assert!(
            (perfect.tep_best_saving_s - reactive.tep_best_saving_s).abs() < 1e-15,
            "a load trajectory buys per-token prediction nothing"
        );
        assert!(perfect.dop_saving_s >= reactive.dop_saving_s - 1e-15);
        // Staleness is monotone: more horizon under drift, less DOP win.
        let near = at(1, None);
        let far = at(8, None);
        assert!(
            far.dop_saving_s <= near.dop_saving_s + 1e-15,
            "drift × horizon must erode DOP: h=1 {} vs h=8 {}",
            near.dop_saving_s,
            far.dop_saving_s
        );
        // Decode obeys the same orderings.
        let d_at = |h: usize, drift: Option<f64>| {
            decode_strategy_savings_in(
                &model,
                &system,
                &c,
                2.0,
                16,
                512,
                Regime { horizon: h, forecast_drift: drift, ..OVERLAP },
            )
        };
        let d_reactive = d_at(0, None);
        let d_perfect = d_at(4, Some(0.0));
        assert!(d_perfect.dop_saving_s >= d_reactive.dop_saving_s - 1e-15);
        assert!(
            d_at(8, None).dop_saving_s <= d_at(1, None).dop_saving_s + 1e-15
        );
    }

    #[test]
    fn microbatch_and_copied_bytes_regimes_price_sanely() {
        // ADR 010: a micro-batch depth > 1 hides leader routing under the
        // FFN window, so every strategy's total can only shrink — the
        // baseline moves too (the wavefront is an engine regime, not a
        // prediction strategy). Depths 0 and 1 are exact no-ops.
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let at = |mb: usize| {
            strategy_savings_in(
                &model,
                &system,
                &c,
                2.0,
                1,
                512,
                Regime { microbatch: mb, ..Regime::default() },
            )
        };
        let plain = strategy_savings(&model, &system, &c, 2.0, 1, 512);
        let serial = at(1);
        assert!((serial.baseline_s - plain.baseline_s).abs() < 1e-15);
        assert!((serial.dop_saving_s - plain.dop_saving_s).abs() < 1e-15);
        let wave = at(4);
        assert!(
            wave.baseline_s <= plain.baseline_s + 1e-15,
            "hiding routing can only shrink the baseline: {} -> {}",
            plain.baseline_s,
            wave.baseline_s
        );
        assert!(wave.baseline_s.is_finite() && wave.baseline_s > 0.0);
        // Deeper wavefronts hide monotonically more (asymptote min(r, f)).
        assert!(at(8).baseline_s <= wave.baseline_s + 1e-15);

        // ADR 009 follow-up: measured copy traffic is a strategy-
        // independent host-bandwidth charge — totals grow, savings don't
        // move (every strategy moves the same activation bytes).
        let copied = strategy_savings_in(
            &model,
            &system,
            &c,
            2.0,
            1,
            512,
            Regime {
                copied_bytes_per_token: Some(4096.0 * 4.0),
                ..Regime::default()
            },
        );
        assert!(copied.baseline_s > plain.baseline_s);
        assert!((copied.dop_saving_s - plain.dop_saving_s).abs() < 1e-12);
        assert!((copied.tep_best_saving_s - plain.tep_best_saving_s).abs() < 1e-12);

        // Decode obeys the same orderings.
        let d_plain = decode_strategy_savings(&model, &system, &c, 2.0, 16, 512);
        let d_wave = decode_strategy_savings_in(
            &model,
            &system,
            &c,
            2.0,
            16,
            512,
            Regime { microbatch: 4, ..Regime::default() },
        );
        assert!(d_wave.baseline_s <= d_plain.baseline_s + 1e-15);
    }

    #[test]
    fn decode_savings_well_formed() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let cmp = decode_strategy_savings(&model, &system, &c, 2.0, 16, 512);
        assert!(cmp.baseline_s > 0.0);
        // DOP can never lose to the decode baseline: communication is
        // unchanged, compute only rebalances, movement hides.
        assert!(cmp.dop_saving_s >= -1e-12, "dop_saving={}", cmp.dop_saving_s);
        assert!(accuracy_grid().contains(&cmp.tep_best_accuracy));
        assert_eq!(
            ServePhase::by_name("decode").unwrap(),
            ServePhase::Decode
        );
        assert!(ServePhase::by_name("nope").is_err());
    }

    #[test]
    fn decode_penalises_tep_relative_to_prefill() {
        // The decode regime's headline: per-step prediction overhead plus
        // a memory-bound FFN (no compute leverage for exact routing) means
        // TEP's relative saving shrinks vs its prefill showing — even on
        // the slow interconnect where prefill-TEP is strongest (§4).
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_pcie();
        let c = cals(&model, &system);
        let skew = 3.0;
        let prefill = strategy_savings(&model, &system, &c, skew, 1, 512);
        let decode = decode_strategy_savings(&model, &system, &c, skew, 16, 512);
        let rel_prefill = prefill.tep_best_saving_s / prefill.baseline_s;
        let rel_decode = decode.tep_best_saving_s / decode.baseline_s;
        assert!(
            rel_decode < rel_prefill,
            "TEP should lose ground in decode: prefill={rel_prefill} decode={rel_decode}"
        );
        // And the phase dispatcher routes to the same numbers.
        let via_phase = strategy_savings_for_phase(
            ServePhase::Decode,
            &model,
            &system,
            &c,
            skew,
            16,
            512,
        );
        assert_eq!(via_phase.baseline_s, decode.baseline_s);
    }
}
