//! Strategy selection (the decision MoE-GPS exists to make) and the
//! Figure-7 savings-difference series.

use super::calibrate::{interpolate_for_skew, WorkloadCalibration};
use super::sweep::accuracy_grid;
use crate::model::ModelConfig;
use crate::sim::hardware::SystemSpec;
use crate::sim::moe::Strategy;
use crate::sim::LayerSim;

/// Best Token-to-Expert configuration at a skewness: the bottom of the
/// U-shape over the accuracy grid. Returns (accuracy, total_s).
pub fn best_tep(
    sim: &LayerSim,
    skew: f64,
    overhead_fit: (f64, f64),
    baseline_s: f64,
) -> (f64, f64) {
    accuracy_grid()
        .into_iter()
        .map(|acc| {
            let overhead_s = overhead_fit.0 * (overhead_fit.1 * acc).exp() * baseline_s;
            let total = sim
                .breakdown(
                    skew,
                    Strategy::TokenToExpert {
                        accuracy: acc,
                        overhead_s,
                    },
                )
                .total();
            (acc, total)
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

/// Figure-7 row: savings of each strategy vs baseline, and their difference
/// (positive ⇒ Distribution-Only wins).
#[derive(Clone, Debug)]
pub struct SavingsComparison {
    pub skewness: f64,
    pub interconnect_gbs: f64,
    pub baseline_s: f64,
    pub dop_saving_s: f64,
    pub tep_best_saving_s: f64,
    pub tep_best_accuracy: f64,
    /// `dop_saving − tep_saving` (the paper's Figure 7 bar height).
    pub difference_s: f64,
}

/// Compute the savings comparison for one (system, skew).
pub fn strategy_savings(
    model: &ModelConfig,
    system: &SystemSpec,
    cals: &[WorkloadCalibration],
    skew: f64,
    batch: usize,
    seq: usize,
) -> SavingsComparison {
    let sim = LayerSim::new(model.clone(), system.clone()).with_workload(batch, seq);
    let baseline_s = sim.baseline_total(skew);
    let (dop_error, overhead_fit) = interpolate_for_skew(cals, skew);
    let dop_s = sim
        .breakdown(skew, Strategy::DistributionOnly { error_rate: dop_error })
        .total();
    let (tep_acc, tep_s) = best_tep(&sim, skew, overhead_fit, baseline_s);
    SavingsComparison {
        skewness: skew,
        interconnect_gbs: system.interconnect.link_bw_gbs,
        baseline_s,
        dop_saving_s: baseline_s - dop_s,
        tep_best_saving_s: baseline_s - tep_s,
        tep_best_accuracy: tep_acc,
        difference_s: (baseline_s - dop_s) - (baseline_s - tep_s),
    }
}

/// Which strategy MoE-GPS recommends for a configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recommendation {
    DistributionOnly,
    TokenToExpert,
    /// Neither beats the baseline (rare; e.g. skew 1 with costly predictor).
    NoPrediction,
}

impl Recommendation {
    pub fn name(self) -> &'static str {
        match self {
            Recommendation::DistributionOnly => "distribution-only",
            Recommendation::TokenToExpert => "token-to-expert",
            Recommendation::NoPrediction => "no-prediction",
        }
    }
}

/// The selection rule: the strategy with the largest positive saving.
pub fn recommend(cmp: &SavingsComparison) -> Recommendation {
    let eps = 1e-12;
    if cmp.dop_saving_s <= eps && cmp.tep_best_saving_s <= eps {
        Recommendation::NoPrediction
    } else if cmp.dop_saving_s >= cmp.tep_best_saving_s {
        Recommendation::DistributionOnly
    } else {
        Recommendation::TokenToExpert
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::calibrate::{calibrate, CalibrationOptions};
    use crate::trace::datasets;

    fn cals(model: &ModelConfig, system: &SystemSpec) -> Vec<WorkloadCalibration> {
        let opts = CalibrationOptions {
            fast: true,
            ..Default::default()
        };
        vec![
            calibrate(datasets::mmlu_like(81), model, system, &opts),
            calibrate(datasets::sst2_like(82), model, system, &opts),
        ]
    }

    #[test]
    fn dop_recommended_on_nvlink_low_skew() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let c = cals(&model, &system);
        let cmp = strategy_savings(&model, &system, &c, 1.4, 1, 512);
        assert!(cmp.dop_saving_s > 0.0);
        assert_eq!(recommend(&cmp), Recommendation::DistributionOnly);
        assert!(cmp.difference_s > 0.0, "Figure 7 bar must be positive");
    }

    #[test]
    fn tep_gains_ground_on_slow_interconnect() {
        // Paper §4 takeaway: TEP becomes more effective when communication
        // is expensive. Its *relative* position vs DOP must improve when
        // moving from NVLink to PCIe (at high skew where accuracy is cheap).
        let model = ModelConfig::mixtral_8x7b();
        let nv = SystemSpec::four_a100_nvlink();
        let pcie = SystemSpec::four_a100_pcie();
        let c_nv = cals(&model, &nv);
        let c_pcie = cals(&model, &pcie);
        let skew = 4.0;
        let on_nv = strategy_savings(&model, &nv, &c_nv, skew, 1, 512);
        let on_pcie = strategy_savings(&model, &pcie, &c_pcie, skew, 1, 512);
        // Normalised difference (relative to baseline) must shrink or flip.
        let rel_nv = on_nv.difference_s / on_nv.baseline_s;
        let rel_pcie = on_pcie.difference_s / on_pcie.baseline_s;
        assert!(
            rel_pcie < rel_nv,
            "TEP should gain on PCIe: nv={rel_nv} pcie={rel_pcie}"
        );
    }

    #[test]
    fn best_tep_is_on_grid_and_finite() {
        let model = ModelConfig::mixtral_8x7b();
        let system = SystemSpec::four_a100_nvlink();
        let sim = LayerSim::new(model.clone(), system.clone());
        let baseline = sim.baseline_total(2.0);
        let (acc, total) = best_tep(&sim, 2.0, (0.01, 3.0), baseline);
        assert!(accuracy_grid().contains(&acc));
        assert!(total.is_finite() && total > 0.0);
    }
}
