//! The MoE-GPS framework proper (paper §1, §4): given a model architecture,
//! a hardware system and a workload, quantify the end-to-end runtime of each
//! prediction strategy and select the best one.
//!
//! * [`calibrate`] — run the full predictor pipeline on a dataset-like
//!   trace: train every Token-to-Expert predictor, measure accuracy, price
//!   overhead on the simulated hardware, fit the paper's exponential
//!   accuracy→overhead curve, and measure the Distribution-Only error rate
//!   (Figure 4 / Table 1 machinery).
//! * [`sweep`] — Figure 6/8/9 grids: per (skewness, strategy, accuracy)
//!   latency breakdowns.
//! * [`select`] — best-configuration selection and the Figure 7
//!   savings-difference series.
//! * [`guidelines`] — the Figure 1 decision output.
//! * [`online`] — the closed loop (ADR 005): rolling-window calibration of
//!   measured serving metrics into fitted cost-model constants, priced
//!   through the same [`select`] entry points the static map uses
//!   (`serve --adaptive`, `advise --from-serve`).
//! * [`report`] — table/CSV emitters shared by the benches and the CLI.

pub mod calibrate;
pub mod guidelines;
pub mod online;
pub mod report;
pub mod select;
pub mod sweep;

pub use calibrate::{calibrate, CalibrationOptions, PredictorPoint, WorkloadCalibration};
pub use online::{
    calibration_check, parse_serve_report, CalibrationCheck, MeasuredConstants,
    OnlineCalibrator, WindowSample,
};
pub use select::{
    best_tep, decode_strategy_savings, decode_strategy_savings_in, strategy_savings,
    strategy_savings_for_phase, strategy_savings_in, Regime, SavingsComparison,
    ServePhase,
};
pub use sweep::{skew_sweep, SweepPoint};
