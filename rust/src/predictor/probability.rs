//! Probability-based model (Appendix B): assign every token to the expert
//! most frequently activated in the training data — a static rule that
//! ignores token identity. Its accuracy equals the global frequency of the
//! most popular expert, so it *improves with skewness* (paper §4: higher
//! skew makes accurate prediction cheaper).

use super::TokenPredictor;
use crate::trace::{Batch, Trace};

#[derive(Clone, Debug, Default)]
pub struct ProbabilityModel {
    /// argmax_i p̂_i after fitting.
    best_expert: u8,
    /// Fitted global distribution (kept for inspection).
    pub probs: Vec<f64>,
}

impl ProbabilityModel {
    pub fn new() -> ProbabilityModel {
        ProbabilityModel::default()
    }
}

impl TokenPredictor for ProbabilityModel {
    fn name(&self) -> String {
        "probability".into()
    }

    fn fit(&mut self, train: &Trace) {
        let counts = train.expert_counts();
        let total: usize = counts.iter().sum();
        self.probs = counts
            .iter()
            .map(|&c| if total == 0 { 0.0 } else { c as f64 / total as f64 })
            .collect();
        self.best_expert = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .map(|(i, _)| i as u8)
            .unwrap_or(0);
    }

    fn predict_batch(&self, batch: &Batch) -> Vec<Vec<u8>> {
        batch
            .sequences
            .iter()
            .map(|seq| vec![self.best_expert; seq.len()])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::accuracy::accuracy;
    use crate::trace::{datasets, Trace};

    #[test]
    fn predicts_global_argmax() {
        let trace = Trace::generate(datasets::sst2_like(3));
        let counts = trace.expert_counts();
        let argmax = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        let mut m = ProbabilityModel::new();
        m.fit(&trace);
        let preds = m.predict_batch(&trace.batches[0]);
        assert!(preds
            .iter()
            .flat_map(|s| s.iter())
            .all(|&e| e as usize == argmax));
    }

    #[test]
    fn accuracy_close_to_top_expert_frequency() {
        let trace = Trace::generate(datasets::sst2_like(9));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let acc = accuracy(&m, &test);
        let counts = test.expert_counts();
        let total: usize = counts.iter().sum();
        let top_freq = *counts.iter().max().unwrap() as f64 / total as f64;
        assert!((acc - top_freq).abs() < 0.05, "acc={acc} top={top_freq}");
    }

    #[test]
    fn higher_skew_higher_accuracy() {
        let mk = |spec| {
            let t = Trace::generate(spec);
            let (train, test) = t.split(0.8);
            let mut m = ProbabilityModel::new();
            m.fit(&train);
            accuracy(&m, &test)
        };
        let low = mk(datasets::mmlu_like(4)); // skew ~1.39
        let high = mk(datasets::sst2_like(4)); // skew ~1.99
        assert!(high > low, "high={high} low={low}");
    }
}
