//! Probability-based model (Appendix B): assign every token to the expert
//! most frequently activated in the training data — a static rule that
//! ignores token identity. Its accuracy equals the global frequency of the
//! most popular expert, so it *improves with skewness* (paper §4: higher
//! skew makes accurate prediction cheaper). Under the unified trait it
//! also keeps learning online: every `observe` folds the routed counts
//! into the global frequency table.

use super::{rank_topk_f64, Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};

#[derive(Clone, Debug, Default)]
pub struct ProbabilityModel {
    /// Global per-expert counts (train + observed).
    counts: Vec<u64>,
    /// Fitted global distribution (kept for inspection).
    pub probs: Vec<f64>,
}

impl ProbabilityModel {
    pub fn new() -> ProbabilityModel {
        ProbabilityModel::default()
    }

    /// argmax of the fitted distribution (lowest index on ties).
    pub fn best_expert(&self) -> u8 {
        let mut order = Vec::with_capacity(self.probs.len());
        rank_topk_f64(&self.probs, 1, &mut order)
            .first()
            .map(|&i| i as u8)
            .unwrap_or(0)
    }

    fn refresh_probs(&mut self) {
        let total: u64 = self.counts.iter().sum();
        self.probs = self
            .counts
            .iter()
            .map(|&c| if total == 0 { 0.0 } else { c as f64 / total as f64 })
            .collect();
    }
}

impl Predictor for ProbabilityModel {
    fn name(&self) -> String {
        "probability".into()
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::TokenToExpert
    }

    fn fit(&mut self, train: &Trace) {
        self.counts = train
            .expert_counts()
            .into_iter()
            .map(|c| c as u64)
            .collect();
        self.refresh_probs();
    }

    fn predict_distribution(&self) -> Vec<f64> {
        if self.counts.iter().sum::<u64>() == 0 {
            let e = self.counts.len().max(1);
            return vec![1.0 / e as f64; e];
        }
        self.probs.clone()
    }

    fn predict_topk(&self, batch: &Batch, k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        // Token-independent: the ranked global distribution broadcast to
        // every token.
        let mut order = Vec::with_capacity(self.probs.len());
        let ranked: Vec<u8> = rank_topk_f64(&self.probs, k, &mut order)
            .iter()
            .map(|&e| e as u8)
            .collect();
        Some(
            batch
                .sequences
                .iter()
                .map(|seq| vec![ranked.clone(); seq.len()])
                .collect(),
        )
    }

    fn observe(&mut self, routed_counts: &[usize]) {
        if self.counts.len() < routed_counts.len() {
            self.counts.resize(routed_counts.len(), 0);
        }
        for (c, &b) in self.counts.iter_mut().zip(routed_counts) {
            *c += b as u64;
        }
        self.refresh_probs();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::accuracy::{accuracy, top1_predictions};
    use crate::trace::{datasets, Trace};

    #[test]
    fn predicts_global_argmax() {
        let trace = Trace::generate(datasets::sst2_like(3));
        let counts = trace.expert_counts();
        let argmax = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        let mut m = ProbabilityModel::new();
        m.fit(&trace);
        let preds = top1_predictions(&m, &trace.batches[0]);
        assert!(preds
            .iter()
            .flat_map(|s| s.iter())
            .all(|&e| e as usize == argmax));
        assert_eq!(m.best_expert() as usize, argmax);
    }

    #[test]
    fn accuracy_close_to_top_expert_frequency() {
        let trace = Trace::generate(datasets::sst2_like(9));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let acc = accuracy(&m, &test);
        let counts = test.expert_counts();
        let total: usize = counts.iter().sum();
        let top_freq = *counts.iter().max().unwrap() as f64 / total as f64;
        assert!((acc - top_freq).abs() < 0.05, "acc={acc} top={top_freq}");
    }

    #[test]
    fn higher_skew_higher_accuracy() {
        let mk = |spec| {
            let t = Trace::generate(spec);
            let (train, test) = t.split(0.8);
            let mut m = ProbabilityModel::new();
            m.fit(&train);
            accuracy(&m, &test)
        };
        let low = mk(datasets::mmlu_like(4)); // skew ~1.39
        let high = mk(datasets::sst2_like(4)); // skew ~1.99
        assert!(high > low, "high={high} low={low}");
    }

    #[test]
    fn observe_shifts_the_argmax_online() {
        let mut m = ProbabilityModel::new();
        m.observe(&[10, 1, 1, 1]);
        assert_eq!(m.best_expert(), 0);
        // A sustained shift in routed traffic moves the prediction.
        for _ in 0..5 {
            m.observe(&[0, 50, 0, 0]);
        }
        assert_eq!(m.best_expert(), 1);
        let d = m.predict_distribution();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn topk_ranks_by_frequency() {
        let mut m = ProbabilityModel::new();
        m.observe(&[5, 30, 1, 20]);
        let trace = Trace::generate(datasets::mmlu_like(6));
        let sets = m.predict_topk(&trace.batches[0], 3).unwrap();
        assert_eq!(sets[0][0], vec![1, 3, 0]);
    }
}
