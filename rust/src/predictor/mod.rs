//! Prediction strategies for dynamic expert duplication (paper §3.2).
//!
//! Two families:
//!
//! * **Distribution-Only** ([`distribution`]) — a multinomial MLE over
//!   observed routing history (Appendix A): predicts per-expert token
//!   *shares*, maintained as a moving average offline, zero request-path
//!   overhead.
//! * **Token-to-Expert** — per-token expert classification (Appendix B):
//!   [`probability`] (global argmax), [`conditional`] (token- or
//!   position-conditioned argmax), [`markov`] (bigram/context model — our
//!   stand-in for the sequence context the paper's LSTM exploits, see
//!   DESIGN.md §3), and [`neural`] (an MLP with learned token embeddings,
//!   trained in rust with Adam; the AOT/PJRT-served variant lives in
//!   `runtime`/`coordinator`).
//!
//! [`overhead`] prices each predictor's request-path runtime on the
//! simulated hardware, and [`accuracy`] is the shared evaluation harness.

pub mod accuracy;
pub mod conditional;
pub mod distribution;
pub mod markov;
pub mod neural;
pub mod overhead;
pub mod probability;

use crate::trace::{Batch, Trace};

/// A token-to-expert predictor: fits on a training trace, then predicts the
/// expert for every token of a batch *before routing runs* (it sees only
/// token ids/positions, never the routing labels of the batch it predicts).
pub trait TokenPredictor {
    fn name(&self) -> String;
    fn fit(&mut self, train: &Trace);
    /// Predict experts for every sequence in the batch.
    fn predict_batch(&self, batch: &Batch) -> Vec<Vec<u8>>;
}

/// Fit + evaluate helper: returns accuracy on the test trace.
pub fn fit_and_evaluate(
    predictor: &mut dyn TokenPredictor,
    train: &Trace,
    test: &Trace,
) -> f64 {
    predictor.fit(train);
    accuracy::accuracy(predictor, test)
}
