//! Prediction strategies for dynamic expert duplication (paper §3.2).
//!
//! Two families, one interface: every predictor — Distribution-Only or
//! Token-to-Expert — implements the object-safe [`Predictor`] trait
//! (ADR 005), so the calibration pipeline, the evaluation harness and the
//! serving-side strategy controller all speak to one surface:
//!
//! * **Distribution-Only** ([`distribution`]) — a multinomial MLE over
//!   observed routing history (Appendix A): predicts per-expert token
//!   *shares*, maintained online via [`Predictor::observe`], zero
//!   request-path overhead. `predict_topk` is `None`: the family has no
//!   per-token opinion (the evaluation harness broadcasts its ranked
//!   share distribution instead, so both families score through one API).
//!   [`forecast`] is its trajectory-aware sibling (ADR 006): per-expert
//!   EWMA level + trend fit from the same `observe()` stream, answering
//!   [`Predictor::predict_horizon`] with a real `h`-step-ahead
//!   distribution instead of the default stationarity assumption.
//! * **Token-to-Expert** — per-token expert classification (Appendix B):
//!   [`probability`] (global argmax), [`conditional`] (token- or
//!   position-conditioned argmax), [`markov`] (bigram/context model — our
//!   stand-in for the sequence context the paper's LSTM exploits, see
//!   DESIGN.md §3), and [`neural`] (an MLP with learned token embeddings,
//!   trained in rust with Adam; the AOT/PJRT-served variant is bridged
//!   onto the serving path by `coordinator::predict`).
//!
//! [`overhead`] prices each predictor's request-path runtime on the
//! simulated hardware, and [`accuracy`] is the shared evaluation harness
//! (top-1, top-k set hit rate, and L1 distribution error — one API for
//! both families).

pub mod accuracy;
pub mod conditional;
pub mod distribution;
pub mod forecast;
pub mod markov;
pub mod neural;
pub mod overhead;
pub mod probability;

use crate::trace::{Batch, Trace};

/// Which of the paper's two prediction families a predictor belongs to
/// (§3.2): the family decides how the planner consumes its output
/// (expected counts from shares vs exact per-token counts + quotas).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorFamily {
    /// Predicts per-expert token *shares* (no per-token opinion).
    DistributionOnly,
    /// Predicts each token's routed expert set before routing runs.
    TokenToExpert,
}

impl PredictorFamily {
    pub fn name(self) -> &'static str {
        match self {
            PredictorFamily::DistributionOnly => "distribution-only",
            PredictorFamily::TokenToExpert => "token-to-expert",
        }
    }
}

/// The unified predictor interface (ADR 005). Object-safe: the
/// calibration zoo, the evaluation harness and the online controller hold
/// `Box<dyn Predictor>` / `&dyn Predictor` without caring which family or
/// implementation is behind it.
///
/// The serving loop's contract: `fit` runs offline on a training trace;
/// `predict_distribution` / `predict_topk` run on the request path
/// *before routing* (they never see the routing labels of the batch they
/// predict); `observe` feeds each layer's *actual* routed counts back
/// after the router-settle stage, so estimates keep improving while
/// serving (the §3.2.1 moving average, generalised to every predictor).
pub trait Predictor {
    fn name(&self) -> String;

    fn family(&self) -> PredictorFamily;

    /// Offline fit on a training trace.
    fn fit(&mut self, train: &Trace);

    /// Estimated per-expert share distribution for upcoming traffic
    /// (sums to 1; uniform when nothing has been observed yet).
    fn predict_distribution(&self) -> Vec<f64>;

    /// Forecast of the share distribution `h` observe-steps ahead
    /// (ADR 006). The default is the stationarity assumption — the
    /// current estimate at every horizon — so every predictor keeps its
    /// exact pre-forecasting behaviour, and **horizon 0 is identical to
    /// [`Predictor::predict_distribution`] for every implementation**
    /// (trajectory-aware predictors like
    /// [`forecast::LoadForecaster`] must preserve that identity too).
    fn predict_horizon(&self, _h: usize) -> Vec<f64> {
        self.predict_distribution()
    }

    /// Ranked top-k expert sets per token of the batch, `[seq][token][rank]`
    /// (rank 0 = argmax). `None` for the Distribution-Only family, which
    /// holds no per-token opinion — callers that need one per token
    /// broadcast the ranked share distribution (see
    /// [`accuracy::broadcast_topk`]).
    fn predict_topk(&self, batch: &Batch, k: usize) -> Option<Vec<Vec<Vec<u8>>>>;

    /// Online update from one batch/layer of observed routed per-expert
    /// counts (fed from the pipeline's router-settle stage).
    fn observe(&mut self, routed_counts: &[usize]);
}

/// Rank the descending top-k of an `n`-element score set into `order`
/// (reused across calls to stay allocation-free). `desc` must be a total
/// order — use `total_cmp` plus an index tie-break so non-finite scores
/// can never panic and the selected set is deterministic. Partial
/// selection + sorting only the k winners keeps this O(n) per call
/// instead of a full O(n log n) sort — the shared kernel behind every
/// top-k in the predictor zoo *and* the serving pipeline's AOT
/// predictor head.
pub fn rank_topk_by(
    n: usize,
    k: usize,
    order: &mut Vec<usize>,
    desc: impl Fn(&usize, &usize) -> std::cmp::Ordering,
) {
    order.clear();
    order.extend(0..n);
    if n == 0 {
        return;
    }
    let k = k.clamp(1, n);
    if k < n {
        order.select_nth_unstable_by(k - 1, &desc);
    }
    order[..k].sort_unstable_by(&desc);
    order.truncate(k);
}

/// [`rank_topk_by`] over an `f32` score row (predictor logits).
pub fn rank_topk_f32<'a>(row: &[f32], k: usize, order: &'a mut Vec<usize>) -> &'a [usize] {
    rank_topk_by(row.len(), k, order, |a, b| {
        row[*b].total_cmp(&row[*a]).then(a.cmp(b))
    });
    order
}

/// [`rank_topk_by`] over an `f64` score row (share distributions).
pub fn rank_topk_f64<'a>(row: &[f64], k: usize, order: &'a mut Vec<usize>) -> &'a [usize] {
    rank_topk_by(row.len(), k, order, |a, b| {
        row[*b].total_cmp(&row[*a]).then(a.cmp(b))
    });
    order
}

/// [`rank_topk_by`] over a `u32` count row (frequency tables).
pub fn rank_topk_u32<'a>(row: &[u32], k: usize, order: &'a mut Vec<usize>) -> &'a [usize] {
    rank_topk_by(row.len(), k, order, |a, b| {
        row[*b].cmp(&row[*a]).then(a.cmp(b))
    });
    order
}

/// Fit + evaluate helper: returns top-1 accuracy on the test trace (the
/// Figure-4 axis). For the full top-k / distribution-error report use
/// [`fit_and_evaluate_k`].
pub fn fit_and_evaluate(predictor: &mut dyn Predictor, train: &Trace, test: &Trace) -> f64 {
    predictor.fit(train);
    accuracy::accuracy(predictor, test)
}

/// Fit + the generalized evaluation (top-1, top-k set hit rate, L1 share
/// error) — one call evaluating DOP and TEP predictors through one API.
pub fn fit_and_evaluate_k(
    predictor: &mut dyn Predictor,
    train: &Trace,
    test: &Trace,
    k: usize,
) -> accuracy::Evaluation {
    predictor.fit(train);
    accuracy::evaluate(predictor, test, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_topk_orders_and_truncates() {
        let row = [0.1f32, 5.0, -2.0, 5.0, 3.0];
        let mut order = Vec::new();
        assert_eq!(rank_topk_f32(&row, 3, &mut order), &[1, 3, 4]);
        assert_eq!(rank_topk_f32(&row, 1, &mut order), &[1]);
        // k larger than n clamps; k == 0 clamps to 1.
        assert_eq!(rank_topk_f32(&row, 99, &mut order), &[1, 3, 4, 0, 2]);
        assert_eq!(rank_topk_f32(&row, 0, &mut order), &[1]);
    }

    #[test]
    fn rank_topk_total_order_survives_nan() {
        let row = [f32::NAN, 1.0, 2.0];
        let mut order = Vec::new();
        // NaN sorts below real scores under total_cmp's descending order.
        assert_eq!(rank_topk_f32(&row, 2, &mut order), &[2, 1]);
    }

    #[test]
    fn rank_topk_u32_ties_break_by_index() {
        let row = [7u32, 9, 9, 1];
        let mut order = Vec::new();
        assert_eq!(rank_topk_u32(&row, 3, &mut order), &[1, 2, 0]);
    }

    #[test]
    fn rank_topk_empty_row_is_empty() {
        let row: [f64; 0] = [];
        let mut order = vec![123];
        assert!(rank_topk_f64(&row, 2, &mut order).is_empty());
    }
}
