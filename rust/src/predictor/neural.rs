//! Neural token-to-expert predictor (Appendix B "Neural Networks"),
//! implemented and trained natively in rust (no torch offline; the
//! AOT-compiled JAX predictor that the serving path executes through PJRT
//! is produced by `python/compile/` — this in-crate trainer powers the
//! Figure-4 sweeps, which need many train/eval cycles inside benches).
//!
//! Architecture (mirrors the paper's FFN predictor, scaled to our traces):
//! learned token embeddings for the current and previous token
//! (concatenated — giving the MLP a slice of the context an LSTM would
//! see), one ReLU hidden layer, and an expert-logit head; trained with
//! Adam on cross-entropy, exactly as Appendix B prescribes.

use super::{rank_topk_f32, Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};
use crate::util::rng::Rng;

/// Hyper-parameters.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub d_emb: usize,
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f64,
    pub seed: u64,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig {
            d_emb: 16,
            hidden: 64,
            epochs: 3,
            lr: 1e-3,
            seed: 1234,
        }
    }
}

/// Flat-parameter MLP with Adam state.
#[derive(Clone, Debug)]
pub struct MlpPredictor {
    pub config: MlpConfig,
    n_experts: usize,
    vocab: usize,
    // Parameters.
    emb: Vec<f32>, // vocab × d_emb
    w1: Vec<f32>,  // (2·d_emb) × hidden
    b1: Vec<f32>,  // hidden
    w2: Vec<f32>,  // hidden × n_experts
    b2: Vec<f32>,  // n_experts
    // Adam first/second moments, same layout as the parameters.
    m: Vec<f32>,
    v: Vec<f32>,
    adam_t: u64,
    fitted: bool,
    /// Per-expert label counts (train + observed) backing the trait's
    /// share-distribution view of this classifier.
    label_counts: Vec<u64>,
}

impl MlpPredictor {
    pub fn new(config: MlpConfig) -> MlpPredictor {
        MlpPredictor {
            config,
            n_experts: 0,
            vocab: 0,
            emb: Vec::new(),
            w1: Vec::new(),
            b1: Vec::new(),
            w2: Vec::new(),
            b2: Vec::new(),
            m: Vec::new(),
            v: Vec::new(),
            adam_t: 0,
            fitted: false,
            label_counts: Vec::new(),
        }
    }

    /// Total parameter count (used by the overhead model).
    pub fn n_params(&self) -> usize {
        self.emb.len() + self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
    }

    fn init(&mut self, vocab: usize, n_experts: usize) {
        let mut rng = Rng::new(self.config.seed);
        self.vocab = vocab;
        self.n_experts = n_experts;
        let d = self.config.d_emb;
        let h = self.config.hidden;
        let input = 2 * d;
        let normal = |rng: &mut Rng, scale: f64, n: usize| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        self.emb = normal(&mut rng, 0.1, vocab * d);
        self.w1 = normal(&mut rng, (2.0 / input as f64).sqrt(), input * h);
        self.b1 = vec![0.0; h];
        self.w2 = normal(&mut rng, (2.0 / h as f64).sqrt(), h * n_experts);
        self.b2 = vec![0.0; n_experts];
        let total = self.n_params();
        self.m = vec![0.0; total];
        self.v = vec![0.0; total];
        self.adam_t = 0;
    }

    /// Parameter-index offsets into the flat Adam state.
    fn offsets(&self) -> (usize, usize, usize, usize) {
        let o_w1 = self.emb.len();
        let o_b1 = o_w1 + self.w1.len();
        let o_w2 = o_b1 + self.b1.len();
        let o_b2 = o_w2 + self.w2.len();
        (o_w1, o_b1, o_w2, o_b2)
    }

    /// Forward pass writing hidden activations into `hid`, logits into
    /// `logits`. Inputs: embeddings of (prev, cur).
    fn forward(&self, prev_id: u32, cur_id: u32, hid: &mut [f32], logits: &mut [f32]) {
        let d = self.config.d_emb;
        let h = self.config.hidden;
        let e_prev = &self.emb[prev_id as usize * d..(prev_id as usize + 1) * d];
        let e_cur = &self.emb[cur_id as usize * d..(cur_id as usize + 1) * d];
        for j in 0..h {
            let mut acc = self.b1[j];
            // w1 layout: [input][hidden]
            for (i, &x) in e_prev.iter().enumerate() {
                acc += x * self.w1[i * h + j];
            }
            for (i, &x) in e_cur.iter().enumerate() {
                acc += x * self.w1[(d + i) * h + j];
            }
            hid[j] = acc.max(0.0);
        }
        for k in 0..self.n_experts {
            let mut acc = self.b2[k];
            for (j, &hj) in hid.iter().enumerate() {
                acc += hj * self.w2[j * self.n_experts + k];
            }
            logits[k] = acc;
        }
    }

    /// One Adam update for a single scalar parameter.
    #[inline]
    fn adam_step(
        param: &mut f32,
        m: &mut f32,
        v: &mut f32,
        grad: f32,
        lr: f64,
        bias1: f64,
        bias2: f64,
    ) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        *m = B1 * *m + (1.0 - B1) * grad;
        *v = B2 * *v + (1.0 - B2) * grad * grad;
        let m_hat = *m as f64 / bias1;
        let v_hat = *v as f64 / bias2;
        *param -= (lr * m_hat / (v_hat.sqrt() + EPS as f64)) as f32;
    }

    /// Train on one (prev, cur, label) example; returns the CE loss.
    fn train_example(&mut self, prev_id: u32, cur_id: u32, label: u8) -> f32 {
        let d = self.config.d_emb;
        let h = self.config.hidden;
        let e = self.n_experts;
        let mut hid = vec![0.0f32; h];
        let mut logits = vec![0.0f32; e];
        self.forward(prev_id, cur_id, &mut hid, &mut logits);

        // Softmax + CE gradient: dlogits = softmax - onehot.
        let max = logits.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let mut dlogits: Vec<f32> = exps.iter().map(|&x| x / sum).collect();
        let loss = -dlogits[label as usize].max(1e-12).ln();
        dlogits[label as usize] -= 1.0;

        self.adam_t += 1;
        let lr = self.config.lr;
        let bias1 = 1.0 - 0.9f64.powi(self.adam_t.min(1_000_000) as i32);
        let bias2 = 1.0 - 0.999f64.powi(self.adam_t.min(1_000_000) as i32);
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();

        // Grad wrt hidden, then backprop through ReLU.
        let mut dhid = vec![0.0f32; h];
        for j in 0..h {
            let mut acc = 0.0;
            for k in 0..e {
                acc += dlogits[k] * self.w2[j * e + k];
            }
            dhid[j] = if hid[j] > 0.0 { acc } else { 0.0 };
        }

        // Update w2 / b2.
        for j in 0..h {
            for k in 0..e {
                let g = dlogits[k] * hid[j];
                let idx = o_w2 + j * e + k;
                Self::adam_step(
                    &mut self.w2[j * e + k],
                    &mut self.m[idx],
                    &mut self.v[idx],
                    g,
                    lr,
                    bias1,
                    bias2,
                );
            }
        }
        for k in 0..e {
            let idx = o_b2 + k;
            Self::adam_step(
                &mut self.b2[k],
                &mut self.m[idx],
                &mut self.v[idx],
                dlogits[k],
                lr,
                bias1,
                bias2,
            );
        }

        // Grad wrt input embeddings via w1, and w1/b1 updates.
        let prev_base = prev_id as usize * d;
        let cur_base = cur_id as usize * d;
        // Cache the input vector before updating emb.
        let x_prev: Vec<f32> = self.emb[prev_base..prev_base + d].to_vec();
        let x_cur: Vec<f32> = self.emb[cur_base..cur_base + d].to_vec();

        let mut dx = vec![0.0f32; 2 * d];
        for j in 0..h {
            let g = dhid[j];
            if g == 0.0 {
                continue;
            }
            for i in 0..d {
                dx[i] += g * self.w1[i * h + j];
                dx[d + i] += g * self.w1[(d + i) * h + j];
            }
        }
        for j in 0..h {
            let g = dhid[j];
            if g != 0.0 {
                for i in 0..d {
                    let idx1 = i * h + j;
                    let gw = g * x_prev[i];
                    let flat = o_w1 + idx1;
                    Self::adam_step(
                        &mut self.w1[idx1],
                        &mut self.m[flat],
                        &mut self.v[flat],
                        gw,
                        lr,
                        bias1,
                        bias2,
                    );
                    let idx2 = (d + i) * h + j;
                    let gw2 = g * x_cur[i];
                    let flat2 = o_w1 + idx2;
                    Self::adam_step(
                        &mut self.w1[idx2],
                        &mut self.m[flat2],
                        &mut self.v[flat2],
                        gw2,
                        lr,
                        bias1,
                        bias2,
                    );
                }
            }
            let idx = o_b1 + j;
            Self::adam_step(
                &mut self.b1[j],
                &mut self.m[idx],
                &mut self.v[idx],
                g,
                lr,
                bias1,
                bias2,
            );
        }

        // Embedding rows (lazy Adam: only touched rows).
        for i in 0..d {
            let idx = prev_base + i;
            Self::adam_step(
                &mut self.emb[idx],
                &mut self.m[idx],
                &mut self.v[idx],
                dx[i],
                lr,
                bias1,
                bias2,
            );
            let idx = cur_base + i;
            Self::adam_step(
                &mut self.emb[idx],
                &mut self.m[idx],
                &mut self.v[idx],
                dx[d + i],
                lr,
                bias1,
                bias2,
            );
        }

        loss
    }
}

impl Predictor for MlpPredictor {
    fn name(&self) -> String {
        format!("mlp-h{}", self.config.hidden)
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::TokenToExpert
    }

    fn fit(&mut self, train: &Trace) {
        self.init(train.spec.vocab_size, train.spec.n_experts);
        self.label_counts = vec![0; train.spec.n_experts];
        // Flatten (prev, cur, label) triples; prev of the first token is
        // the token itself (a BOS-like convention).
        let mut examples: Vec<(u32, u32, u8)> = Vec::with_capacity(train.n_tokens());
        for batch in &train.batches {
            for seq in &batch.sequences {
                for (pos, tok) in seq.iter().enumerate() {
                    let prev = if pos == 0 { tok.id } else { seq[pos - 1].id };
                    examples.push((prev, tok.id, tok.expert));
                    self.label_counts[tok.expert as usize] += 1;
                }
            }
        }
        let mut rng = Rng::new(self.config.seed ^ 0x5EED);
        for _epoch in 0..self.config.epochs {
            rng.shuffle(&mut examples);
            for &(prev, cur, label) in &examples {
                self.train_example(prev, cur, label);
            }
        }
        self.fitted = true;
    }

    fn predict_distribution(&self) -> Vec<f64> {
        let total: u64 = self.label_counts.iter().sum();
        if total == 0 {
            let e = self.n_experts.max(1);
            return vec![1.0 / e as f64; e];
        }
        self.label_counts
            .iter()
            .map(|&c| c as f64 / total as f64)
            .collect()
    }

    fn predict_topk(&self, batch: &Batch, k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        assert!(self.fitted, "predict before fit");
        let h = self.config.hidden;
        let mut hid = vec![0.0f32; h];
        let mut logits = vec![0.0f32; self.n_experts];
        let mut order = Vec::with_capacity(self.n_experts);
        Some(
            batch
                .sequences
                .iter()
                .map(|seq| {
                    seq.iter()
                        .enumerate()
                        .map(|(pos, tok)| {
                            let prev = if pos == 0 { tok.id } else { seq[pos - 1].id };
                            self.forward(prev, tok.id, &mut hid, &mut logits);
                            rank_topk_f32(&logits, k, &mut order)
                                .iter()
                                .map(|&e| e as u8)
                                .collect()
                        })
                        .collect()
                })
                .collect(),
        )
    }

    fn observe(&mut self, routed_counts: &[usize]) {
        if self.label_counts.len() < routed_counts.len() {
            self.label_counts.resize(routed_counts.len(), 0);
        }
        for (c, &b) in self.label_counts.iter_mut().zip(routed_counts) {
            *c += b as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::accuracy::accuracy;
    use crate::predictor::probability::ProbabilityModel;
    use crate::trace::{datasets, Trace};

    /// Small trace so debug-mode tests stay fast.
    fn small_trace(seed: u64) -> Trace {
        let mut spec = datasets::mmlu_like(seed);
        spec.vocab_size = 128;
        spec.seq_len = 64;
        spec.sequences_per_batch = 4;
        spec.n_batches = 12;
        spec.lambda = 0.7;
        spec.mu = 0.0;
        Trace::generate(spec)
    }

    fn fast_config() -> MlpConfig {
        MlpConfig {
            d_emb: 8,
            hidden: 16,
            epochs: 4,
            lr: 3e-3,
            seed: 7,
        }
    }

    #[test]
    fn mlp_learns_token_affinities() {
        let trace = small_trace(41);
        let (train, test) = trace.split(0.8);
        let mut mlp = MlpPredictor::new(fast_config());
        mlp.fit(&train);
        let acc_mlp = accuracy(&mlp, &test);
        let mut prob = ProbabilityModel::new();
        prob.fit(&train);
        let acc_prob = accuracy(&prob, &test);
        assert!(
            acc_mlp > acc_prob + 0.15,
            "mlp={acc_mlp} prob={acc_prob}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let trace = small_trace(42);
        let (train, test) = trace.split(0.8);
        let mut a = MlpPredictor::new(fast_config());
        a.fit(&train);
        let mut b = MlpPredictor::new(fast_config());
        b.fit(&train);
        assert_eq!(
            a.predict_topk(&test.batches[0], 2),
            b.predict_topk(&test.batches[0], 2)
        );
    }

    #[test]
    fn loss_decreases_during_training() {
        let trace = small_trace(43);
        let mut mlp = MlpPredictor::new(fast_config());
        mlp.init(trace.spec.vocab_size, trace.spec.n_experts);
        let batch = &trace.batches[0];
        let mut first_pass = 0.0;
        let mut last_pass = 0.0;
        for epoch in 0..6 {
            let mut total = 0.0;
            let mut n = 0;
            for seq in &batch.sequences {
                for (pos, tok) in seq.iter().enumerate() {
                    let prev = if pos == 0 { tok.id } else { seq[pos - 1].id };
                    total += mlp.train_example(prev, tok.id, tok.expert);
                    n += 1;
                }
            }
            let avg = total / n as f32;
            if epoch == 0 {
                first_pass = avg;
            }
            last_pass = avg;
        }
        assert!(
            last_pass < first_pass * 0.9,
            "loss {first_pass} -> {last_pass}"
        );
    }

    #[test]
    #[should_panic(expected = "predict before fit")]
    fn predict_requires_fit() {
        let trace = small_trace(44);
        let mlp = MlpPredictor::new(fast_config());
        mlp.predict_topk(&trace.batches[0], 1);
    }
}
