//! Predictor evaluation harness: accuracy, per-expert confusion, and the
//! predicted-vs-actual load comparison the duplication planner consumes.

use super::TokenPredictor;
use crate::trace::Trace;

/// Top-1 prediction accuracy over every token of the test trace.
pub fn accuracy(predictor: &dyn TokenPredictor, test: &Trace) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for batch in &test.batches {
        let preds = predictor.predict_batch(batch);
        for (seq, pred_seq) in batch.sequences.iter().zip(&preds) {
            for (tok, &pred) in seq.iter().zip(pred_seq) {
                total += 1;
                if tok.expert == pred {
                    correct += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

/// Confusion matrix `confusion[actual][predicted]`.
pub fn confusion(predictor: &dyn TokenPredictor, test: &Trace) -> Vec<Vec<usize>> {
    let e = test.spec.n_experts;
    let mut m = vec![vec![0usize; e]; e];
    for batch in &test.batches {
        let preds = predictor.predict_batch(batch);
        for (seq, pred_seq) in batch.sequences.iter().zip(&preds) {
            for (tok, &pred) in seq.iter().zip(pred_seq) {
                m[tok.expert as usize][pred as usize] += 1;
            }
        }
    }
    m
}

/// Predicted per-expert loads for one batch — what the placement manager
/// feeds to Algorithm 1 under Token-to-Expert prediction.
pub fn predicted_loads(
    predictor: &dyn TokenPredictor,
    batch: &crate::trace::Batch,
    n_experts: usize,
) -> Vec<usize> {
    let mut counts = vec![0usize; n_experts];
    for pred_seq in predictor.predict_batch(batch) {
        for &e in &pred_seq {
            counts[e as usize] += 1;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::probability::ProbabilityModel;
    use crate::trace::{datasets, Trace};

    #[test]
    fn accuracy_bounds() {
        let trace = Trace::generate(datasets::mmlu_like(51));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let acc = accuracy(&m, &test);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn confusion_sums_to_token_count() {
        let trace = Trace::generate(datasets::mmlu_like(52));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let c = confusion(&m, &test);
        let sum: usize = c.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(sum, test.n_tokens());
        // Diagonal fraction equals accuracy.
        let diag: usize = (0..8).map(|i| c[i][i]).sum();
        let acc = accuracy(&m, &test);
        assert!((diag as f64 / sum as f64 - acc).abs() < 1e-12);
    }

    #[test]
    fn predicted_loads_conserve_tokens() {
        let trace = Trace::generate(datasets::mmlu_like(53));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let loads = predicted_loads(&m, &test.batches[0], 8);
        assert_eq!(
            loads.iter().sum::<usize>(),
            test.batches[0].n_tokens()
        );
    }
}
