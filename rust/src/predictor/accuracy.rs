//! Predictor evaluation harness — one API for both prediction families
//! (the ADR-005 generalisation): top-1 accuracy, top-k *set* hit rate
//! (a routed slot scores when its expert appears anywhere in the token's
//! predicted set — the same confirmation rule the speculative scatter
//! uses), and L1 distribution error on per-expert shares (the paper's
//! Table-1 metric, now scored for every predictor, not just DOP).
//!
//! Distribution-Only predictors hold no per-token opinion
//! (`predict_topk` is `None`); the harness broadcasts their ranked share
//! distribution to every token, so a DOP estimator and a TEP classifier
//! are comparable through the same calls.

use super::{rank_topk_f64, Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};
use crate::util::stats;

/// The generalized evaluation result.
#[derive(Clone, Copy, Debug)]
pub struct Evaluation {
    /// Fraction of tokens whose argmax prediction matched the routed
    /// expert (the classic Figure-4 axis).
    pub top1: f64,
    /// Fraction of tokens whose routed expert appeared anywhere in the
    /// predicted top-k set.
    pub topk: f64,
    /// L1 distance between the predictor's share distribution and the
    /// test trace's empirical shares (Table 1's error rate).
    pub dist_l1: f64,
    /// The k the set metric was scored at.
    pub k: usize,
}

/// Ranked top-k sets for one batch, falling back to broadcasting the
/// predictor's share distribution when the family has no per-token
/// opinion — the bridge that lets DOP predictors flow through the
/// per-token scoring path.
pub fn broadcast_topk(p: &dyn Predictor, batch: &Batch, k: usize) -> Vec<Vec<Vec<u8>>> {
    if let Some(sets) = p.predict_topk(batch, k) {
        // The declared family and the per-token behavior are two
        // encodings of one fact; keep them honest about each other.
        debug_assert_eq!(
            p.family(),
            PredictorFamily::TokenToExpert,
            "{} returns per-token sets but declares itself {}",
            p.name(),
            p.family().name()
        );
        return sets;
    }
    debug_assert_eq!(
        p.family(),
        PredictorFamily::DistributionOnly,
        "{} returns no per-token sets but declares itself {}",
        p.name(),
        p.family().name()
    );
    let dist = p.predict_distribution();
    let mut order = Vec::with_capacity(dist.len());
    let ranked: Vec<u8> = rank_topk_f64(&dist, k, &mut order)
        .iter()
        .map(|&e| e as u8)
        .collect();
    batch
        .sequences
        .iter()
        .map(|seq| vec![ranked.clone(); seq.len()])
        .collect()
}

/// Argmax (top-1) predictions for every token of a batch — the historic
/// `predict_batch` shape, preserved for call sites that want one expert
/// per token.
pub fn top1_predictions(p: &dyn Predictor, batch: &Batch) -> Vec<Vec<u8>> {
    broadcast_topk(p, batch, 1)
        .into_iter()
        .map(|seq| {
            seq.into_iter()
                .map(|ranked| ranked.first().copied().unwrap_or(0))
                .collect()
        })
        .collect()
}

/// The generalized evaluation over a test trace.
pub fn evaluate(p: &dyn Predictor, test: &Trace, k: usize) -> Evaluation {
    let e = test.spec.n_experts;
    let mut top1_hits = 0usize;
    let mut topk_hits = 0usize;
    let mut total = 0usize;
    for batch in &test.batches {
        let sets = broadcast_topk(p, batch, k);
        for (seq, pred_seq) in batch.sequences.iter().zip(&sets) {
            for (tok, ranked) in seq.iter().zip(pred_seq) {
                total += 1;
                if ranked.first() == Some(&tok.expert) {
                    top1_hits += 1;
                }
                if ranked.contains(&tok.expert) {
                    topk_hits += 1;
                }
            }
        }
    }
    let counts = test.expert_counts();
    let n_tokens: usize = counts.iter().sum();
    let dist_l1 = if n_tokens == 0 {
        0.0
    } else {
        let empirical: Vec<f64> = counts
            .iter()
            .map(|&c| c as f64 / n_tokens as f64)
            .collect();
        let mut predicted = p.predict_distribution();
        predicted.resize(e, 0.0);
        stats::l1_distance(&predicted, &empirical)
    };
    if total == 0 {
        return Evaluation {
            top1: 0.0,
            topk: 0.0,
            dist_l1,
            k,
        };
    }
    Evaluation {
        top1: top1_hits as f64 / total as f64,
        topk: topk_hits as f64 / total as f64,
        dist_l1,
        k,
    }
}

/// Top-1 prediction accuracy over every token of the test trace.
pub fn accuracy(predictor: &dyn Predictor, test: &Trace) -> f64 {
    evaluate(predictor, test, 1).top1
}

/// Confusion matrix `confusion[actual][predicted]` (argmax predictions).
pub fn confusion(predictor: &dyn Predictor, test: &Trace) -> Vec<Vec<usize>> {
    let e = test.spec.n_experts;
    let mut m = vec![vec![0usize; e]; e];
    for batch in &test.batches {
        let preds = top1_predictions(predictor, batch);
        for (seq, pred_seq) in batch.sequences.iter().zip(&preds) {
            for (tok, &pred) in seq.iter().zip(pred_seq) {
                m[tok.expert as usize][pred as usize] += 1;
            }
        }
    }
    m
}

/// Predicted per-expert loads for one batch — what the placement manager
/// feeds to Algorithm 1 under Token-to-Expert prediction. Counts one
/// predicted slot per rank of each token's top-k set.
pub fn predicted_loads(
    predictor: &dyn Predictor,
    batch: &crate::trace::Batch,
    n_experts: usize,
    k: usize,
) -> Vec<usize> {
    let mut counts = vec![0usize; n_experts];
    for pred_seq in broadcast_topk(predictor, batch, k) {
        for ranked in &pred_seq {
            for &e in ranked {
                counts[e as usize] += 1;
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::distribution::DistributionEstimator;
    use crate::predictor::probability::ProbabilityModel;
    use crate::trace::{datasets, Trace};

    #[test]
    fn accuracy_bounds() {
        let trace = Trace::generate(datasets::mmlu_like(51));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let acc = accuracy(&m, &test);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn confusion_sums_to_token_count() {
        let trace = Trace::generate(datasets::mmlu_like(52));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let c = confusion(&m, &test);
        let sum: usize = c.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(sum, test.n_tokens());
        // Diagonal fraction equals accuracy.
        let diag: usize = (0..8).map(|i| c[i][i]).sum();
        let acc = accuracy(&m, &test);
        assert!((diag as f64 / sum as f64 - acc).abs() < 1e-12);
    }

    #[test]
    fn predicted_loads_conserve_tokens() {
        let trace = Trace::generate(datasets::mmlu_like(53));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let loads = predicted_loads(&m, &test.batches[0], 8, 1);
        assert_eq!(loads.iter().sum::<usize>(), test.batches[0].n_tokens());
        // k slots per token at k = 2.
        let loads2 = predicted_loads(&m, &test.batches[0], 8, 2);
        assert_eq!(
            loads2.iter().sum::<usize>(),
            2 * test.batches[0].n_tokens()
        );
    }

    #[test]
    fn topk_dominates_top1() {
        let trace = Trace::generate(datasets::mmlu_like(54));
        let (train, test) = trace.split(0.8);
        let mut m = ProbabilityModel::new();
        m.fit(&train);
        let e1 = evaluate(&m, &test, 1);
        let e2 = evaluate(&m, &test, 2);
        assert!((e1.top1 - e1.topk).abs() < 1e-12, "k=1: set == argmax");
        assert!(e2.topk >= e1.top1, "a wider set can only hit more");
        assert!((e1.top1 - e2.top1).abs() < 1e-12, "top1 independent of k");
    }

    #[test]
    fn dop_scores_through_the_same_api() {
        // The ADR-005 point: a Distribution-Only estimator flows through
        // the identical evaluate() call as a TEP classifier.
        let trace = Trace::generate(datasets::sst2_like(55));
        let (train, test) = trace.split(0.8);
        let mut dop = DistributionEstimator::new(8);
        dop.fit(&train);
        let ev = evaluate(&dop, &test, 2);
        assert!(ev.top1 > 0.0, "broadcast argmax must hit the hot expert");
        assert!(ev.topk >= ev.top1);
        // Its L1 share error equals the historic Table-1 error rate.
        assert!((ev.dist_l1 - dop.error_rate(&test)).abs() < 1e-12);
    }

    #[test]
    fn dist_l1_small_for_matched_distribution() {
        let trace = Trace::generate(datasets::mmlu_like(56));
        let (train, test) = trace.split(0.8);
        let mut dop = DistributionEstimator::new(8);
        dop.fit(&train);
        let ev = evaluate(&dop, &test, 1);
        assert!(ev.dist_l1 < 0.06, "l1={}", ev.dist_l1);
    }
}
