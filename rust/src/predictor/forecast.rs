//! Load-trajectory forecasting (ADR 006).
//!
//! Every other predictor in the zoo answers "what is the expert
//! distribution *now*"; this module answers "what will it be `h`
//! observation steps from now". "Prediction Is All MoE Needs" (PAPERS.md)
//! observes that per-expert decode load *stabilizes* over a serving
//! window, which makes short-horizon forecasting cheap and accurate
//! exactly when proactive replanning needs it: a placement built for the
//! forecast distribution at the next replan boundary has its replicas
//! prewarmed *before* the spike instead of one replan interval after.
//!
//! The model is Holt's double exponential smoothing, per expert: a level
//! (EWMA of the raw per-expert load) plus a trend (EWMA of the level's
//! step-to-step delta), fit online from the same `observe()` stream of
//! routed counts the DOP estimators and the online calibrator already
//! consume. The `h`-step forecast is `level + h · trend`, clamped at
//! zero and normalized into a share distribution.
//!
//! Contracts the test harness pins (`tests/forecasting.rs`):
//! * horizon 0 is **bitwise identical** to [`Predictor::predict_distribution`]
//!   (it *is* `forecast_distribution(0)` — no separate code path);
//! * a perfectly linear per-expert ramp is a fixed point of the Holt
//!   recurrence after the two-observation initialization, so linear loads
//!   are recovered exactly at any horizon;
//! * constant loads converge to the stationary distribution with zero
//!   trend.

use super::{Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};
use crate::util::stats;

/// Per-expert EWMA level + trend forecaster (Holt's linear method).
#[derive(Clone, Debug)]
pub struct LoadForecaster {
    n_experts: usize,
    /// Level smoothing weight for the newest observation.
    pub alpha: f64,
    /// Trend smoothing weight for the newest level delta.
    pub beta: f64,
    level: Vec<f64>,
    trend: Vec<f64>,
    /// Raw first observation, kept until the second fixes the trend.
    first: Option<Vec<f64>>,
    observed: u64,
}

impl LoadForecaster {
    pub fn new(n_experts: usize) -> LoadForecaster {
        LoadForecaster {
            n_experts,
            alpha: 0.5,
            beta: 0.5,
            level: vec![0.0; n_experts],
            trend: vec![0.0; n_experts],
            first: None,
            observed: 0,
        }
    }

    /// How many observations have been ingested.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Current per-expert level estimate (raw load units).
    pub fn level(&self) -> &[f64] {
        &self.level
    }

    /// Current per-expert trend estimate (load delta per step).
    pub fn trend(&self) -> &[f64] {
        &self.trend
    }

    /// Ingest one step's observed per-expert routed counts.
    ///
    /// Standard Holt initialization: the first observation seeds the
    /// level; the second seeds `level = x₁, trend = x₁ − x₀` — which
    /// makes an exactly linear signal a *fixed point* of the recurrence
    /// (`level_t = x_t`, `trend_t = slope`) from the second observation
    /// on, the exact-recovery property the forecasting tests pin.
    pub fn ingest(&mut self, counts: &[usize]) {
        assert_eq!(counts.len(), self.n_experts);
        let x: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        self.observed += 1;
        match self.observed {
            1 => {
                self.level.copy_from_slice(&x);
                self.first = Some(x);
            }
            2 => {
                let x0 = self.first.take().expect("first observation kept");
                for e in 0..self.n_experts {
                    self.trend[e] = x[e] - x0[e];
                    self.level[e] = x[e];
                }
            }
            _ => {
                for e in 0..self.n_experts {
                    let prev_level = self.level[e];
                    let new_level = self.alpha * x[e]
                        + (1.0 - self.alpha) * (prev_level + self.trend[e]);
                    self.trend[e] = self.beta * (new_level - prev_level)
                        + (1.0 - self.beta) * self.trend[e];
                    self.level[e] = new_level;
                }
            }
        }
    }

    /// Raw per-expert load forecast `h` steps ahead: `level + h · trend`,
    /// clamped at zero (a load can shrink to nothing but not below it).
    pub fn forecast(&self, h: usize) -> Vec<f64> {
        let h = h as f64;
        self.level
            .iter()
            .zip(&self.trend)
            .map(|(&l, &t)| (l + h * t).max(0.0))
            .collect()
    }

    /// Share-distribution forecast `h` steps ahead (sums to 1; uniform
    /// before any observation or when the forecast collapses to zero).
    pub fn forecast_distribution(&self, h: usize) -> Vec<f64> {
        let raw = self.forecast(h);
        let total: f64 = raw.iter().sum();
        if self.observed == 0 || total <= 0.0 || !total.is_finite() {
            return vec![1.0 / self.n_experts as f64; self.n_experts];
        }
        raw.into_iter().map(|v| v / total).collect()
    }

    /// Predicted skewness of the `h`-step-ahead distribution.
    pub fn predicted_skewness(&self, h: usize) -> f64 {
        stats::skewness_of_probs(&self.forecast_distribution(h))
    }
}

/// The forecaster behind the unified trait (ADR 005/006): it is a
/// Distribution-Only family member (no per-token opinion), whose
/// [`Predictor::predict_horizon`] actually uses its trend state —
/// `predict_distribution` is exactly `forecast_distribution(0)`, so
/// horizon 0 degrades to the reactive estimate bitwise.
impl Predictor for LoadForecaster {
    fn name(&self) -> String {
        "load-forecast".into()
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::DistributionOnly
    }

    fn fit(&mut self, train: &Trace) {
        for b in &train.batches {
            self.ingest(&b.expert_counts(self.n_experts));
        }
    }

    fn predict_distribution(&self) -> Vec<f64> {
        self.forecast_distribution(0)
    }

    fn predict_horizon(&self, h: usize) -> Vec<f64> {
        self.forecast_distribution(h)
    }

    fn predict_topk(&self, _batch: &Batch, _k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        None
    }

    fn observe(&mut self, routed_counts: &[usize]) {
        self.ingest(routed_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_forecaster_is_uniform_at_every_horizon() {
        let f = LoadForecaster::new(4);
        for h in [0, 1, 8] {
            assert_eq!(f.forecast_distribution(h), vec![0.25; 4]);
        }
    }

    #[test]
    fn linear_ramp_is_a_fixed_point() {
        let mut f = LoadForecaster::new(2);
        // x_t = [100 + 10t, 300 - 10t]
        for t in 0..12usize {
            f.ingest(&[100 + 10 * t, 300 - 10 * t]);
        }
        let last_t = 11.0;
        assert!((f.level[0] - (100.0 + 10.0 * last_t)).abs() < 1e-9);
        assert!((f.trend[0] - 10.0).abs() < 1e-9);
        assert!((f.trend[1] + 10.0).abs() < 1e-9);
        let fc = f.forecast(4);
        assert!((fc[0] - (100.0 + 10.0 * (last_t + 4.0))).abs() < 1e-9);
        assert!((fc[1] - (300.0 - 10.0 * (last_t + 4.0))).abs() < 1e-9);
    }

    #[test]
    fn constant_load_converges_with_zero_trend() {
        let mut f = LoadForecaster::new(3);
        for _ in 0..40 {
            f.ingest(&[60, 30, 10]);
        }
        for (e, want) in [(0usize, 0.6), (1, 0.3), (2, 0.1)] {
            assert!((f.forecast_distribution(5)[e] - want).abs() < 1e-9);
        }
        for &t in f.trend() {
            assert!(t.abs() < 1e-9, "trend must vanish on constant load");
        }
    }

    #[test]
    fn horizon_zero_is_predict_distribution_bitwise() {
        let mut f = LoadForecaster::new(4);
        for t in 0..7usize {
            f.ingest(&[5 + t, 9, 2 * t, 31]);
        }
        let a = f.predict_distribution();
        let b = f.predict_horizon(0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn forecast_clamps_at_zero_and_renormalizes() {
        let mut f = LoadForecaster::new(2);
        // Expert 1 collapses fast: its linear extrapolation goes negative.
        f.ingest(&[10, 100]);
        f.ingest(&[10, 40]);
        let far = f.forecast(10);
        assert_eq!(far[1], 0.0, "negative extrapolation must clamp");
        let dist = f.forecast_distribution(10);
        assert!((dist.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(dist[0], 1.0);
    }
}
