//! Distribution-Only Prediction (paper §3.2.1, Appendix A).
//!
//! Models per-layer expert activation as a multinomial; the MLE of the
//! activation probabilities is the empirical frequency `p̂_i = n_i / N`
//! (Appendix A, eq. 6). When training data arrives in batches the estimate
//! becomes a moving average. The paper's error-rate metric (Table 1) is
//! `|p̂ − p| / (1/E)` — with `|·|` the mean absolute component difference,
//! this equals the L1 distance between the estimated and the test-set
//! empirical distributions.

use super::{Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};
use crate::util::stats;

/// Multinomial MLE estimator with optional exponential moving average.
#[derive(Clone, Debug)]
pub struct DistributionEstimator {
    n_experts: usize,
    /// Cumulative counts (pure MLE).
    counts: Vec<u64>,
    /// EMA of per-batch distributions; `None` until the first batch.
    ema: Option<Vec<f64>>,
    /// EMA weight for the newest batch (0 = frozen, 1 = last batch only).
    pub ema_weight: f64,
}

impl DistributionEstimator {
    pub fn new(n_experts: usize) -> DistributionEstimator {
        DistributionEstimator {
            n_experts,
            counts: vec![0; n_experts],
            ema: None,
            ema_weight: 0.1,
        }
    }

    /// Ingest one batch of per-expert counts (streaming form).
    pub fn update(&mut self, batch_counts: &[usize]) {
        assert_eq!(batch_counts.len(), self.n_experts);
        for (c, &b) in self.counts.iter_mut().zip(batch_counts) {
            *c += b as u64;
        }
        let total: usize = batch_counts.iter().sum();
        if total > 0 {
            let batch_p: Vec<f64> = batch_counts
                .iter()
                .map(|&c| c as f64 / total as f64)
                .collect();
            self.ema = Some(match self.ema.take() {
                None => batch_p,
                Some(prev) => prev
                    .iter()
                    .zip(&batch_p)
                    .map(|(&a, &b)| (1.0 - self.ema_weight) * a + self.ema_weight * b)
                    .collect(),
            });
        }
    }

    /// The MLE `p̂_i = n_i / N` (Appendix A eq. 6).
    pub fn mle(&self) -> Vec<f64> {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return vec![1.0 / self.n_experts as f64; self.n_experts];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / total as f64)
            .collect()
    }

    /// The EMA estimate (adapts to drift; equals MLE-ish when stationary).
    pub fn ema(&self) -> Vec<f64> {
        self.ema.clone().unwrap_or_else(|| self.mle())
    }

    /// Predicted skewness implied by the estimate.
    pub fn predicted_skewness(&self) -> f64 {
        stats::skewness_of_probs(&self.mle())
    }

    /// The paper's Table-1 error rate against a test trace:
    /// `mean_i |p̂_i − p_i| / (1/E)` = L1(p̂, p_test).
    pub fn error_rate(&self, test: &Trace) -> f64 {
        let counts = test.expert_counts();
        let total: usize = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let p_test: Vec<f64> = counts.iter().map(|&c| c as f64 / total as f64).collect();
        stats::l1_distance(&self.mle(), &p_test)
    }

    /// Per-batch error rate averaged over test batches (stricter variant
    /// used by the per-batch duplication planner).
    pub fn error_rate_per_batch(&self, test: &Trace) -> f64 {
        let p_hat = self.mle();
        let errs: Vec<f64> = test
            .batches
            .iter()
            .map(|b| {
                let counts = b.expert_counts(self.n_experts);
                let total: usize = counts.iter().sum();
                if total == 0 {
                    return 0.0;
                }
                let p: Vec<f64> =
                    counts.iter().map(|&c| c as f64 / total as f64).collect();
                stats::l1_distance(&p_hat, &p)
            })
            .collect();
        stats::mean(&errs)
    }
}

/// The canonical Distribution-Only predictor behind the unified trait
/// (ADR 005): `fit` replays a training trace batch-by-batch (the paper's
/// "moving average" framing), `observe` is the streaming update the
/// serving pipeline's router-settle stage feeds, and `predict_topk` is
/// `None` — this family holds no per-token opinion.
impl Predictor for DistributionEstimator {
    fn name(&self) -> String {
        "distribution-mle".into()
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::DistributionOnly
    }

    fn fit(&mut self, train: &Trace) {
        for b in &train.batches {
            self.update(&b.expert_counts(self.n_experts));
        }
    }

    fn predict_distribution(&self) -> Vec<f64> {
        self.mle()
    }

    fn predict_topk(&self, _batch: &Batch, _k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        None
    }

    fn observe(&mut self, routed_counts: &[usize]) {
        self.update(routed_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{datasets, Trace};

    #[test]
    fn mle_is_empirical_frequency() {
        let mut est = DistributionEstimator::new(4);
        est.update(&[75, 10, 10, 5]);
        let p = est.mle();
        assert!((p[0] - 0.75).abs() < 1e-12);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_estimator_is_uniform() {
        let est = DistributionEstimator::new(8);
        assert_eq!(est.mle(), vec![0.125; 8]);
        assert_eq!(est.predicted_skewness(), 1.0);
    }

    #[test]
    fn ema_tracks_drift_faster_than_mle() {
        let mut est = DistributionEstimator::new(2);
        for _ in 0..50 {
            est.update(&[90, 10]);
        }
        for _ in 0..5 {
            est.update(&[10, 90]);
        }
        let mle = est.mle();
        let ema = est.ema();
        // EMA should have moved further toward the new regime.
        assert!(ema[1] > mle[1], "ema={ema:?} mle={mle:?}");
    }

    #[test]
    fn error_rate_on_matched_distribution_is_small() {
        let trace = Trace::generate(datasets::mmlu_like(11));
        let (train, test) = trace.split(0.8);
        let mut est = DistributionEstimator::new(8);
        est.fit(&train);
        let err = est.error_rate(&test);
        // MMLU-like is calibrated to ~1.8%; anything under 6% proves the
        // estimator; the exact calibration is asserted in bench table1.
        assert!(err < 0.06, "err={err}");
    }

    #[test]
    fn error_rate_ordering_matches_table1() {
        // SST2-like (heterogeneous) must show much larger estimation error
        // than MMLU-like / Alpaca-like — the Table 1 trend.
        let seeds = 17;
        let mk = |spec| {
            let t = Trace::generate(spec);
            let (train, test) = t.split(0.8);
            let mut est = DistributionEstimator::new(8);
            est.fit(&train);
            est.error_rate(&test)
        };
        let mmlu = mk(datasets::mmlu_like(seeds));
        let alpaca = mk(datasets::alpaca_like(seeds));
        let sst2 = mk(datasets::sst2_like(seeds));
        assert!(sst2 > 2.0 * mmlu, "sst2={sst2} mmlu={mmlu}");
        assert!(sst2 > 2.0 * alpaca, "sst2={sst2} alpaca={alpaca}");
    }

    #[test]
    fn predicted_skewness_tracks_trace() {
        let trace = Trace::generate(datasets::sst2_like(5));
        let mut est = DistributionEstimator::new(8);
        est.fit(&trace);
        let s = est.predicted_skewness();
        assert!((s - 1.99).abs() < 0.35, "s={s}");
    }
}
