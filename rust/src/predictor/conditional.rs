//! Conditional probability models (Appendix B): condition the frequency
//! table on the token index or on the absolute position, and predict the
//! per-condition ranked experts. Captures per-token / per-position routing
//! biases at lookup-table cost.

use super::probability::ProbabilityModel;
use super::{rank_topk_u32, Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};

/// What the frequency table is conditioned on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Conditioning {
    /// Vocabulary id of the token.
    TokenId,
    /// Absolute position in the sequence.
    Position,
}

#[derive(Clone, Debug)]
pub struct ConditionalModel {
    pub conditioning: Conditioning,
    n_experts: usize,
    /// counts[condition][expert]
    counts: Vec<Vec<u32>>,
    /// Fallback for unseen conditions.
    fallback: ProbabilityModel,
}

impl ConditionalModel {
    pub fn new(conditioning: Conditioning) -> ConditionalModel {
        ConditionalModel {
            conditioning,
            n_experts: 0,
            counts: Vec::new(),
            fallback: ProbabilityModel::new(),
        }
    }

    fn condition_index(&self, token_id: u32, pos: usize) -> usize {
        match self.conditioning {
            Conditioning::TokenId => token_id as usize,
            Conditioning::Position => pos,
        }
    }

    /// Memory footprint of the lookup table in entries (used by the
    /// overhead model).
    pub fn table_entries(&self) -> usize {
        self.counts.len() * self.n_experts
    }
}

impl Predictor for ConditionalModel {
    fn name(&self) -> String {
        match self.conditioning {
            Conditioning::TokenId => "conditional-token".into(),
            Conditioning::Position => "conditional-position".into(),
        }
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::TokenToExpert
    }

    fn fit(&mut self, train: &Trace) {
        self.n_experts = train.spec.n_experts;
        let n_conditions = match self.conditioning {
            Conditioning::TokenId => train.spec.vocab_size,
            Conditioning::Position => train.spec.seq_len,
        };
        self.counts = vec![vec![0u32; self.n_experts]; n_conditions];
        for batch in &train.batches {
            for seq in &batch.sequences {
                for (pos, tok) in seq.iter().enumerate() {
                    let cond = self.condition_index(tok.id, pos);
                    if cond < self.counts.len() {
                        self.counts[cond][tok.expert as usize] += 1;
                    }
                }
            }
        }
        self.fallback.fit(train);
    }

    fn predict_distribution(&self) -> Vec<f64> {
        self.fallback.predict_distribution()
    }

    fn predict_topk(&self, batch: &Batch, k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        let fallback_sets = self.fallback.predict_topk(batch, k)?;
        let mut order = Vec::with_capacity(self.n_experts);
        Some(
            batch
                .sequences
                .iter()
                .zip(fallback_sets)
                .map(|(seq, fb)| {
                    seq.iter()
                        .enumerate()
                        .zip(fb)
                        .map(|((pos, tok), fb_ranked)| {
                            let cond = self.condition_index(tok.id, pos);
                            match self.counts.get(cond) {
                                Some(row) if row.iter().sum::<u32>() > 0 => {
                                    rank_topk_u32(row, k, &mut order)
                                        .iter()
                                        .map(|&e| e as u8)
                                        .collect()
                                }
                                _ => fb_ranked,
                            }
                        })
                        .collect()
                })
                .collect(),
        )
    }

    /// Aggregate routed counts carry no condition labels, so the online
    /// signal lands in the global fallback distribution (the conditional
    /// table itself only learns offline, from labelled traces).
    fn observe(&mut self, routed_counts: &[usize]) {
        self.fallback.observe(routed_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::accuracy::{accuracy, top1_predictions};
    use crate::predictor::probability::ProbabilityModel;
    use crate::trace::{datasets, Trace};

    #[test]
    fn token_conditioning_beats_global_probability() {
        // Traces have unigram predictability λ — conditioning on token id
        // must exploit it.
        let trace = Trace::generate(datasets::mmlu_like(21));
        let (train, test) = trace.split(0.8);
        let mut cond = ConditionalModel::new(Conditioning::TokenId);
        cond.fit(&train);
        let mut prob = ProbabilityModel::new();
        prob.fit(&train);
        let acc_cond = accuracy(&cond, &test);
        let acc_prob = accuracy(&prob, &test);
        assert!(
            acc_cond > acc_prob + 0.1,
            "cond={acc_cond} prob={acc_prob}"
        );
    }

    #[test]
    fn position_conditioning_no_worse_than_global() {
        // Our generator has no positional bias, so position conditioning
        // should roughly match the probability model (not crash / degrade
        // catastrophically).
        let trace = Trace::generate(datasets::mmlu_like(22));
        let (train, test) = trace.split(0.8);
        let mut cond = ConditionalModel::new(Conditioning::Position);
        cond.fit(&train);
        let mut prob = ProbabilityModel::new();
        prob.fit(&train);
        let acc_cond = accuracy(&cond, &test);
        let acc_prob = accuracy(&prob, &test);
        assert!((acc_cond - acc_prob).abs() < 0.05);
    }

    #[test]
    fn unseen_tokens_fall_back() {
        // Tiny train slice → most vocab unseen; predictions must still be
        // produced for every token.
        let trace = Trace::generate(datasets::mmlu_like(23));
        let (train, test) = trace.split(0.02);
        let mut cond = ConditionalModel::new(Conditioning::TokenId);
        cond.fit(&train);
        let preds = top1_predictions(&cond, &test.batches[0]);
        assert_eq!(preds.len(), test.batches[0].sequences.len());
        assert!(preds
            .iter()
            .zip(&test.batches[0].sequences)
            .all(|(p, s)| p.len() == s.len()));
    }

    #[test]
    fn table_entries_reflect_conditioning() {
        let trace = Trace::generate(datasets::mmlu_like(24));
        let mut by_token = ConditionalModel::new(Conditioning::TokenId);
        by_token.fit(&trace);
        let mut by_pos = ConditionalModel::new(Conditioning::Position);
        by_pos.fit(&trace);
        assert_eq!(by_token.table_entries(), trace.spec.vocab_size * 8);
        assert_eq!(by_pos.table_entries(), trace.spec.seq_len * 8);
    }

    #[test]
    fn topk_sets_contain_the_argmax_and_respect_k() {
        let trace = Trace::generate(datasets::mmlu_like(25));
        let (train, test) = trace.split(0.8);
        let mut cond = ConditionalModel::new(Conditioning::TokenId);
        cond.fit(&train);
        let k = 3;
        let sets = cond.predict_topk(&test.batches[0], k).unwrap();
        let top1 = top1_predictions(&cond, &test.batches[0]);
        for (seq_sets, seq_top1) in sets.iter().zip(&top1) {
            for (ranked, &argmax) in seq_sets.iter().zip(seq_top1) {
                assert_eq!(ranked.len(), k);
                assert_eq!(ranked[0], argmax, "rank 0 is the argmax");
            }
        }
    }
}
