//! Predictor runtime-overhead model (paper §3.2.2 / Figure 4).
//!
//! The paper measures each predictor's inference overhead on A100s and
//! reports it as a *ratio to the simulated model runtime* (§5 "Kernel
//! underutilization": "we report and analyze prediction overhead as a ratio
//! to the simulated inference runtime"). We price each predictor's
//! arithmetic on the same roofline the simulator uses:
//!
//! * lookup-family predictors (probability / conditional / bigram):
//!   memory-bound gathers over their tables;
//! * the FFN predictor (paper Appendix B): GEMMs `d_model→128→64→E` per
//!   token, per MoE layer head;
//! * the LSTM predictor: a *serial* scan over the sequence — per-step
//!   small matvecs that cannot batch across time, which is what makes it
//!   expensive (the paper's §5 "LSTM-based predictors … suffer from poor
//!   parallelism");
//! * the in-crate MLP (for the rust-trained sweeps): embedding gathers +
//!   two small GEMMs.

use crate::model::ModelConfig;
use crate::sim::hardware::{Dtype, SystemSpec};
use crate::sim::roofline;

/// Predictor families with their cost-relevant parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PredictorKind {
    /// Global argmax broadcast.
    Probability,
    /// Table gather conditioned on token id (table ≈ vocab × E).
    ConditionalToken,
    /// Table gather conditioned on position (table ≈ seq × E).
    ConditionalPosition,
    /// Two-level gather with hashing over bigram table.
    BigramContext,
    /// The paper's FFN predictor: d_model → 128 → 64 → E per token.
    PaperFfn,
    /// The paper's LSTM (+sparse attention): serial scan, 2 layers,
    /// hidden 64, input compressed d_model → 128.
    PaperLstm,
    /// Our rust MLP: 2 embeddings (d_emb) → hidden → E.
    RustMlp { d_emb: usize, hidden: usize },
}

impl PredictorKind {
    pub fn name(&self) -> String {
        match self {
            PredictorKind::Probability => "probability".into(),
            PredictorKind::ConditionalToken => "conditional-token".into(),
            PredictorKind::ConditionalPosition => "conditional-position".into(),
            PredictorKind::BigramContext => "bigram-context".into(),
            PredictorKind::PaperFfn => "ffn-net".into(),
            PredictorKind::PaperLstm => "lstm-net".into(),
            PredictorKind::RustMlp { hidden, .. } => format!("mlp-h{hidden}"),
        }
    }
}

/// Request-path overhead (seconds) of running the predictor on a
/// `batch × seq` token batch, for *one* MoE layer's prediction.
pub fn overhead_s(
    kind: PredictorKind,
    model: &ModelConfig,
    system: &SystemSpec,
    batch: usize,
    seq: usize,
) -> f64 {
    let dev = &system.device;
    let tokens = batch * seq;
    let dt = Dtype::Fp16;
    match kind {
        PredictorKind::Probability => {
            // One broadcasted write of the argmax expert id.
            roofline::elementwise_time(dev, tokens, 1.0, 0, dt)
        }
        PredictorKind::ConditionalToken | PredictorKind::ConditionalPosition => {
            // Gather one table row per token + argmax over E.
            roofline::elementwise_time(dev, tokens * model.n_experts, 2.0, 1, dt)
        }
        PredictorKind::BigramContext => {
            // Hash + two gathers + fallback row.
            2.0 * roofline::elementwise_time(dev, tokens * model.n_experts, 3.0, 2, dt)
        }
        PredictorKind::PaperFfn => {
            // d_model → 128 → 64 → E (+ one head per MoE layer, amortised:
            // the paper predicts layer-by-layer; we price one layer).
            roofline::gemm_time(dev, tokens, 128, model.d_model, dt)
                + roofline::gemm_time(dev, tokens, 64, 128, dt)
                + roofline::gemm_time(dev, tokens, model.n_experts, 64, dt)
        }
        PredictorKind::PaperLstm => {
            // Input compression is parallel over tokens...
            let compress = roofline::gemm_time(dev, tokens, 128, model.d_model, dt);
            // ...but the 2-layer LSTM scan is serial in time: `seq` steps of
            // small matvecs over the whole batch. Each step is launch- and
            // latency-bound (tiny GEMMs), which is the poor parallelism the
            // paper calls out.
            let per_step_flops =
                2.0 * batch as f64 * (4.0 * 64.0 * (128.0 + 64.0)) * 2.0; // 2 layers
            let step_util = 0.02; // tiny serial matvec utilisation
            let per_step_s = (per_step_flops
                / (dev.peak_matrix_tflops * 1e12 * step_util))
                .max(dev.kernel_launch_s);
            let scan = seq as f64 * per_step_s;
            // Sparse attention over LSTM outputs + heads.
            let attn = roofline::gemm_time(dev, tokens, 64, 64, dt);
            let head = roofline::gemm_time(dev, tokens, model.n_experts, 64, dt);
            compress + scan + attn + head
        }
        PredictorKind::RustMlp { d_emb, hidden } => {
            roofline::gemm_time(dev, tokens, hidden, 2 * d_emb, dt)
                + roofline::gemm_time(dev, tokens, model.n_experts, hidden, dt)
                + roofline::elementwise_time(dev, tokens * 2 * d_emb, 1.0, 1, dt)
        }
    }
}

/// Overhead expressed as a ratio to a reference layer runtime (how the
/// paper's Figure 4 y-axis is defined).
pub fn overhead_ratio(
    kind: PredictorKind,
    model: &ModelConfig,
    system: &SystemSpec,
    batch: usize,
    seq: usize,
    layer_runtime_s: f64,
) -> f64 {
    overhead_s(kind, model, system, batch, seq) / layer_runtime_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SystemSpec;

    fn setup() -> (ModelConfig, SystemSpec) {
        (ModelConfig::mixtral_8x7b(), SystemSpec::four_a100_nvlink())
    }

    #[test]
    fn overhead_ordering_matches_complexity() {
        let (m, s) = setup();
        let o = |k| overhead_s(k, &m, &s, 1, 512);
        let prob = o(PredictorKind::Probability);
        let cond = o(PredictorKind::ConditionalToken);
        let bigram = o(PredictorKind::BigramContext);
        let ffn = o(PredictorKind::PaperFfn);
        let lstm = o(PredictorKind::PaperLstm);
        assert!(prob <= cond, "prob={prob} cond={cond}");
        assert!(cond < bigram);
        assert!(bigram < ffn, "bigram={bigram} ffn={ffn}");
        assert!(ffn < lstm, "ffn={ffn} lstm={lstm}");
    }

    #[test]
    fn lstm_scan_dominated_by_sequence_length() {
        let (m, s) = setup();
        let short = overhead_s(PredictorKind::PaperLstm, &m, &s, 1, 128);
        let long = overhead_s(PredictorKind::PaperLstm, &m, &s, 1, 1024);
        // Serial scan: ~linear in seq.
        let ratio = long / short;
        assert!(ratio > 4.0, "ratio={ratio}");
    }

    #[test]
    fn ffn_predictor_cheaper_than_model_layer() {
        // Paper Figure 4: overhead is a modest fraction of layer runtime.
        let (m, s) = setup();
        let sim = crate::sim::LayerSim::new(m.clone(), s.clone());
        let layer = sim.baseline_total(1.4);
        let ratio =
            overhead_ratio(PredictorKind::PaperFfn, &m, &s, 1, 512, layer);
        assert!(ratio > 0.001 && ratio < 0.6, "ratio={ratio}");
    }

    #[test]
    fn rust_mlp_overhead_scales_with_hidden() {
        let (m, s) = setup();
        let small = overhead_s(
            PredictorKind::RustMlp { d_emb: 16, hidden: 32 },
            &m,
            &s,
            1,
            512,
        );
        let big = overhead_s(
            PredictorKind::RustMlp { d_emb: 16, hidden: 256 },
            &m,
            &s,
            1,
            512,
        );
        assert!(big > small);
    }
}
