//! Bigram (context-conditioned) model — the sequence-context predictor.
//!
//! The paper's LSTM predictor exploits temporal dependencies between tokens
//! (Appendix B). We cannot train an LSTM in torch here, so — per DESIGN.md
//! §3 — the *context-capturing* predictor is a bigram frequency model: it
//! conditions the expert frequency table on the (previous-token, token)
//! pair, backed off to the unigram conditional, backed off to the global
//! argmax. This captures exactly the context signal (`mu`) the trace
//! generator injects, the same way an LSTM captures context in the paper's
//! real traces. Its *runtime overhead* is priced separately in `overhead`
//! (where the paper's actual LSTM serial-scan cost is modelled).

use std::collections::HashMap;

use super::conditional::{ConditionalModel, Conditioning};
use super::{rank_topk_u32, Predictor, PredictorFamily};
use crate::trace::{Batch, Trace};

#[derive(Clone, Debug)]
pub struct BigramModel {
    n_experts: usize,
    /// (prev_id, id) → per-expert counts.
    counts: HashMap<(u32, u32), Vec<u32>>,
    /// Minimum observations before the bigram row is trusted.
    pub min_support: u32,
    fallback: ConditionalModel,
}

impl BigramModel {
    pub fn new() -> BigramModel {
        BigramModel {
            n_experts: 0,
            counts: HashMap::new(),
            min_support: 2,
            fallback: ConditionalModel::new(Conditioning::TokenId),
        }
    }

    /// Number of bigram rows learned (used by the overhead model).
    pub fn table_rows(&self) -> usize {
        self.counts.len()
    }
}

impl Default for BigramModel {
    fn default() -> Self {
        Self::new()
    }
}

impl Predictor for BigramModel {
    fn name(&self) -> String {
        "bigram-context".into()
    }

    fn family(&self) -> PredictorFamily {
        PredictorFamily::TokenToExpert
    }

    fn fit(&mut self, train: &Trace) {
        self.n_experts = train.spec.n_experts;
        self.counts.clear();
        for batch in &train.batches {
            for seq in &batch.sequences {
                for pair in seq.windows(2) {
                    let key = (pair[0].id, pair[1].id);
                    let row = self
                        .counts
                        .entry(key)
                        .or_insert_with(|| vec![0u32; self.n_experts]);
                    row[pair[1].expert as usize] += 1;
                }
            }
        }
        self.fallback.fit(train);
    }

    fn predict_distribution(&self) -> Vec<f64> {
        self.fallback.predict_distribution()
    }

    fn predict_topk(&self, batch: &Batch, k: usize) -> Option<Vec<Vec<Vec<u8>>>> {
        let fallback_sets = self.fallback.predict_topk(batch, k)?;
        let mut order = Vec::with_capacity(self.n_experts);
        Some(
            batch
                .sequences
                .iter()
                .zip(fallback_sets)
                .map(|(seq, fb)| {
                    seq.iter()
                        .enumerate()
                        .zip(fb)
                        .map(|((pos, tok), fb_ranked)| {
                            if pos == 0 {
                                return fb_ranked;
                            }
                            let key = (seq[pos - 1].id, tok.id);
                            match self.counts.get(&key) {
                                Some(row)
                                    if row.iter().sum::<u32>() >= self.min_support =>
                                {
                                    rank_topk_u32(row, k, &mut order)
                                        .iter()
                                        .map(|&e| e as u8)
                                        .collect()
                                }
                                _ => fb_ranked,
                            }
                        })
                        .collect()
                })
                .collect(),
        )
    }

    /// Aggregate routed counts carry no (prev, cur) labels; the online
    /// signal lands in the fallback chain's global distribution.
    fn observe(&mut self, routed_counts: &[usize]) {
        self.fallback.observe(routed_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::accuracy::accuracy;
    use crate::trace::{datasets, generator::TraceSpec, Trace};

    /// A spec with strong context signal so the bigram model shows its
    /// advantage clearly.
    fn contextual_spec(seed: u64) -> TraceSpec {
        TraceSpec {
            mu: 0.5,
            lambda: 0.3,
            vocab_size: 64, // small vocab → bigram rows well supported
            drift: 0.0,
            ..datasets::mmlu_like(seed)
        }
    }

    #[test]
    fn bigram_beats_unigram_on_contextual_traces() {
        let trace = Trace::generate(contextual_spec(31));
        let (train, test) = trace.split(0.8);
        let mut bigram = BigramModel::new();
        bigram.fit(&train);
        let mut unigram = ConditionalModel::new(Conditioning::TokenId);
        unigram.fit(&train);
        let acc_bi = accuracy(&bigram, &test);
        let acc_uni = accuracy(&unigram, &test);
        assert!(
            acc_bi > acc_uni + 0.05,
            "bigram={acc_bi} unigram={acc_uni}"
        );
    }

    #[test]
    fn falls_back_gracefully_without_context_signal() {
        let mut spec = datasets::mmlu_like(32);
        spec.mu = 0.0;
        let trace = Trace::generate(spec);
        let (train, test) = trace.split(0.8);
        let mut bigram = BigramModel::new();
        bigram.fit(&train);
        let mut unigram = ConditionalModel::new(Conditioning::TokenId);
        unigram.fit(&train);
        let acc_bi = accuracy(&bigram, &test);
        let acc_uni = accuracy(&unigram, &test);
        // Without context signal the bigram should not be much worse.
        assert!(acc_bi > acc_uni - 0.06, "bigram={acc_bi} unigram={acc_uni}");
    }

    #[test]
    fn table_grows_with_data() {
        let trace = Trace::generate(contextual_spec(33));
        let mut bigram = BigramModel::new();
        bigram.fit(&trace);
        assert!(bigram.table_rows() > 1000);
    }
}
