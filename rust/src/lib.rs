//! # MoE-GPS
//!
//! Reproduction of *"MoE-GPS: Guidelines for Prediction Strategy for Dynamic
//! Expert Duplication in MoE Load Balancing"* (Ma, Du, Chen — cs.LG 2025) as a
//! three-layer rust + JAX + Pallas serving stack.
//!
//! The crate is organised as:
//!
//! * [`util`] / [`testing`] / [`bench`] — dependency-free substrates (PRNG,
//!   JSON, CLI args, stats, property testing, micro-benchmark harness). The
//!   build environment only ships the `xla` and `anyhow` crates, so everything
//!   else is implemented here.
//! * [`sim`] — an LLMCompass-like block-level performance simulator for
//!   transformer inference (roofline compute costs, collective communication,
//!   attention/FFN/MoE layer models, prediction-error models).
//! * [`model`] — model architecture configurations (Mixtral 8×7B / 8×22B,
//!   LLaMA-MoE, Switch Transformer, and the tiny serving model).
//! * [`trace`] — synthetic routing-trace generation calibrated to the paper's
//!   measured dataset skewness (MMLU ≈ 1.39, Alpaca Eval ≈ 1.40, SST2 ≈ 1.99).
//! * [`predictor`] — the paper's prediction strategies: Distribution-Only
//!   (multinomial MLE) and Token-to-Expert (probability, conditional
//!   probability, neural network predictors) plus the accuracy↔overhead model.
//! * [`duplication`] — Algorithm 1 (dynamic expert duplication) and token
//!   dispatch.
//! * [`gps`] — the MoE-GPS framework proper: sweeps, strategy selection and
//!   the Figure-1 guideline output.
//! * [`runtime`] — PJRT engine: loads AOT-compiled HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them on the request
//!   path (python is never on the request path).
//! * [`coordinator`] — the serving layer: request router, dynamic batcher,
//!   virtual-GPU expert-parallel workers, and the predictor-driven expert
//!   placement manager.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment index
//! mapping every table and figure of the paper to a bench target.

pub mod bench;
pub mod coordinator;
pub mod duplication;
pub mod gps;
pub mod model;
pub mod predictor;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod trace;
pub mod util;

/// Crate version, re-exported for the CLI `--version` flag.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
