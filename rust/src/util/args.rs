//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports the shapes the `moe-gps` CLI needs: a leading subcommand,
//! `--key value` options, `--flag` booleans, and positional arguments.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, named options, flags and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        out.options.insert(name.to_string(), v);
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{s}`")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{s}`")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{s}`")),
        }
    }

    /// Parse an optional byte size with a binary suffix, e.g.
    /// `--memory-cap 24g`, `--prewarm-budget 1.5m`, `--memory-cap 4096`
    /// (plain numbers are bytes; k/m/g/t are KiB/MiB/GiB/TiB, an optional
    /// trailing `b`/`ib` is accepted). Returns `None` when absent.
    pub fn opt_bytes(&self, name: &str) -> anyhow::Result<Option<u64>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => parse_byte_size(s)
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name} expects a byte size (e.g. 24g, 512m, 4096), got `{s}`")),
        }
    }

    /// Parse a comma-separated list of floats, e.g. `--skews 1.0,1.4,2.0`.
    pub fn opt_f64_list(&self, name: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|part| {
                    part.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad number `{part}`"))
                })
                .collect(),
        }
    }
}

/// Parse `"24g"` / `"512m"` / `"1.5m"` / `"4096"` into bytes (binary
/// multipliers; optional trailing `b` or `ib` after the unit).
pub fn parse_byte_size(s: &str) -> Result<u64, ()> {
    let t = s.trim().to_ascii_lowercase();
    let t = t.strip_suffix("ib").or_else(|| t.strip_suffix('b')).unwrap_or(&t);
    let (digits, mult) = match t.chars().last() {
        Some('k') => (&t[..t.len() - 1], 1024.0),
        Some('m') => (&t[..t.len() - 1], 1024.0 * 1024.0),
        Some('g') => (&t[..t.len() - 1], 1024.0 * 1024.0 * 1024.0),
        Some('t') => (&t[..t.len() - 1], 1024.0 * 1024.0 * 1024.0 * 1024.0),
        _ => (t, 1.0),
    };
    let v: f64 = digits.trim().parse().map_err(|_| ())?;
    if !v.is_finite() || v < 0.0 {
        return Err(());
    }
    Ok((v * mult).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str], flags: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(
            &["simulate", "--model", "mixtral-8x7b", "--skew", "1.4"],
            &[],
        );
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("model"), Some("mixtral-8x7b"));
        assert_eq!(a.opt_f64("skew", 1.0).unwrap(), 1.4);
        assert_eq!(a.opt_f64("missing", 2.0).unwrap(), 2.0);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&["sweep", "--fast", "--seq=512", "--verbose"], &["fast"]);
        assert!(a.flag("fast"));
        assert!(a.flag("verbose")); // trailing --flag with no value
        assert_eq!(a.opt_usize("seq", 0).unwrap(), 512);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "val"], &[]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("val"));
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse(&["trace", "out.json", "extra"], &[]);
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        assert_eq!(a.positionals, vec!["out.json", "extra"]);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--skews", "1.0, 1.4,2.0"], &[]);
        assert_eq!(
            a.opt_f64_list("skews", &[]).unwrap(),
            vec![1.0, 1.4, 2.0]
        );
        assert_eq!(a.opt_f64_list("other", &[9.0]).unwrap(), vec![9.0]);
        let bad = parse(&["x", "--skews", "1.0,zzz"], &[]);
        assert!(bad.opt_f64_list("skews", &[]).is_err());
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--n", "abc"], &[]);
        assert!(a.opt_usize("n", 0).is_err());
        assert!(a.opt_f64("n", 0.0).is_err());
        assert!(a.opt_u64("n", 0).is_err());
    }

    #[test]
    fn byte_sizes_parse_with_binary_suffixes() {
        assert_eq!(parse_byte_size("4096"), Ok(4096));
        assert_eq!(parse_byte_size("2k"), Ok(2048));
        assert_eq!(parse_byte_size("1.5m"), Ok(1_572_864));
        assert_eq!(parse_byte_size("24g"), Ok(24 * 1024 * 1024 * 1024));
        assert_eq!(parse_byte_size("24GiB"), Ok(24 * 1024 * 1024 * 1024));
        assert_eq!(parse_byte_size("512MB"), Ok(512 * 1024 * 1024));
        assert_eq!(parse_byte_size(" 2T "), Ok(2_199_023_255_552));
        assert!(parse_byte_size("oops").is_err());
        assert!(parse_byte_size("-4k").is_err());
        assert!(parse_byte_size("").is_err());
    }

    #[test]
    fn opt_bytes_absent_none_bad_errors() {
        let a = parse(&["serve", "--memory-cap", "256k"], &[]);
        assert_eq!(a.opt_bytes("memory-cap").unwrap(), Some(262_144));
        assert_eq!(a.opt_bytes("prewarm-budget").unwrap(), None);
        let bad = parse(&["serve", "--memory-cap", "many"], &[]);
        assert!(bad.opt_bytes("memory-cap").is_err());
    }
}
