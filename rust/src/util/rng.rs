//! Pseudo-random number generation substrate.
//!
//! The `rand` crate is not available offline, so this module implements the
//! generators the project needs: a SplitMix64 seeder, a PCG64 (XSL-RR 128/64)
//! core generator, plus the distributions used by the trace generator
//! (uniform, normal, gamma, Dirichlet, multinomial/categorical) and
//! permutation helpers. All generators are deterministic given a seed, which
//! the benches rely on for reproducibility.

/// SplitMix64 — used to expand a single `u64` seed into PCG state.
/// Reference: Steele, Lea & Flood, "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG64 (XSL-RR 128/64). The project's core generator: fast, small state,
/// excellent statistical quality for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // stream must be odd
            spare_normal: None,
        };
        // Warm up so that nearby seeds diverge immediately.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with caching of the spare deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gamma(shape, 1.0) via Marsaglia–Tsang (2000), with the shape<1 boost.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Sample a probability vector from a symmetric-or-general Dirichlet.
    pub fn dirichlet(&mut self, alphas: &[f64]) -> Vec<f64> {
        let mut draws: Vec<f64> = alphas.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            // Degenerate fallback: uniform.
            let k = alphas.len() as f64;
            return vec![1.0 / k; alphas.len()];
        }
        for d in &mut draws {
            *d /= sum;
        }
        draws
    }

    /// Categorical draw: index `i` with probability `probs[i]`.
    /// `probs` need not be normalised.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let total: f64 = probs.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.f64() * total;
        for (i, &p) in probs.iter().enumerate() {
            target -= p;
            if target < 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Multinomial counts: distribute `n` trials over `probs`.
    pub fn multinomial(&mut self, n: usize, probs: &[f64]) -> Vec<usize> {
        let mut counts = vec![0usize; probs.len()];
        for _ in 0..n {
            counts[self.categorical(probs)] += 1;
        }
        counts
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.range(0, slice.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(9);
        for &shape in &[0.5, 1.0, 2.5, 8.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() / shape < 0.05,
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        let p = r.dirichlet(&[0.3, 0.3, 0.3, 0.3, 0.3, 0.3, 0.3, 0.3]);
        assert_eq!(p.len(), 8);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn categorical_frequencies_track_probs() {
        let mut r = Rng::new(17);
        let probs = [0.1, 0.2, 0.7];
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[r.categorical(&probs)] += 1;
        }
        for (i, &p) in probs.iter().enumerate() {
            let freq = counts[i] as f64 / n as f64;
            assert!((freq - p).abs() < 0.01, "i={i} freq={freq}");
        }
    }

    #[test]
    fn multinomial_conserves_total() {
        let mut r = Rng::new(19);
        let counts = r.multinomial(512, &[0.75, 0.10, 0.10, 0.05]);
        assert_eq!(counts.iter().sum::<usize>(), 512);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(31);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
