//! Leveled logging substrate writing to stderr, controlled by the
//! `MOE_GPS_LOG` environment variable (`error|warn|info|debug|trace`,
//! default `info`). Kept deliberately simple: one global atomic level and
//! macro-free function API plus convenience macros.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let level = std::env::var("MOE_GPS_LOG")
            .ok()
            .and_then(|s| Level::from_str(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(level as u8, Ordering::Relaxed);
        return level;
    }
    // Safety: only valid discriminants are ever stored.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Core logging entry point; prefer the `log_*!` macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let stderr = std::io::stderr();
    let mut handle = stderr.lock();
    let _ = writeln!(handle, "[{:5}] {}: {}", level.name(), module, msg);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
