//! Small statistics toolkit: summary statistics, percentiles, online
//! accumulators, distance metrics and curve fitting used across the
//! simulator, the predictor-evaluation harness and the bench reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile in `[0, 100]` with linear interpolation between order
/// statistics. Sorts a copy of the input.
pub fn percentile(xs: &[f64], pct: f64) -> f64 {
    assert!((0.0..=100.0).contains(&pct));
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// L1 distance between two vectors of equal length.
pub fn l1_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// L1 distance between the *share* distributions of two count histograms
/// (each normalised to sum to 1) — the Table-1 error metric applied to
/// predicted-vs-routed per-expert counts. 0.0 when either side is empty.
pub fn l1_of_counts(a: &[usize], b: &[usize]) -> f64 {
    let (ta, tb): (usize, usize) = (a.iter().sum(), b.iter().sum());
    if ta == 0 || tb == 0 || a.len() != b.len() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 / ta as f64 - y as f64 / tb as f64).abs())
        .sum()
}

/// Normalise a non-negative vector to sum to 1. Uniform if the sum is 0.
pub fn normalize(xs: &[f64]) -> Vec<f64> {
    let sum: f64 = xs.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / xs.len() as f64; xs.len()];
    }
    xs.iter().map(|x| x / sum).collect()
}

/// The paper's skewness metric over a token-count histogram:
/// `max_count / (total / n_bins)`. Returns 1.0 for empty input.
pub fn skewness_of_counts(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 || counts.is_empty() {
        return 1.0;
    }
    let max = *counts.iter().max().unwrap() as f64;
    let avg = total as f64 / counts.len() as f64;
    max / avg
}

/// Skewness over a probability vector (counts already normalised).
pub fn skewness_of_probs(probs: &[f64]) -> f64 {
    if probs.is_empty() {
        return 1.0;
    }
    let max = probs.iter().cloned().fold(f64::MIN, f64::max);
    let sum: f64 = probs.iter().sum();
    if sum <= 0.0 {
        return 1.0;
    }
    max / (sum / probs.len() as f64)
}

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Least-squares fit of `y = a * exp(b * x)` (by linear regression on ln y).
/// Used for the paper's accuracy→overhead curves (Figure 4). All `y` must be
/// positive. Returns `(a, b)`.
pub fn fit_exponential(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let log_ys: Vec<f64> = ys
        .iter()
        .map(|&y| {
            assert!(y > 0.0, "fit_exponential requires positive y");
            y.ln()
        })
        .collect();
    let (b, ln_a) = linear_regression(xs, &log_ys);
    (ln_a.exp(), b)
}

/// Ordinary least squares `y = slope * x + intercept`; returns
/// `(slope, intercept)`.
pub fn linear_regression(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    if sxx == 0.0 {
        return (0.0, my);
    }
    let slope = sxy / sxx;
    (slope, my - slope * mx)
}

/// Least-squares polynomial fit of given degree via normal equations with
/// Gaussian elimination. Returns coefficients `c[0] + c[1] x + ... + c[d] x^d`.
/// Used for the paper's accuracy→performance curves (Figure 4).
pub fn fit_polynomial(xs: &[f64], ys: &[f64], degree: usize) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() > degree, "need more points than degree");
    let n = degree + 1;
    // Build normal equations A c = b where A[i][j] = sum x^(i+j).
    let mut pow_sums = vec![0.0; 2 * degree + 1];
    for &x in xs {
        let mut p = 1.0;
        for s in pow_sums.iter_mut() {
            *s += p;
            p *= x;
        }
    }
    let mut a = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            a[i][j] = pow_sums[i + j];
        }
    }
    let mut b = vec![0.0; n];
    for (&x, &y) in xs.iter().zip(ys) {
        let mut p = 1.0;
        for bi in b.iter_mut() {
            *bi += p * y;
            p *= x;
        }
    }
    gaussian_solve(&mut a, &mut b);
    b
}

/// Solve `A x = b` in place via Gaussian elimination with partial pivoting;
/// the solution is written into `b`.
fn gaussian_solve(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        let diag = a[col][col];
        assert!(diag.abs() > 1e-12, "singular normal equations");
        for row in col + 1..n {
            let factor = a[row][col] / diag;
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    for col in (0..n).rev() {
        let mut acc = b[col];
        for k in col + 1..n {
            acc -= a[col][k] * b[k];
        }
        b[col] = acc / a[col][col];
    }
}

/// Evaluate a polynomial given coefficients in ascending-degree order.
pub fn eval_polynomial(coeffs: &[f64], x: f64) -> f64 {
    coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn skewness_matches_paper_example() {
        // Figure 2: expert 1 has 75% of tokens over 4 experts → skewness 3.
        let counts = [75, 9, 8, 8];
        let s = skewness_of_counts(&counts);
        assert!((s - 3.0).abs() < 0.01, "s={s}");
        let probs = [0.75, 0.0833, 0.0833, 0.0834];
        assert!((skewness_of_probs(&probs) - 3.0).abs() < 0.01);
    }

    #[test]
    fn skewness_balanced_is_one() {
        assert_eq!(skewness_of_counts(&[25, 25, 25, 25]), 1.0);
        assert_eq!(skewness_of_counts(&[]), 1.0);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 9.0);
        assert_eq!(acc.count(), 8);
    }

    #[test]
    fn exponential_fit_recovers_params() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64 / 20.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 0.5 * (3.0 * x).exp()).collect();
        let (a, b) = fit_exponential(&xs, &ys);
        assert!((a - 0.5).abs() < 1e-9, "a={a}");
        assert!((b - 3.0).abs() < 1e-9, "b={b}");
    }

    #[test]
    fn polynomial_fit_recovers_coeffs() {
        let xs: Vec<f64> = (0..30).map(|i| i as f64 / 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 1.0 - 2.0 * x + 0.5 * x * x).collect();
        let c = fit_polynomial(&xs, &ys, 2);
        assert!((c[0] - 1.0).abs() < 1e-8);
        assert!((c[1] + 2.0).abs() < 1e-8);
        assert!((c[2] - 0.5).abs() < 1e-8);
        let y = eval_polynomial(&c, 2.0);
        assert!((y - (1.0 - 4.0 + 2.0)).abs() < 1e-8);
    }

    #[test]
    fn l1_and_normalize() {
        assert_eq!(l1_distance(&[1.0, 2.0], &[0.0, 4.0]), 3.0);
        let p = normalize(&[2.0, 2.0, 4.0]);
        assert_eq!(p, vec![0.25, 0.25, 0.5]);
        let u = normalize(&[0.0, 0.0]);
        assert_eq!(u, vec![0.5, 0.5]);
    }
}
