//! Minimal JSON substrate (serde is unavailable offline).
//!
//! Provides a [`Value`] tree, a recursive-descent parser, a serializer with
//! optional pretty printing, and ergonomic accessors used by the config
//! loaders and report emitters. Numbers are stored as `f64` (adequate for
//! configuration and metrics payloads; integers up to 2^53 round-trip).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Error produced by the parser, with a byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(input: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- constructors ----
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Value) -> &mut Self {
        match self {
            Value::Obj(map) => {
                map.insert(key.to_string(), val);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers used by config loaders.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }

    // ---- serialization ----
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() {
            match self.bytes[self.pos] {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, value: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let code = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let low = self.hex4()?;
                                let combined = 0x10000
                                    + ((code - 0xD800) << 10)
                                    + (low.wrapping_sub(0xDC00));
                                char::from_u32(combined)
                                    .ok_or_else(|| self.err("invalid surrogate pair"))?
                            } else {
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("invalid unicode escape"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Decode one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated unicode escape"));
        }
        let text = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid utf8 in escape"))?;
        let code =
            u32::from_str_radix(text, 16).map_err(|_| self.err("invalid hex in escape"))?;
        self.pos += 4;
        Ok(code)
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("3.25").unwrap(), Value::Num(3.25));
        assert_eq!(Value::parse("-17").unwrap(), Value::Num(-17.0));
        assert_eq!(Value::parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(
            Value::parse("\"hi\\nthere\"").unwrap(),
            Value::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Value::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("\"unterminated").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Value::parse("\"\\u0041\\u00e9\"").unwrap(),
            Value::Str("Aé".into())
        );
        // Surrogate pair for 😀 (U+1F600).
        assert_eq!(
            Value::parse("\"\\ud83d\\ude00\"").unwrap(),
            Value::Str("😀".into())
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"name":"mixtral","experts":8,"probs":[0.75,0.25],"gqa":true}"#;
        let v = Value::parse(src).unwrap();
        let compact = v.to_string_compact();
        let v2 = Value::parse(&compact).unwrap();
        assert_eq!(v, v2);
        let pretty = v.to_string_pretty();
        let v3 = Value::parse(&pretty).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn builder_and_accessors() {
        let mut v = Value::obj();
        v.set("x", Value::Num(4.0))
            .set("s", Value::Str("hi".into()))
            .set("a", Value::from_f64_slice(&[1.0, 2.0]));
        assert_eq!(v.req_f64("x").unwrap(), 4.0);
        assert_eq!(v.req_usize("x").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(v.req_f64("missing").is_err());
        assert!(Value::Num(1.5).as_usize().is_none());
    }
}
