//! ASCII table formatting for bench reports (the paper's tables/figures are
//! regenerated as aligned text tables and CSV blocks).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder: set headers, push rows, render aligned text.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override alignment per column (defaults to right-aligned).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match header width"
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from displayable values.
    pub fn row_display<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let strings: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&strings)
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for (i, w) in widths.iter().enumerate() {
                out.push_str(if i == 0 { "+" } else { "+" });
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        let emit_row = |out: &mut String, cells: &[String], aligns: &[Align]| {
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                out.push_str("| ");
                match aligns[i] {
                    Align::Left => {
                        out.push_str(cell);
                        out.push_str(&" ".repeat(pad));
                    }
                    Align::Right => {
                        out.push_str(&" ".repeat(pad));
                        out.push_str(cell);
                    }
                }
                out.push(' ');
            }
            out.push_str("|\n");
        };
        sep(&mut out);
        emit_row(&mut out, &self.headers, &vec![Align::Left; ncols]);
        sep(&mut out);
        for row in &self.rows {
            emit_row(&mut out, row, &self.aligns);
        }
        sep(&mut out);
        out
    }

    /// Render as CSV (headers + rows), for machine-readable bench output.
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        let esc = |cell: &str| {
            if cell.contains(',') || cell.contains('"') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format a ratio as a percentage string.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(&["dataset", "skew", "err%"]).align(&[
            Align::Left,
            Align::Right,
            Align::Right,
        ]);
        t.row(&["MMLU".into(), "1.39".into(), "1.80".into()]);
        t.row(&["SST2".into(), "1.99".into(), "16.00".into()]);
        let s = t.render();
        assert!(s.contains("| dataset |"));
        assert!(s.contains("|    16.00 |") || s.contains("| 16.00 |"));
        // All lines equal width.
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["name", "note"]);
        t.row(&["x,y".into(), "say \"hi\"".into()]);
        let csv = t.render_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.235), "23.5%");
    }
}
