//! Dependency-free utility substrates.
//!
//! The offline build environment ships only the `xla` and `anyhow` crates, so
//! the usual ecosystem crates (rand, serde, clap, …) are re-implemented here
//! at the scale this project needs. Each submodule is unit-tested in place.

pub mod args;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod tablefmt;

/// Format a byte count as a human-readable string (KiB/MiB/GiB).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{:.0} {}", v, UNITS[unit])
    } else {
        format!("{:.2} {}", v, UNITS[unit])
    }
}

/// Format a duration in seconds with an auto-selected unit (ns/µs/ms/s).
pub fn human_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if abs < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if abs < 1.0 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.3} s", seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
        assert_eq!(human_bytes(1024f64.powi(3)), "1.00 GiB");
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(5e-9), "5.0 ns");
        assert_eq!(human_time(1.5e-5), "15.00 µs");
        assert_eq!(human_time(0.25), "250.000 ms");
        assert_eq!(human_time(2.0), "2.000 s");
    }
}
