//! Expert→GPU placement state.

use std::collections::BTreeSet;

/// Placement `P ⊆ experts × gpus` with the constraints Algorithm 1 enforces:
/// a per-GPU expert-slot capacity `M_g` (memory, in units of experts) and a
/// per-expert maximum copy count `C_max`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    n_experts: usize,
    n_gpus: usize,
    /// pairs (expert, gpu), kept sorted for deterministic iteration.
    pairs: BTreeSet<(usize, usize)>,
    /// Per-GPU expert-slot capacity.
    capacity: Vec<usize>,
    /// Maximum copies of any single expert.
    max_copies: usize,
}

impl Placement {
    /// The canonical initial placement: expert `e` on GPU `e * G / E`
    /// (round-robin block assignment, experts evenly spread).
    pub fn initial(n_experts: usize, n_gpus: usize, capacity_per_gpu: usize, max_copies: usize) -> Placement {
        assert!(n_experts >= 1 && n_gpus >= 1);
        assert!(
            capacity_per_gpu * n_gpus >= n_experts,
            "capacity too small to host all experts"
        );
        let mut pairs = BTreeSet::new();
        for e in 0..n_experts {
            let g = e * n_gpus / n_experts;
            pairs.insert((e, g));
        }
        Placement {
            n_experts,
            n_gpus,
            pairs,
            capacity: vec![capacity_per_gpu; n_gpus],
            max_copies,
        }
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn n_gpus(&self) -> usize {
        self.n_gpus
    }

    pub fn max_copies(&self) -> usize {
        self.max_copies
    }

    pub fn hosts(&self, expert: usize, gpu: usize) -> bool {
        self.pairs.contains(&(expert, gpu))
    }

    /// GPUs hosting an expert (sorted).
    pub fn gpus_of(&self, expert: usize) -> Vec<usize> {
        self.pairs
            .iter()
            .filter(|(e, _)| *e == expert)
            .map(|&(_, g)| g)
            .collect()
    }

    /// Experts hosted on a GPU (sorted).
    pub fn experts_on(&self, gpu: usize) -> Vec<usize> {
        self.pairs
            .iter()
            .filter(|(_, g)| *g == gpu)
            .map(|&(e, _)| e)
            .collect()
    }

    pub fn copies(&self, expert: usize) -> usize {
        self.pairs.iter().filter(|(e, _)| *e == expert).count()
    }

    pub fn used_slots(&self, gpu: usize) -> usize {
        self.pairs.iter().filter(|(_, g)| *g == gpu).count()
    }

    pub fn capacity(&self, gpu: usize) -> usize {
        self.capacity[gpu]
    }

    /// Whether the Algorithm-1 guard admits `(expert, gpu)`.
    pub fn can_add(&self, expert: usize, gpu: usize) -> bool {
        !self.hosts(expert, gpu)
            && self.copies(expert) < self.max_copies
            && self.used_slots(gpu) < self.capacity[gpu]
    }

    /// Add a replica; returns false (and leaves state unchanged) if the
    /// guard rejects it.
    pub fn add(&mut self, expert: usize, gpu: usize) -> bool {
        if !self.can_add(expert, gpu) {
            return false;
        }
        self.pairs.insert((expert, gpu));
        true
    }

    /// Drop replicas not in `keep`, never dropping the last copy of an
    /// expert (used between batches to reclaim slots).
    pub fn retain_with(&mut self, keep: &BTreeSet<(usize, usize)>) {
        let pairs: Vec<(usize, usize)> = self.pairs.iter().cloned().collect();
        for pair in pairs {
            if !keep.contains(&pair) && self.copies(pair.0) > 1 {
                self.pairs.remove(&pair);
            }
        }
    }

    /// All (expert, gpu) pairs, sorted.
    pub fn pairs(&self) -> impl Iterator<Item = &(usize, usize)> {
        self.pairs.iter()
    }

    /// Replicas added in `after` relative to `self` (what must be moved
    /// over the interconnect).
    pub fn added_replicas(&self, after: &Placement) -> Vec<(usize, usize)> {
        after
            .pairs
            .iter()
            .filter(|p| !self.pairs.contains(p))
            .cloned()
            .collect()
    }

    /// Remove a failed GPU from the host set (ADR 008): its capacity
    /// drops to zero so no rebalance ever places a replica there again,
    /// its pairs are dropped, and any expert it was the *sole* host of is
    /// re-homed onto the least-loaded surviving GPU (lowest index on
    /// ties) so the every-expert-hosted invariant survives the death.
    /// Returns the re-homed `(expert, gpu)` pairs — those replicas are
    /// cold on their new host and upload on first use.
    pub fn fail_gpu(&mut self, gpu: usize) -> Vec<(usize, usize)> {
        if gpu >= self.n_gpus {
            return Vec::new();
        }
        self.capacity[gpu] = 0;
        let dropped: Vec<(usize, usize)> = self
            .pairs
            .iter()
            .filter(|&&(_, g)| g == gpu)
            .copied()
            .collect();
        for pair in &dropped {
            self.pairs.remove(pair);
        }
        let mut rehomed = Vec::new();
        for &(e, _) in &dropped {
            if self.copies(e) > 0 {
                continue;
            }
            let target = (0..self.n_gpus)
                .filter(|&g| self.used_slots(g) < self.capacity[g])
                .min_by_key(|&g| (self.used_slots(g), g));
            if let Some(g) = target {
                self.pairs.insert((e, g));
                rehomed.push((e, g));
            }
        }
        rehomed
    }

    /// Every expert has ≥1 replica and every GPU is within capacity —
    /// the invariant property tests assert.
    pub fn check_invariants(&self) -> Result<(), String> {
        for e in 0..self.n_experts {
            let c = self.copies(e);
            if c == 0 {
                return Err(format!("expert {e} has no replica"));
            }
            if c > self.max_copies {
                return Err(format!("expert {e} has {c} > C_max copies"));
            }
        }
        for g in 0..self.n_gpus {
            if self.used_slots(g) > self.capacity[g] {
                return Err(format!(
                    "gpu {g} over capacity: {} > {}",
                    self.used_slots(g),
                    self.capacity[g]
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_spreads_experts() {
        let p = Placement::initial(8, 4, 4, 4);
        for g in 0..4 {
            assert_eq!(p.experts_on(g).len(), 2);
        }
        assert!(p.hosts(0, 0));
        assert!(p.hosts(7, 3));
        p.check_invariants().unwrap();
    }

    #[test]
    fn initial_more_gpus_than_experts() {
        let p = Placement::initial(2, 4, 1, 4);
        assert_eq!(p.copies(0), 1);
        assert_eq!(p.copies(1), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn add_respects_guards() {
        let mut p = Placement::initial(8, 4, 2, 2);
        // GPU 0 already hosts 2 experts at capacity 2 → reject.
        assert!(!p.add(5, 0));
        // Duplicate to a GPU with room after raising capacity.
        let mut p = Placement::initial(8, 4, 3, 2);
        assert!(p.add(0, 1));
        assert_eq!(p.copies(0), 2);
        // Copy limit.
        assert!(!p.add(0, 2), "C_max=2 reached");
        // Already hosted.
        assert!(!p.add(0, 1));
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "capacity too small")]
    fn capacity_must_fit_all_experts() {
        Placement::initial(8, 2, 3, 4);
    }

    #[test]
    fn added_replicas_diff() {
        let before = Placement::initial(8, 4, 3, 2);
        let mut after = before.clone();
        after.add(0, 1);
        after.add(3, 0);
        let moved = before.added_replicas(&after);
        assert_eq!(moved, vec![(0, 1), (3, 0)]);
    }

    #[test]
    fn fail_gpu_rehomes_sole_hosted_experts() {
        // experts 0,1 on gpu 0; 2,3 on 1; 4,5 on 2; 6,7 on 3.
        let mut p = Placement::initial(8, 4, 4, 4);
        p.add(0, 1); // expert 0 gains a replica elsewhere
        let rehomed = p.fail_gpu(0);
        assert_eq!(p.capacity(0), 0);
        assert!(p.experts_on(0).is_empty());
        // Expert 0 survived on its replica; expert 1 was sole-hosted and
        // must be re-homed onto a survivor.
        assert_eq!(p.copies(0), 1);
        assert!(p.hosts(0, 1));
        assert_eq!(rehomed.len(), 1);
        assert_eq!(rehomed[0].0, 1);
        assert!(rehomed[0].1 != 0);
        p.check_invariants().unwrap();
        // No rebalance can place on the dead gpu again.
        assert!(!p.can_add(5, 0));
    }

    #[test]
    fn fail_gpu_is_idempotent_and_bounds_checked() {
        let mut p = Placement::initial(4, 2, 4, 2);
        let first = p.fail_gpu(1);
        assert!(!first.is_empty());
        assert!(p.fail_gpu(1).is_empty(), "second failure is a no-op");
        assert!(p.fail_gpu(99).is_empty(), "out of range tolerated");
        p.check_invariants().unwrap();
    }

    #[test]
    fn retain_never_drops_last_copy() {
        let mut p = Placement::initial(4, 4, 2, 2);
        p.add(0, 1);
        let keep = BTreeSet::new(); // ask to drop everything
        p.retain_with(&keep);
        for e in 0..4 {
            assert_eq!(p.copies(e), 1, "expert {e}");
        }
    }
}
