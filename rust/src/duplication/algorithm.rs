//! Algorithm 1 — Expert Duplication in MoE Load Balancing (paper §3.1).
//!
//! ```text
//! Input:  token-expert map f, per-GPU capacities M, initial placement P,
//!         max copies per expert C_max
//! Output: balanced placement P and dispatch d: tokens → GPUs
//! 1  d(t) ← min{ g | (f(t), g) ∈ P }
//! 2  L_g ← |{t | d(t) = g}|
//! 3  while max L − min L > 1:
//! 4      g_h ← argmax L;  g_c ← argmin L
//! 5      Δ ← ⌈(L_h − L_c) / 2⌉
//! 6      e* ← the expert with the most tokens dispatched to g_h
//! 7      if (e*, g_c) ∉ P and copies(e*) < C_max and params(e*) ≤ M_gc:
//! 8          P ← P ∪ {(e*, g_c)}
//! 9      reassign the first Δ tokens of e* on g_h to g_c
//! 10     update L
//! ```
//!
//! Implementation notes (guards the paper's pseudocode leaves implicit):
//! * line 9 is only valid when `(e*, g_c) ∈ P` after line 7/8 — if the
//!   guard rejected the new replica, moving tokens there would route them
//!   to a GPU without the expert. We skip the move in that case and try the
//!   next-hottest (expert, cold-GPU) combination; if no combination admits
//!   progress, we terminate (capacity/copy limits bound achievable balance).
//! * Δ is additionally capped by the number of tokens of `e*` on `g_h`.
//! * Tokens are tracked as counts per (expert, gpu) — "the first Δ tokens"
//!   only needs cardinality for balance; `dispatch` materialises per-token
//!   assignments.

use super::placement::Placement;

/// Result of a balancing run.
#[derive(Clone, Debug)]
pub struct BalanceResult {
    pub placement: Placement,
    /// Tokens of expert `e` dispatched to gpu `g`: `share[e][g]`.
    pub share: Vec<Vec<usize>>,
    /// Per-GPU loads after balancing.
    pub loads: Vec<usize>,
    /// Iterations of the while loop executed.
    pub iterations: usize,
    /// True if the loop reached `max − min ≤ 1`; false if it stopped on a
    /// capacity/copy-limit wall.
    pub converged: bool,
}

impl BalanceResult {
    pub fn max_load(&self) -> usize {
        self.loads.iter().copied().max().unwrap_or(0)
    }
    pub fn min_load(&self) -> usize {
        self.loads.iter().copied().min().unwrap_or(0)
    }
    /// Post-balancing skewness: max load / average load.
    pub fn skewness(&self) -> f64 {
        crate::util::stats::skewness_of_counts(&self.loads)
    }
}

/// Run Algorithm 1 on per-expert token counts.
///
/// `expert_tokens[e]` is the number of tokens routed to expert `e`
/// (predicted or actual — the algorithm is agnostic, which is exactly why
/// both prediction strategies can drive it).
pub fn balance(expert_tokens: &[usize], initial: &Placement) -> BalanceResult {
    let n_experts = initial.n_experts();
    let n_gpus = initial.n_gpus();
    assert_eq!(expert_tokens.len(), n_experts);

    let mut placement = initial.clone();
    // share[e][g]: tokens of expert e dispatched to gpu g.
    let mut share = vec![vec![0usize; n_gpus]; n_experts];
    // Line 1: initial dispatch to the lowest-indexed hosting GPU.
    for (e, &count) in expert_tokens.iter().enumerate() {
        let g = *placement
            .gpus_of(e)
            .first()
            .expect("placement must host every expert");
        share[e][g] = count;
    }
    let mut loads = compute_loads(&share, n_gpus);

    let mut iterations = 0;
    // The loop must terminate: each useful iteration strictly reduces
    // max−min; `max_iters` is a safety net for adversarial capacity walls.
    let max_iters = 4 * (n_experts + n_gpus) * (n_gpus + 1);
    let mut converged = false;

    while iterations < max_iters {
        let (g_h, g_c) = hot_cold(&loads);
        if loads[g_h] - loads[g_c] <= 1 {
            converged = true;
            break;
        }
        iterations += 1;
        let delta_target = (loads[g_h] - loads[g_c]).div_ceil(2);

        // Line 6: hottest expert on g_h (by tokens dispatched there);
        // fall back to the next-hottest if the hottest cannot progress.
        let mut experts_by_share: Vec<usize> = (0..n_experts)
            .filter(|&e| share[e][g_h] > 0)
            .collect();
        experts_by_share.sort_by_key(|&e| std::cmp::Reverse(share[e][g_h]));

        let mut moved = false;
        for &e_star in &experts_by_share {
            // Line 7/8: duplicate if the guards admit it.
            if !placement.hosts(e_star, g_c) {
                placement.add(e_star, g_c); // no-op if guards reject
            }
            if placement.hosts(e_star, g_c) {
                // Line 9: move up to Δ tokens of e* from g_h to g_c.
                let delta = delta_target.min(share[e_star][g_h]);
                if delta > 0 {
                    share[e_star][g_h] -= delta;
                    share[e_star][g_c] += delta;
                    loads[g_h] -= delta;
                    loads[g_c] += delta;
                    moved = true;
                    break;
                }
            }
        }

        if !moved {
            // Try moving to any under-average GPU, not just the argmin.
            let avg = loads.iter().sum::<usize>() as f64 / n_gpus as f64;
            let mut cold_gpus: Vec<usize> = (0..n_gpus)
                .filter(|&g| (loads[g] as f64) < avg && g != g_h)
                .collect();
            cold_gpus.sort_by_key(|&g| loads[g]);
            'outer: for &g_c2 in &cold_gpus {
                for &e_star in &experts_by_share {
                    if !placement.hosts(e_star, g_c2) {
                        placement.add(e_star, g_c2);
                    }
                    if placement.hosts(e_star, g_c2) && loads[g_h] > loads[g_c2] + 1 {
                        let delta = ((loads[g_h] - loads[g_c2]).div_ceil(2))
                            .min(share[e_star][g_h]);
                        if delta > 0 {
                            share[e_star][g_h] -= delta;
                            share[e_star][g_c2] += delta;
                            loads[g_h] -= delta;
                            loads[g_c2] += delta;
                            moved = true;
                            break 'outer;
                        }
                    }
                }
            }
        }

        if !moved {
            break; // capacity / copy-limit wall: no further progress possible
        }
    }

    if !converged {
        let (g_h, g_c) = hot_cold(&loads);
        converged = loads[g_h] - loads[g_c] <= 1;
    }

    BalanceResult {
        placement,
        share,
        loads,
        iterations,
        converged,
    }
}

/// Fractional balancing for Distribution-Only prediction: only the aggregate
/// shares `p[e]` are known, so the planner splits *expected* load across
/// replicas. Returns per-(expert,gpu) fractional shares summing to 1.
///
/// Greedy water-filling: process experts by descending share; give each GPU
/// at most `1/G` total. Mirrors §3.1's "keep duplicating experts on GPUs
/// with > 1/N tokens to GPUs with < 1/N tokens".
pub fn balance_fractional(probs: &[f64], initial: &Placement) -> (Placement, Vec<Vec<f64>>) {
    let n_experts = initial.n_experts();
    let n_gpus = initial.n_gpus();
    assert_eq!(probs.len(), n_experts);
    let mut placement = initial.clone();
    let mut share = vec![vec![0.0f64; n_gpus]; n_experts];
    let mut loads = vec![0.0f64; n_gpus];
    let cap = 1.0 / n_gpus as f64 + 1e-12;

    let mut order: Vec<usize> = (0..n_experts).collect();
    order.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]));

    for &e in &order {
        let mut remaining = probs[e];
        // Fill the home GPUs first, then duplicate to the least-loaded.
        let mut hosts = placement.gpus_of(e);
        hosts.sort_by(|&a, &b| loads[a].total_cmp(&loads[b]));
        for g in hosts {
            if remaining <= 0.0 {
                break;
            }
            let take = remaining.min((cap - loads[g]).max(0.0));
            share[e][g] += take;
            loads[g] += take;
            remaining -= take;
        }
        while remaining > 1e-12 {
            // Need a new replica on the least-loaded GPU with room.
            let mut candidates: Vec<usize> = (0..n_gpus)
                .filter(|&g| loads[g] < cap && !placement.hosts(e, g))
                .collect();
            candidates.sort_by(|&a, &b| loads[a].total_cmp(&loads[b]));
            let mut placed = false;
            for g in candidates {
                if placement.add(e, g) {
                    let take = remaining.min(cap - loads[g]);
                    share[e][g] += take;
                    loads[g] += take;
                    remaining -= take;
                    placed = true;
                    break;
                }
            }
            if !placed {
                // Guards exhausted: dump the remainder on the least-loaded
                // hosting GPU (imbalance persists — mirrors the real wall).
                let g = placement
                    .gpus_of(e)
                    .into_iter()
                    .min_by(|&a, &b| loads[a].total_cmp(&loads[b]))
                    .unwrap();
                share[e][g] += remaining;
                loads[g] += remaining;
                remaining = 0.0;
            }
        }
    }
    (placement, share)
}

fn hot_cold(loads: &[usize]) -> (usize, usize) {
    let mut g_h = 0;
    let mut g_c = 0;
    for g in 1..loads.len() {
        if loads[g] > loads[g_h] {
            g_h = g;
        }
        if loads[g] < loads[g_c] {
            g_c = g;
        }
    }
    (g_h, g_c)
}

fn compute_loads(share: &[Vec<usize>], n_gpus: usize) -> Vec<usize> {
    let mut loads = vec![0usize; n_gpus];
    for per_gpu in share {
        for (g, &c) in per_gpu.iter().enumerate() {
            loads[g] += c;
        }
    }
    loads
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total(share: &[Vec<usize>]) -> usize {
        share.iter().flat_map(|row| row.iter()).sum()
    }

    #[test]
    fn paper_figure2_example_balances() {
        // 4 experts, 4 GPUs; expert 0 has 75% of 1024 tokens (skew 3).
        let tokens = [768usize, 96, 80, 80];
        let initial = Placement::initial(4, 4, 4, 4);
        let r = balance(&tokens, &initial);
        assert!(r.converged);
        assert!(r.max_load() - r.min_load() <= 1);
        assert_eq!(total(&r.share), 1024);
        assert!(r.skewness() < 1.01, "skew={}", r.skewness());
        // Expert 0 must have been duplicated.
        assert!(r.placement.copies(0) >= 3);
        r.placement.check_invariants().unwrap();
    }

    #[test]
    fn balanced_input_is_noop() {
        let tokens = [128usize, 128, 128, 128, 128, 128, 128, 128];
        let initial = Placement::initial(8, 4, 4, 4);
        let r = balance(&tokens, &initial);
        assert!(r.converged);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.placement, initial, "no duplication needed");
    }

    #[test]
    fn copy_limit_bounds_balance() {
        // One expert holds everything but C_max=1: no duplication possible,
        // algorithm must terminate without converging.
        let tokens = [1000usize, 0, 0, 0];
        let initial = Placement::initial(4, 4, 4, 1);
        let r = balance(&tokens, &initial);
        assert!(!r.converged);
        assert_eq!(r.max_load(), 1000);
        r.placement.check_invariants().unwrap();
    }

    #[test]
    fn capacity_wall_respected() {
        // Capacity 2/GPU with 8 experts: every GPU is full, no replicas fit.
        let tokens = [800usize, 50, 50, 20, 20, 20, 20, 20];
        let initial = Placement::initial(8, 4, 2, 4);
        let r = balance(&tokens, &initial);
        r.placement.check_invariants().unwrap();
        for g in 0..4 {
            assert!(r.placement.used_slots(g) <= 2);
        }
    }

    #[test]
    fn token_conservation_random_cases() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let n_experts = rng.range(2, 17);
            let n_gpus = rng.range(2, 9);
            let cap = (n_experts.div_ceil(n_gpus)) + rng.range(0, 3);
            let tokens: Vec<usize> = (0..n_experts).map(|_| rng.range(0, 500)).collect();
            let initial = Placement::initial(n_experts, n_gpus, cap, n_gpus);
            let sum: usize = tokens.iter().sum();
            let r = balance(&tokens, &initial);
            assert_eq!(total(&r.share), sum, "token conservation");
            assert_eq!(r.loads.iter().sum::<usize>(), sum);
            r.placement.check_invariants().unwrap();
            // Balance must never be worse than the starting dispatch.
            let start_max = {
                let mut loads = vec![0usize; n_gpus];
                for (e, &c) in tokens.iter().enumerate() {
                    let g = *initial.gpus_of(e).first().unwrap();
                    loads[g] += c;
                }
                *loads.iter().max().unwrap()
            };
            assert!(r.max_load() <= start_max);
        }
    }

    #[test]
    fn fractional_balances_dop_distribution() {
        // Skewed distribution, generous capacity → near-perfect balance.
        let probs = [0.75, 0.05, 0.05, 0.05, 0.025, 0.025, 0.025, 0.025];
        let initial = Placement::initial(8, 4, 8, 4);
        let (placement, share) = balance_fractional(&probs, &initial);
        placement.check_invariants().unwrap();
        let mut loads = vec![0.0; 4];
        for e in 0..8 {
            for g in 0..4 {
                loads[g] += share[e][g];
            }
        }
        let sum: f64 = loads.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let max = loads.iter().cloned().fold(0.0, f64::max);
        assert!(max <= 0.25 + 1e-6, "max load {max}");
    }

    #[test]
    fn fractional_respects_copy_limits() {
        let probs = [0.97, 0.01, 0.01, 0.01];
        let initial = Placement::initial(4, 4, 4, 2); // expert 0 limited to 2 copies
        let (placement, share) = balance_fractional(&probs, &initial);
        placement.check_invariants().unwrap();
        assert!(placement.copies(0) <= 2);
        // With only 2 copies of a 97% expert, the best max-load is 0.485.
        let mut loads = vec![0.0; 4];
        for e in 0..4 {
            for g in 0..4 {
                loads[g] += share[e][g];
            }
        }
        let max = loads.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.4, "copy limit must keep imbalance, max={max}");
    }
}
