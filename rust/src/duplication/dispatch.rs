//! Token→GPU dispatch under a placement.
//!
//! Materialises the `d : tokens → GPUs` map of Algorithm 1: given each
//! token's expert and the (possibly duplicated) placement, assign every
//! token to a hosting GPU, least-loaded first. Used by the serving
//! coordinator on the hot path.

use super::placement::Placement;

/// Assign each token (by its expert id) to a GPU hosting that expert,
/// balancing load greedily (least-loaded compatible GPU, ties broken by
/// GPU index for determinism). Returns (assignment, per-GPU loads).
pub fn dispatch_tokens(experts: &[u8], placement: &Placement) -> (Vec<u32>, Vec<usize>) {
    let n_gpus = placement.n_gpus();
    let mut loads = vec![0usize; n_gpus];
    let mut out = Vec::with_capacity(experts.len());
    // Pre-compute host lists per expert (placement queries are O(E·G)).
    let hosts: Vec<Vec<usize>> = (0..placement.n_experts())
        .map(|e| placement.gpus_of(e))
        .collect();
    for &e in experts {
        let candidates = &hosts[e as usize];
        debug_assert!(!candidates.is_empty(), "expert {e} unplaced");
        let g = *candidates
            .iter()
            .min_by_key(|&&g| (loads[g], g))
            .expect("expert must have at least one host");
        loads[g] += 1;
        out.push(g as u32);
    }
    (out, loads)
}

/// Dispatch with per-(expert,gpu) quotas from Algorithm 1's share matrix:
/// tokens of expert `e` fill `share[e][g]` slots in GPU order, overflowing
/// to the least-loaded host if quotas were under-provisioned (prediction
/// error at serving time).
pub fn dispatch_with_quota(
    experts: &[u8],
    placement: &Placement,
    share: &[Vec<usize>],
) -> (Vec<u32>, Vec<usize>) {
    let n_gpus = placement.n_gpus();
    let mut remaining: Vec<Vec<usize>> = share.to_vec();
    let mut loads = vec![0usize; n_gpus];
    let mut out = Vec::with_capacity(experts.len());
    let hosts: Vec<Vec<usize>> = (0..placement.n_experts())
        .map(|e| placement.gpus_of(e))
        .collect();
    for &e in experts {
        let ei = e as usize;
        // Prefer a GPU with remaining quota for this expert.
        let quota_gpu = (0..n_gpus)
            .filter(|&g| remaining[ei][g] > 0 && placement.hosts(ei, g))
            .min_by_key(|&g| (loads[g], g));
        let g = match quota_gpu {
            Some(g) => {
                remaining[ei][g] -= 1;
                g
            }
            None => *hosts[ei]
                .iter()
                .min_by_key(|&&g| (loads[g], g))
                .expect("expert must have at least one host"),
        };
        loads[g] += 1;
        out.push(g as u32);
    }
    (out, loads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;
    use crate::util::stats;

    #[test]
    fn dispatch_only_to_hosting_gpus() {
        let placement = Placement::initial(8, 4, 4, 4);
        let experts: Vec<u8> = (0..64).map(|i| (i % 8) as u8).collect();
        let (assign, loads) = dispatch_tokens(&experts, &placement);
        for (tok, &g) in assign.iter().enumerate() {
            assert!(placement.hosts(experts[tok] as usize, g as usize));
        }
        assert_eq!(loads.iter().sum::<usize>(), 64);
    }

    #[test]
    fn duplication_reduces_dispatch_skew() {
        // Hot expert 0: without duplication GPU 0 takes it all.
        let mut experts = vec![0u8; 96];
        experts.extend([1, 2, 3, 4, 5, 6, 7].iter().flat_map(|&e| vec![e; 4]));
        let single = Placement::initial(8, 4, 4, 1);
        let (_, loads1) = dispatch_tokens(&experts, &single);
        let skew1 = stats::skewness_of_counts(&loads1);

        let mut dup = Placement::initial(8, 4, 4, 4);
        dup.add(0, 1);
        dup.add(0, 2);
        dup.add(0, 3);
        let (_, loads2) = dispatch_tokens(&experts, &dup);
        let skew2 = stats::skewness_of_counts(&loads2);
        assert!(skew2 < skew1 * 0.5, "skew {skew1} -> {skew2}");
    }

    #[test]
    fn quota_dispatch_follows_shares_then_overflows() {
        let mut placement = Placement::initial(4, 4, 4, 4);
        placement.add(0, 1);
        // Quota: expert 0 split 3 on gpu0 / 3 on gpu1 — but 8 tokens arrive.
        let mut share = vec![vec![0usize; 4]; 4];
        share[0][0] = 3;
        share[0][1] = 3;
        let experts = vec![0u8; 8];
        let (assign, loads) = dispatch_with_quota(&experts, &placement, &share);
        assert_eq!(loads[0] + loads[1], 8);
        // First six follow quota evenly, overflow least-loaded.
        assert!((loads[0] as i64 - loads[1] as i64).abs() <= 2);
        for &g in &assign {
            assert!(g == 0 || g == 1);
        }
    }

    #[test]
    fn property_dispatch_conserves_and_respects_placement() {
        testing::forall_config(
            testing::Config {
                cases: 64,
                ..Default::default()
            },
            |rng: &mut Rng| {
                let n_experts = rng.range(2, 12);
                let n_gpus = rng.range(2, 6);
                let cap = n_experts.div_ceil(n_gpus) + rng.range(0, 3);
                let mut placement =
                    Placement::initial(n_experts, n_gpus, cap, n_gpus);
                // Random extra replicas.
                for _ in 0..rng.range(0, 6) {
                    let e = rng.range(0, n_experts);
                    let g = rng.range(0, n_gpus);
                    placement.add(e, g);
                }
                let experts: Vec<u8> = (0..rng.range(1, 400))
                    .map(|_| rng.range(0, n_experts) as u8)
                    .collect();
                (placement, experts)
            },
            |(placement, experts)| {
                let (assign, loads) = dispatch_tokens(experts, placement);
                if assign.len() != experts.len() {
                    return Err("length mismatch".into());
                }
                if loads.iter().sum::<usize>() != experts.len() {
                    return Err("token loss".into());
                }
                for (tok, &g) in assign.iter().enumerate() {
                    if !placement.hosts(experts[tok] as usize, g as usize) {
                        return Err(format!(
                            "token {tok} sent to gpu {g} without expert {}",
                            experts[tok]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
