//! Expert-movement cost analysis (paper §5, "Expert duplication's
//! communication overhead").
//!
//! The paper's back-of-envelope: a Mixtral 8×7B fp16 expert is
//! `4096 × 14336 × 2 × 2` bytes; sending one expert per GPU per layer over
//! NVLink 3.0 (2 TB/s) takes ~0.1 ms, which hides under the attention
//! compute at batch 1 / seq 512. Over PCIe 4.0 (32 GB/s) it needs larger
//! workloads (e.g. batch 16 / seq 2K) to hide.

use crate::model::ModelConfig;
use crate::sim::attention;
use crate::sim::hardware::SystemSpec;

/// Movement-cost report for one duplication round.
#[derive(Clone, Debug)]
pub struct MovementReport {
    pub expert_bytes: f64,
    pub transfer_s: f64,
    pub attention_compute_s: f64,
    /// Movement time exceeding the attention window (0 = fully hidden).
    pub exposed_s: f64,
    pub hidden: bool,
}

/// Analyse whether moving `experts_moved` experts per GPU hides under the
/// attention phase of a `batch × seq` workload.
pub fn movement_report(
    model: &ModelConfig,
    system: &SystemSpec,
    batch: usize,
    seq: usize,
    experts_moved: usize,
) -> MovementReport {
    let expert_bytes = model.expert_bytes();
    let transfer_s = experts_moved as f64
        * crate::sim::collective::p2p_time(&system.interconnect, expert_bytes);
    let attn = attention::attention_cost(model, system, batch, seq);
    let exposed = (transfer_s - attn.compute()).max(0.0);
    MovementReport {
        expert_bytes,
        transfer_s,
        attention_compute_s: attn.compute(),
        exposed_s: exposed,
        hidden: exposed <= 0.0,
    }
}

/// Smallest batch size (at the given seq) where movement hides fully —
/// the §5 claim is that PCIe hides at "batch 16, seq 2K"-ish workloads.
pub fn min_hiding_batch(
    model: &ModelConfig,
    system: &SystemSpec,
    seq: usize,
    experts_moved: usize,
    max_batch: usize,
) -> Option<usize> {
    (1..=max_batch).find(|&b| movement_report(model, system, b, seq, experts_moved).hidden)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvlink_movement_negligible_at_paper_workload() {
        // Paper §5: one expert over NVLink (2 TB/s striped) ≈ 0.1 ms for
        // the 2-matrix accounting; our full 3-matrix SwiGLU expert is
        // ~0.18 ms. The paper hides this entirely under its (conservative,
        // no-FlashAttention) attention estimate; our leaner roofline
        // attention leaves a small exposure — assert it is negligible
        // (<15%) relative to the baseline layer latency, which is the
        // claim that matters for Figure 6.
        let m = ModelConfig::mixtral_8x7b();
        let sys = SystemSpec::four_a100_nvlink();
        let r = movement_report(&m, &sys, 1, 512, 1);
        assert!(r.transfer_s < 0.5e-3, "transfer={}", r.transfer_s);
        let layer = crate::sim::LayerSim::new(m, sys).baseline_total(1.4);
        assert!(
            r.exposed_s < 0.15 * layer,
            "exposed={} layer={layer}",
            r.exposed_s
        );
    }

    #[test]
    fn pcie_exposed_at_small_workload_hidden_at_larger() {
        let m = ModelConfig::mixtral_8x7b();
        let sys = SystemSpec::four_a100_pcie();
        let small = movement_report(&m, &sys, 1, 512, 1);
        assert!(!small.hidden, "PCIe should NOT hide at bs=1/seq=512");
        assert!(small.exposed_s > 0.5 * small.transfer_s);
        // Paper §5: hides with "modest increases in batch size or sequence
        // length (e.g. batch 16, seq 2K)". Their attention estimate is
        // conservative (no FlashAttention); with our leaner roofline the
        // crossover lands at a somewhat larger batch — assert it exists
        // and is still a modest workload.
        let min_b = min_hiding_batch(&m, &sys, 2048, 1, 128).unwrap();
        assert!(min_b <= 64, "min hiding batch {min_b}");
        let big = movement_report(&m, &sys, min_b, 2048, 1);
        assert!(big.hidden);
    }

    #[test]
    fn transfer_scales_with_experts_moved() {
        let m = ModelConfig::mixtral_8x7b();
        let sys = SystemSpec::four_a100_nvlink();
        let one = movement_report(&m, &sys, 1, 512, 1);
        let four = movement_report(&m, &sys, 1, 512, 4);
        assert!((four.transfer_s / one.transfer_s - 4.0).abs() < 0.01);
    }
}
