//! Dynamic expert duplication (paper §3.1, Algorithm 1).
//!
//! Given a token→expert map (actual or predicted) and a current expert
//! placement, duplicate popular experts onto under-loaded GPUs and dispatch
//! tokens so per-GPU loads equalise. Three pieces:
//!
//! * [`placement`] — the expert→GPU placement state (replicas, per-GPU
//!   capacity, copy limits);
//! * [`algorithm`] — Algorithm 1 itself (iterative hot→cold shifting),
//!   plus a fractional variant for Distribution-Only prediction where only
//!   aggregate shares are known;
//! * [`dispatch`] — token→GPU assignment under a placement;
//! * [`cost`] — the §5 movement-cost arithmetic (can duplication hide under
//!   attention?).

pub mod algorithm;
pub mod cost;
pub mod dispatch;
pub mod placement;

pub use algorithm::{balance, BalanceResult};
pub use placement::Placement;
