//! Property-based testing substrate (proptest is unavailable offline).
//!
//! A deliberately small proptest-like runner: generators are closures over
//! the project PRNG, properties return `Result<(), String>`, and on failure
//! the runner attempts a bounded shrink using a caller-provided shrinker
//! before panicking with the minimal counterexample it found.
//!
//! Used by the L3 invariant tests (duplication, dispatch, routing, batching,
//! skewness bounds) per the DESIGN.md §7 testing strategy.

use crate::util::rng::Rng;

/// Number of random cases per property (overridable per call).
pub const DEFAULT_CASES: usize = 256;

/// A generator produces a value from the PRNG.
pub trait Generator<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Generator<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: DEFAULT_CASES,
            seed: 0x0E06_F5A7,
            max_shrink_steps: 512,
        }
    }
}

/// Run a property over random inputs with no shrinking.
///
/// Panics with the seed + case index + failure message on the first failure.
pub fn forall<T: std::fmt::Debug>(
    gen: impl Generator<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    forall_config(Config::default(), gen, prop)
}

/// Run a property with explicit configuration.
pub fn forall_config<T: std::fmt::Debug>(
    config: Config,
    gen: impl Generator<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={}, case={}): {}\ninput: {:#?}",
                config.seed, case, msg, input
            );
        }
    }
}

/// Run a property with shrinking: `shrink(value)` returns candidate smaller
/// values; the runner greedily descends to a local minimum that still fails.
pub fn forall_shrink<T: Clone + std::fmt::Debug>(
    config: Config,
    gen: impl Generator<T>,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let input = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for candidate in shrink(&best) {
                    steps += 1;
                    if steps >= config.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(msg) = prop(&candidate) {
                        best = candidate;
                        best_msg = msg;
                        continue 'outer;
                    }
                }
                break; // no shrink candidate fails → local minimum
            }
            panic!(
                "property failed (seed={}, case={}, shrunk over {} steps): {}\nminimal input: {:#?}",
                config.seed, case, steps, best_msg, best
            );
        }
    }
}

// ---- common generators ----

/// Vec of usize in [0, max) with length in [min_len, max_len].
pub fn vec_usize(
    min_len: usize,
    max_len: usize,
    max: usize,
) -> impl Fn(&mut Rng) -> Vec<usize> {
    move |rng: &mut Rng| {
        let len = rng.range(min_len, max_len + 1);
        (0..len).map(|_| rng.range(0, max)).collect()
    }
}

/// Probability vector of fixed length from a Dirichlet(alpha).
pub fn prob_vec(len: usize, alpha: f64) -> impl Fn(&mut Rng) -> Vec<f64> {
    move |rng: &mut Rng| rng.dirichlet(&vec![alpha; len])
}

/// Shrinker for vectors: tries removing halves and individual elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut smaller = v.to_vec();
            smaller.remove(i);
            out.push(smaller);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(vec_usize(0, 32, 100), |v| {
            if v.iter().all(|&x| x < 100) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(vec_usize(1, 8, 10), |_| Err("always fails".into()));
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: no vector contains the value 3 AND has length > 2.
        // Generator frequently produces violations; the shrinker should
        // reduce to something small. We capture the panic and inspect it.
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                Config {
                    cases: 64,
                    seed: 99,
                    max_shrink_steps: 256,
                },
                vec_usize(0, 64, 5),
                |v| shrink_vec(v),
                |v| {
                    if v.len() > 2 && v.contains(&3) {
                        Err(format!("bad vec of len {}", v.len()))
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // Shrunk counterexample should mention a small length (3 is minimal).
        assert!(msg.contains("bad vec of len 3"), "got: {msg}");
    }

    #[test]
    fn prob_vec_generator_is_normalised() {
        forall(prob_vec(8, 0.5), |p| {
            let sum: f64 = p.iter().sum();
            if (sum - 1.0).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("sum={sum}"))
            }
        });
    }
}
