//! Virtual-GPU workers: one thread per simulated device, each owning its
//! own PJRT engine (the `xla` client is not `Send`) with the expert-FFN
//! executables compiled locally. Expert weights become device-resident on
//! first use — that upload is exactly the duplication transfer Algorithm 1
//! triggers, and is accounted per worker. The lookahead pipeline
//! (`coordinator/pipeline.rs`) instead pre-warms replica weights with
//! [`WorkerMsg::Prewarm`] while the leader runs attention, so the transfer
//! is hidden rather than stalling the FFN phase; the coordinator-side view
//! of what each worker holds is the capacity-bounded LRU in
//! [`super::residency::ResidencyManager`] (ADR 004), which both gates
//! duplicate prewarm sends and emits the [`WorkerMsg::Evict`] messages
//! that keep each engine inside its `--memory-cap` budget.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::faults::{FaultAction, WorkerFaults};
use crate::runtime::{Engine, EngineSource, HostTensor, In};

/// Work sent to a worker.
pub enum WorkerMsg {
    /// Run one expert's FFN over a padded token tile.
    Run {
        tag: u64,
        layer: usize,
        expert: usize,
        /// Padded to a compiled bucket; first `n_real` rows are real.
        xn: HostTensor,
        n_real: usize,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Run one sequence's attention block for a layer (the serving
    /// analogue of Tensor-Parallel attention: sequences of a round spread
    /// across the virtual GPUs — §Perf iteration 2).
    Attention {
        tag: u64,
        layer: usize,
        x: HostTensor,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Pre-warm an expert's weights ahead of the FFN phase — the
    /// duplication transfer the paper hides under attention. The ack is
    /// non-blocking: the coordinator keeps working and settles acks when
    /// the layer's FFN phase actually needs the weights (ADR 002).
    Prewarm {
        tag: u64,
        layer: usize,
        expert: usize,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Evict an expert's weights and free the engine-side residency (LRU
    /// capacity eviction or placement shrink — ADR 004). Workers process
    /// their queue in FIFO order, so an eviction enqueued before a later
    /// `Run`/`Prewarm` of the same expert is applied first and the replica
    /// re-uploads cold (the refetch the coordinator accounts).
    Evict { layer: usize, expert: usize },
    /// Install a fault-injection script (ADR 008). Sent before any work
    /// when `--inject-faults` / `MOE_GPS_FAULTS` is active; never sent
    /// otherwise, so uninjected runs take the exact pre-ADR-008 path.
    Faults(WorkerFaults),
    Shutdown,
}

/// Worker reply.
pub struct WorkerResult {
    pub tag: u64,
    pub worker: usize,
    pub layer: usize,
    pub expert: usize,
    /// FFN output rows (only the first `n_real` are meaningful); empty for
    /// prefetch replies.
    pub out: Vec<f32>,
    /// The input tile's buffer, returned so the coordinator's
    /// [`crate::coordinator::tile_pool::TilePool`] can recycle it (the
    /// zero-alloc dispatch path, ADR 003). Empty for non-Run replies.
    pub tile: Vec<f32>,
    pub n_real: usize,
    /// Wall time the worker spent executing (busy time).
    pub exec_s: f64,
    /// Weight bytes uploaded for this message (duplication transfer).
    pub upload_bytes: u64,
    pub error: Option<String>,
}

/// Handle owned by the coordinator.
pub struct WorkerHandle {
    pub index: usize,
    sender: mpsc::Sender<WorkerMsg>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn a worker over an engine source (artifacts directory or the
    /// synthetic weight set — the synthetic store is shared via `Arc`, so
    /// per-worker construction is cheap).
    pub fn spawn(index: usize, source: EngineSource) -> Result<WorkerHandle> {
        let (sender, receiver) = mpsc::channel::<WorkerMsg>();
        let join = std::thread::Builder::new()
            .name(format!("vgpu-{index}"))
            .spawn(move || worker_main(index, &source, receiver))?;
        Ok(WorkerHandle {
            index,
            sender,
            join: Some(join),
        })
    }

    pub fn send(&self, msg: WorkerMsg) {
        // A dead worker surfaces as a reply-deadline timeout in the
        // pipeline's collectors (ADR 008), which mark it dead in the
        // WorkerHealth registry and redispatch; sends to it are dropped.
        let _ = self.sender.send(msg);
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(WorkerMsg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn expert_weight_names(layer: usize, expert: usize) -> [String; 3] {
    [
        format!("layers.{layer}.experts.{expert}.w_gate"),
        format!("layers.{layer}.experts.{expert}.w_up"),
        format!("layers.{layer}.experts.{expert}.w_down"),
    ]
}

fn worker_main(index: usize, source: &EngineSource, rx: mpsc::Receiver<WorkerMsg>) {
    let mut engine = match Engine::from_source(source) {
        Ok(e) => e,
        Err(err) => {
            crate::util::logging::log(
                crate::util::logging::Level::Error,
                "coordinator::worker",
                format_args!("vgpu-{index}: engine init failed: {err:#}"),
            );
            // Drain messages, replying with errors, until shutdown.
            for msg in rx {
                match msg {
                    WorkerMsg::Run { tag, layer, expert, xn, n_real, reply } => {
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert,
                            out: Vec::new(), tile: xn.data, n_real,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Prewarm { tag, layer, expert, reply } => {
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert,
                            out: Vec::new(), tile: Vec::new(), n_real: 0,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Attention { tag, layer, reply, .. } => {
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert: 0,
                            out: Vec::new(), tile: Vec::new(), n_real: 0,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Evict { .. } => {}
                    WorkerMsg::Faults(_) => {}
                    WorkerMsg::Shutdown => break,
                }
            }
            return;
        }
    };
    let buckets = engine.manifest().ffn_buckets();
    let mut faults = WorkerFaults::default();

    for msg in rx {
        // Injected faults (ADR 008) trigger on countable ops — Run /
        // Attention / Prewarm — before the op is processed: a killed
        // worker exits without replying (its queue dies with it), a
        // delayed worker stalls like a straggler, a dropped op is
        // consumed without ever producing a reply.
        if matches!(
            msg,
            WorkerMsg::Run { .. } | WorkerMsg::Attention { .. } | WorkerMsg::Prewarm { .. }
        ) {
            match faults.on_op() {
                Some(FaultAction::Kill) => return,
                Some(FaultAction::Delay(ms)) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                Some(FaultAction::Drop) => continue,
                None => {}
            }
        }
        match msg {
            WorkerMsg::Run {
                tag,
                layer,
                expert,
                xn,
                n_real,
                reply,
            } => {
                let t0 = Instant::now();
                let names = expert_weight_names(layer, expert);
                let mut upload_bytes = 0u64;
                let mut error = None;
                let mut out = Vec::new();
                // Ensure this expert's weights are resident (duplication
                // transfer if they weren't).
                for n in &names {
                    match engine.upload_weight(n) {
                        Ok(b) => upload_bytes += b,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                if error.is_none() {
                    debug_assert!(buckets.contains(&xn.rows()), "xn must be padded");
                    let artifact = format!("expert_ffn_b{}", xn.rows());
                    match engine.call(
                        &artifact,
                        &[In::T(&xn), In::W(&names[0]), In::W(&names[1]), In::W(&names[2])],
                    ) {
                        Ok(mut tensors) => out = tensors.remove(0).data,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert,
                    out,
                    // Hand the input tile's buffer back for pool reuse.
                    tile: xn.data,
                    n_real,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Attention { tag, layer, x, reply } => {
                let t0 = Instant::now();
                let names = [
                    format!("layers.{layer}.attn.ln"),
                    format!("layers.{layer}.attn.wq"),
                    format!("layers.{layer}.attn.wk"),
                    format!("layers.{layer}.attn.wv"),
                    format!("layers.{layer}.attn.wo"),
                ];
                let mut error = None;
                let mut upload_bytes = 0u64;
                for n in &names {
                    match engine.upload_weight(n) {
                        Ok(b) => upload_bytes += b,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let mut out = Vec::new();
                let n_real = x.rows();
                if error.is_none() {
                    match engine.call(
                        "attention",
                        &[
                            In::T(&x),
                            In::W(&names[0]),
                            In::W(&names[1]),
                            In::W(&names[2]),
                            In::W(&names[3]),
                            In::W(&names[4]),
                        ],
                    ) {
                        Ok(mut tensors) => out = tensors.remove(0).data,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert: 0,
                    out,
                    tile: Vec::new(),
                    n_real,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Prewarm { tag, layer, expert, reply } => {
                let t0 = Instant::now();
                let mut upload_bytes = 0u64;
                let mut error = None;
                for n in &expert_weight_names(layer, expert) {
                    match engine.upload_weight(n) {
                        Ok(b) => upload_bytes += b,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert,
                    out: Vec::new(),
                    tile: Vec::new(),
                    n_real: 0,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Evict { layer, expert } => {
                for n in &expert_weight_names(layer, expert) {
                    engine.evict_weight(n);
                }
            }
            WorkerMsg::Faults(f) => faults = f,
            WorkerMsg::Shutdown => break,
        }
    }
}

// `ResidentSets` (the grow-only coordinator-side residency view) lived
// here through ADR 003; it was refactored into the capacity-bounded LRU
// in `super::residency::ResidencyManager` (ADR 004).
