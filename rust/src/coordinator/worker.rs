//! Virtual-GPU workers: one thread per simulated device, each owning its
//! own PJRT engine (the `xla` client is not `Send`) with the expert-FFN
//! executables compiled locally. Expert weights become device-resident on
//! first use — that upload is exactly the duplication transfer Algorithm 1
//! triggers, and is accounted per worker. The lookahead pipeline
//! (`coordinator/pipeline.rs`) instead pre-warms replica weights with
//! [`WorkerMsg::Prewarm`] while the leader runs attention, so the transfer
//! is hidden rather than stalling the FFN phase; the coordinator-side view
//! of what each worker holds is the capacity-bounded LRU in
//! [`super::residency::ResidencyManager`] (ADR 004), which both gates
//! duplicate prewarm sends and emits the [`WorkerMsg::Evict`] messages
//! that keep each engine inside its `--memory-cap` budget.
//!
//! Under the micro-batch wavefront (ADR 010) a worker may hold several
//! in-flight [`WorkerMsg::RunBatch`] slabs at once — one per micro-batch
//! chunk whose FFN work it owns. Nothing here changes: the queue is FIFO,
//! each batch executes and replies independently, and each counts as one
//! op on the fault clock, so an injected fault lands on the same
//! countable op at every wavefront depth.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::faults::{FaultAction, WorkerFaults};
use crate::runtime::{Engine, EngineSource, HostTensor, In};

/// One expert group inside a coalesced [`WorkerMsg::RunBatch`]: a
/// bucket-padded tile living at a row offset of the batch's shared arena
/// slab (ADR 009).
#[derive(Clone, Debug)]
pub struct BatchGroup {
    pub expert: usize,
    /// First slab row of this group's tile.
    pub row_offset: usize,
    /// Tile rows (padded to a compiled FFN bucket).
    pub rows: usize,
    /// Leading rows that carry real tokens; the rest are zero padding.
    pub n_real: usize,
}

/// Work sent to a worker.
pub enum WorkerMsg {
    /// Run every expert-FFN group this worker owns for one layer wave in
    /// a single message (ADR 009): the groups' bucket-padded tiles are
    /// packed back-to-back into one contiguous `TilePool` slab, and the
    /// worker executes them group by group through borrowed slab views —
    /// one channel send + one wakeup per (layer wave, worker) instead of
    /// one per group. The reply returns the slab for recycling and one
    /// output buffer per group.
    RunBatch {
        tag: u64,
        layer: usize,
        /// Arena slab `[total_rows, d]`; group `g` occupies rows
        /// `groups[g].row_offset .. + groups[g].rows`.
        xn: HostTensor,
        groups: Vec<BatchGroup>,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Run one sequence's attention block for a layer (the serving
    /// analogue of Tensor-Parallel attention: sequences of a round spread
    /// across the virtual GPUs — §Perf iteration 2). The hidden batch is
    /// read-shared: every worker of the fan-out sees the same `Arc`'d
    /// buffer instead of a per-worker deep copy (ADR 009).
    Attention {
        tag: u64,
        layer: usize,
        x: Arc<HostTensor>,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Pre-warm an expert's weights ahead of the FFN phase — the
    /// duplication transfer the paper hides under attention. The ack is
    /// non-blocking: the coordinator keeps working and settles acks when
    /// the layer's FFN phase actually needs the weights (ADR 002).
    Prewarm {
        tag: u64,
        layer: usize,
        expert: usize,
        reply: mpsc::Sender<WorkerResult>,
    },
    /// Evict an expert's weights and free the engine-side residency (LRU
    /// capacity eviction or placement shrink — ADR 004). Workers process
    /// their queue in FIFO order, so an eviction enqueued before a later
    /// `RunBatch`/`Prewarm` of the same expert is applied first and the replica
    /// re-uploads cold (the refetch the coordinator accounts).
    Evict { layer: usize, expert: usize },
    /// Install a fault-injection script (ADR 008). Sent before any work
    /// when `--inject-faults` / `MOE_GPS_FAULTS` is active; never sent
    /// otherwise, so uninjected runs take the exact pre-ADR-008 path.
    Faults(WorkerFaults),
    Shutdown,
}

/// Worker reply.
pub struct WorkerResult {
    pub tag: u64,
    pub worker: usize,
    pub layer: usize,
    pub expert: usize,
    /// Attention output rows; empty for prewarm and batch replies.
    pub out: Vec<f32>,
    /// Per-group FFN outputs of a `RunBatch` (group order matches the
    /// batch's `groups`; only each group's first `n_real` rows are
    /// meaningful). The combine stage reads slot rows straight out of
    /// these buffers — no intermediate scatter copy (ADR 009) — then
    /// recycles them through the tile pool. Empty for non-batch replies.
    pub outs: Vec<Vec<f32>>,
    /// The input slab's buffer, returned so the coordinator's
    /// [`crate::coordinator::tile_pool::TilePool`] can recycle it (the
    /// zero-alloc dispatch path, ADR 003 — extended to arena slabs by
    /// ADR 009). Empty for non-batch replies.
    pub tile: Vec<f32>,
    pub n_real: usize,
    /// Wall time the worker spent executing (busy time).
    pub exec_s: f64,
    /// Weight bytes uploaded for this message (duplication transfer).
    pub upload_bytes: u64,
    pub error: Option<String>,
}

/// Handle owned by the coordinator.
pub struct WorkerHandle {
    pub index: usize,
    sender: mpsc::Sender<WorkerMsg>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn a worker over an engine source (artifacts directory or the
    /// synthetic weight set — the synthetic store is shared via `Arc`, so
    /// per-worker construction is cheap).
    pub fn spawn(index: usize, source: EngineSource) -> Result<WorkerHandle> {
        let (sender, receiver) = mpsc::channel::<WorkerMsg>();
        let join = std::thread::Builder::new()
            .name(format!("vgpu-{index}"))
            .spawn(move || worker_main(index, &source, receiver))?;
        Ok(WorkerHandle {
            index,
            sender,
            join: Some(join),
        })
    }

    pub fn send(&self, msg: WorkerMsg) {
        // A dead worker surfaces as a reply-deadline timeout in the
        // pipeline's collectors (ADR 008), which mark it dead in the
        // WorkerHealth registry and redispatch; sends to it are dropped.
        let _ = self.sender.send(msg);
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(WorkerMsg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn expert_weight_names(layer: usize, expert: usize) -> [String; 3] {
    [
        format!("layers.{layer}.experts.{expert}.w_gate"),
        format!("layers.{layer}.experts.{expert}.w_up"),
        format!("layers.{layer}.experts.{expert}.w_down"),
    ]
}

fn worker_main(index: usize, source: &EngineSource, rx: mpsc::Receiver<WorkerMsg>) {
    let mut engine = match Engine::from_source(source) {
        Ok(e) => e,
        Err(err) => {
            crate::util::logging::log(
                crate::util::logging::Level::Error,
                "coordinator::worker",
                format_args!("vgpu-{index}: engine init failed: {err:#}"),
            );
            // Drain messages, replying with errors, until shutdown.
            for msg in rx {
                match msg {
                    WorkerMsg::RunBatch { tag, layer, xn, groups, reply } => {
                        let n_real = groups.iter().map(|g| g.n_real).sum();
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert: 0,
                            out: Vec::new(), outs: Vec::new(),
                            tile: xn.data, n_real,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Prewarm { tag, layer, expert, reply } => {
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert,
                            out: Vec::new(), outs: Vec::new(),
                            tile: Vec::new(), n_real: 0,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Attention { tag, layer, reply, .. } => {
                        let _ = reply.send(WorkerResult {
                            tag, worker: index, layer, expert: 0,
                            out: Vec::new(), outs: Vec::new(),
                            tile: Vec::new(), n_real: 0,
                            exec_s: 0.0, upload_bytes: 0,
                            error: Some("engine init failed".into()),
                        });
                    }
                    WorkerMsg::Evict { .. } => {}
                    WorkerMsg::Faults(_) => {}
                    WorkerMsg::Shutdown => break,
                }
            }
            return;
        }
    };
    let buckets = engine.manifest().ffn_buckets();
    let mut faults = WorkerFaults::default();

    for msg in rx {
        // Injected faults (ADR 008) trigger on countable ops — RunBatch /
        // Attention / Prewarm — before the op is processed: a killed
        // worker exits without replying (its queue dies with it), a
        // delayed worker stalls like a straggler, a dropped op is
        // consumed without ever producing a reply. A coalesced batch
        // counts as ONE op: it is one message, and a fault loses/delays
        // it atomically (ADR 009).
        if matches!(
            msg,
            WorkerMsg::RunBatch { .. } | WorkerMsg::Attention { .. } | WorkerMsg::Prewarm { .. }
        ) {
            match faults.on_op() {
                Some(FaultAction::Kill) => return,
                Some(FaultAction::Delay(ms)) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
                Some(FaultAction::Drop) => continue,
                None => {}
            }
        }
        match msg {
            WorkerMsg::RunBatch {
                tag,
                layer,
                xn,
                groups,
                reply,
            } => {
                let t0 = Instant::now();
                let d = xn.row_len();
                let mut upload_bytes = 0u64;
                let mut error: Option<String> = None;
                let mut outs: Vec<Vec<f32>> = Vec::with_capacity(groups.len());
                for g in &groups {
                    // Ensure this expert's weights are resident
                    // (duplication transfer if they weren't).
                    let names = expert_weight_names(layer, g.expert);
                    for n in &names {
                        match engine.upload_weight(n) {
                            Ok(b) => upload_bytes += b,
                            Err(e) => error = Some(format!("{e:#}")),
                        }
                    }
                    if error.is_some() {
                        break;
                    }
                    debug_assert!(buckets.contains(&g.rows), "group must be bucket-padded");
                    debug_assert!((g.row_offset + g.rows) * d <= xn.data.len());
                    // Borrowed slab view — the group's tile travels and
                    // executes with zero per-group copies (ADR 009).
                    let view = In::View {
                        data: &xn.data[g.row_offset * d..(g.row_offset + g.rows) * d],
                        rows: g.rows,
                        cols: d,
                    };
                    let artifact = format!("expert_ffn_b{}", g.rows);
                    match engine.call(
                        &artifact,
                        &[view, In::W(&names[0]), In::W(&names[1]), In::W(&names[2])],
                    ) {
                        Ok(mut tensors) => outs.push(tensors.remove(0).data),
                        Err(e) => {
                            error = Some(format!("{e:#}"));
                            break;
                        }
                    }
                }
                let n_real = groups.iter().map(|g| g.n_real).sum();
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert: 0,
                    out: Vec::new(),
                    outs,
                    // Hand the input slab's buffer back for pool reuse.
                    tile: xn.data,
                    n_real,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Attention { tag, layer, x, reply } => {
                let t0 = Instant::now();
                let names = [
                    format!("layers.{layer}.attn.ln"),
                    format!("layers.{layer}.attn.wq"),
                    format!("layers.{layer}.attn.wk"),
                    format!("layers.{layer}.attn.wv"),
                    format!("layers.{layer}.attn.wo"),
                ];
                let mut error = None;
                let mut upload_bytes = 0u64;
                for n in &names {
                    match engine.upload_weight(n) {
                        Ok(b) => upload_bytes += b,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let mut out = Vec::new();
                let n_real = x.rows();
                if error.is_none() {
                    match engine.call(
                        "attention",
                        &[
                            // Read-shared fan-out batch (ADR 009): borrow
                            // through the Arc, never copy it.
                            In::T(x.as_ref()),
                            In::W(&names[0]),
                            In::W(&names[1]),
                            In::W(&names[2]),
                            In::W(&names[3]),
                            In::W(&names[4]),
                        ],
                    ) {
                        Ok(mut tensors) => out = tensors.remove(0).data,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert: 0,
                    out,
                    outs: Vec::new(),
                    tile: Vec::new(),
                    n_real,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Prewarm { tag, layer, expert, reply } => {
                let t0 = Instant::now();
                let mut upload_bytes = 0u64;
                let mut error = None;
                for n in &expert_weight_names(layer, expert) {
                    match engine.upload_weight(n) {
                        Ok(b) => upload_bytes += b,
                        Err(e) => error = Some(format!("{e:#}")),
                    }
                }
                let _ = reply.send(WorkerResult {
                    tag,
                    worker: index,
                    layer,
                    expert,
                    out: Vec::new(),
                    outs: Vec::new(),
                    tile: Vec::new(),
                    n_real: 0,
                    exec_s: t0.elapsed().as_secs_f64(),
                    upload_bytes,
                    error,
                });
            }
            WorkerMsg::Evict { layer, expert } => {
                for n in &expert_weight_names(layer, expert) {
                    engine.evict_weight(n);
                }
            }
            WorkerMsg::Faults(f) => faults = f,
            WorkerMsg::Shutdown => break,
        }
    }
}

// `ResidentSets` (the grow-only coordinator-side residency view) lived
// here through ADR 003; it was refactored into the capacity-bounded LRU
// in `super::residency::ResidencyManager` (ADR 004).
