//! The unified layer-pipeline serving engine (ADR 002).
//!
//! Prefill rounds and continuous-batching decode steps run the same
//! per-layer stage sequence; only the attention form differs:
//!
//! ```text
//! embed → [predict → plan] → per layer:
//!     prewarm(L+1) → attention(L) → router(L) →
//!     [settle needed prewarms] → dispatch/ffn(L) → combine(L) → observe(L)
//! ```
//!
//! * `embed` stays with the caller ([`Coordinator::serve_round`] /
//!   `decode_step`), which also owns phase-specific state (KV caches,
//!   sampling).
//! * `predict → plan` is [`Coordinator::build_plans`]: one shared stage
//!   covering all three strategies and the decode replan cadence.
//! * The per-layer loop is [`Coordinator::run_layers`], parameterised by
//!   [`AttentionMode`] (whole-sequence attention vs KV-cache incremental).
//!
//! **Budgeted multi-step lookahead** (`Coordinator::lookahead = N`,
//! ADR 002/004): while layer `L` runs attention on the leader, the
//! already-built plans for layers `L+1 ..= L+N` are pushed to the workers
//! as non-blocking [`WorkerMsg::Prewarm`] messages — nearest layer first,
//! so when the per-layer-step transfer budget
//! (`Coordinator::prewarm_budget_bytes`) runs out it is the *deepest*
//! prewarms that are dropped (they get re-attempted at the next layer
//! step, or upload cold at dispatch). Replica weight uploads therefore
//! stream while the leader computes instead of stalling the FFN phase on
//! first use, and slow interconnects can hide `N > 1` layers deep. The
//! settle point is *selective* ([`Prewarmer::settle_for`]): the FFN phase
//! blocks only on prewarms for the (worker, expert) pairs its dispatch
//! actually routed work to — warming the rest of the placement never
//! barriers the pipeline — and every transferred byte is accounted as
//! *hidden* (ack arrived before any dispatch needed it) or *exposed* (the
//! FFN phase had to block, or the worker uploaded cold inside `RunBatch`) —
//! the split `metrics.rs` reports and `sim/` prices (`lookahead_overlap`).
//! With `parallel_attention` on, prewarms are issued *after* the
//! attention fan-out instead, so transfers queue behind attention work on
//! the shared worker queues rather than ahead of it.
//!
//! **Memory-budgeted residency** (ADR 004): every replica that becomes
//! worker-resident — prewarm issue or cold FFN dispatch — is admitted
//! into the [`super::residency::ResidencyManager`], a per-worker LRU
//! bounded by `--memory-cap`. Admissions over the cap evict the
//! least-recently-used replicas of *unpinned* layers (the active layer
//! and the in-flight prewarm window are pinned) as real
//! [`WorkerMsg::Evict`] messages, and plan shrinks under a cap evict the
//! dropped replicas eagerly at plan time. Evictions move bytes, never
//! values: serving under any cap is bitwise identical to unbounded
//! serving (`tests/residency.rs`), while evictions / refetch bytes / the
//! residency high-water mark flow into `metrics.rs`.
//!
//! **Speculative TEP scatter** (`Coordinator::speculative`, ADR 003 —
//! the full §3.1 contract): with lookahead on and Token-to-Expert
//! predictions in hand, each layer's per-token dispatch targets are
//! derived from predictions + plan alone *during an earlier layer's
//! FFN phase* (no activations needed) — depth-k under ADR 006: the
//! target-build window tracks the prewarm window, so layer `L+k`'s
//! targets can be derived up to `k` FFN waits ahead of their use
//! instead of always exactly one. At the FFN stage, slots whose
//! routed expert confirms the prediction ship immediately — before the
//! dispatcher/LPT machinery runs — so workers compute confirmed tiles
//! while the leader plans the misprediction-*repair* pass for the rest
//! (LPT seeded with the speculative load so repair work avoids the busy
//! hosts).
//!
//! **Zero-copy data plane** (ADR 009, extending the zero-alloc dispatch
//! of ADR 003): the attention fan-out ships one `Arc`'d hidden batch to
//! every worker instead of per-worker deep copies; each layer wave's FFN
//! groups coalesce into a single [`WorkerMsg::RunBatch`] per assigned
//! worker, backed by one contiguous [`super::tile_pool::TilePool`] arena
//! slab with bucket-padded per-group row offsets — O(alive workers)
//! messages per layer, not O(groups); and the combine stage reads each
//! slot's output row straight out of the reply buffers (no intermediate
//! scatter copy). The reply returns the slab and the per-group output
//! buffers for pool recycling, so steady-state serving performs no
//! per-layer tile allocation (`metrics.rs` counts allocs vs reuses plus
//! `bytes_copied`/`bytes_shared`; `tests/zero_alloc_dispatch.rs` and
//! `tests/data_plane.rs` pin the invariants).
//!
//! **Micro-batch wavefront** (`Coordinator::microbatch = K`, ADR 010):
//! with K > 1 each layer's sequence set splits into K deterministic
//! contiguous chunks ([`microbatch_ranges`]) and the layer runs as a
//! wavefront instead of a barrier — while chunk A's FFN slabs are in
//! flight on the workers, the leader routes and dispatches chunk B and
//! drains/combines chunk Z's replies as they land
//! ([`Coordinator::wavefront_layer`]). Chunks are sequence-aligned and
//! combined strictly in chunk order, so per-chunk slot-order accumulation
//! *is* global slot order; repair-pass LPT is seeded with the padded load
//! every earlier chunk already committed per worker. K = 1 takes the
//! serial path below untouched, and every K produces bitwise-identical
//! hidden states (`tests/wavefront.rs`). The leader's blocking reply
//! waits are accounted as `leader_stall_s` and the layer's router→combine
//! wall time as `wavefront_window_s`, from which `worker_idle_frac` is
//! derived.
//!
//! **Determinism contract**: the combine stage accumulates `gate · out`
//! in *global slot order*, reading each slot's row from its batch reply.
//! Each slot's FFN row depends only on its own activation row (the
//! reference backend's matmuls are row-independent, and bucket padding
//! rows are zero), so the final hidden states are bitwise independent of
//! reply arrival order, dispatch grouping, batching, prediction strategy,
//! lookahead, speculation, and micro-batch depth — the property
//! `tests/pipeline_parity.rs` and `tests/wavefront.rs` pin down.

use std::collections::{BTreeMap, HashSet};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::faults::{all_workers_dead_err, sequence_fault_err, WorkerHealth, MAX_TIMEOUT_WAITS};
use super::metrics::{DecodeStepMetrics, RoundMetrics};
use super::placement_mgr::LayerPlan;
use super::residency::ResidencyManager;
use super::router::{expert_counts, route_sequence, Slot};
use super::server::{Coordinator, SeqSession, ServeStrategy, StepSeq};
use super::worker::{BatchGroup, WorkerHandle, WorkerMsg, WorkerResult};
use crate::duplication::dispatch::{dispatch_tokens, dispatch_with_quota};
use crate::duplication::Placement;
use crate::runtime::bucket::split_into_buckets;
use crate::runtime::{HostTensor, In};
use crate::util::stats;

/// §Perf iteration 1: groups smaller than this fold into the same
/// expert's largest group (a runt split costs a whole padded-bucket FFN
/// call for negligible balance gain).
pub const MIN_GROUP: usize = 16;

/// Timings and counters the per-layer loop produces, independent of the
/// serving phase; the caller copies them into [`RoundMetrics`] or
/// [`DecodeStepMetrics`].
#[derive(Clone, Debug)]
pub struct StageMetrics {
    pub attention_s: f64,
    pub router_s: f64,
    pub ffn_wall_s: f64,
    pub n_slots: usize,
    pub worker_busy_s: Vec<f64>,
    pub worker_slots: Vec<usize>,
    /// Total duplication-transfer bytes (= hidden + exposed).
    pub upload_bytes: u64,
    /// Bytes whose transfer completed under the lookahead window.
    pub hidden_upload_bytes: u64,
    /// Bytes transferred on the critical path (blocked-on prewarms plus
    /// cold uploads inside `WorkerMsg::RunBatch`).
    pub exposed_upload_bytes: u64,
    /// Worker seconds spent on overlapped transfers.
    pub hidden_transfer_s: f64,
    /// Leader wall seconds stalled waiting on transfers.
    pub exposed_transfer_s: f64,
    /// Mean per-layer routing skewness.
    pub routing_skew: f64,
    /// Tile buffers freshly heap-allocated on the dispatch path (ADR 003).
    pub tile_allocs: u64,
    /// Tile buffers recycled from the coordinator's tile pool.
    pub tile_reuses: u64,
    /// Slots dispatched on the speculative fast path (predicted expert
    /// confirmed by the router).
    pub spec_dispatch_slots: usize,
    /// Slots that took the misprediction-repair pass.
    pub spec_repair_slots: usize,
    /// Replica weights evicted under the memory cap (LRU + plan shrink).
    pub evictions: u64,
    /// Bytes re-uploaded for previously evicted replicas (the transfer
    /// the cap forced back onto the wire — ADR 004).
    pub refetch_upload_bytes: u64,
    /// Peak per-worker resident replica bytes seen so far (max, not sum).
    pub resident_high_water_bytes: u64,
    /// Routed slots that carried a per-token prediction (TEP) — the
    /// top-k hit rate's denominator (ADR 005).
    pub pred_slots: usize,
    /// Tokens that carried a prediction (= pred_slots / routed top_k) —
    /// the top-1 denominator, so the realized argmax accuracy is
    /// comparable with the offline harness's per-token `top1`.
    pub pred_tokens: usize,
    /// Slots whose routed expert appeared anywhere in the predicted
    /// top-k set (the speculative-confirmation rule, measured even when
    /// speculation is off).
    pub pred_topk_hits: usize,
    /// Tokens whose routed expert set contained the predictor argmax
    /// (at most one routed slot per token can match rank 0, so this is
    /// a per-token count).
    pub pred_top1_hits: usize,
    /// Mean per-layer L1 error between the plan's predicted per-expert
    /// shares and the actually routed shares (the Table-1 metric,
    /// measured live — feeds the online calibrator, ADR 005).
    pub pred_share_l1: f64,
    /// Layers that carried predicted counts (0 for NoPrediction).
    pub pred_share_layers: usize,
    /// Workers newly detected dead during this stage (ADR 008).
    pub worker_deaths: u64,
    /// Slots re-sent to a surviving replica after their owner died.
    pub redispatched_slots: usize,
    /// Reply-deadline timeouts waited through (straggler retries).
    pub retry_count: u64,
    /// Prewarm acks abandoned (deadline exhausted or owner died).
    pub prewarm_timeouts: u64,
    /// The stage ran on a degraded fleet (a death occurred, or fewer
    /// workers than configured were alive).
    pub degraded: bool,
    /// Host bytes deep-copied on the coordinator↔worker data plane
    /// (ADR 009): today only the FFN gather that packs routed rows into
    /// arena slabs — the attention fan-out and the combine read-back are
    /// copy-free, so in steady state this is exactly
    /// `n_slots × d_model × 4`.
    pub bytes_copied: u64,
    /// Host bytes moved by reference instead of copied (ADR 009): the
    /// `Arc`-shared hidden batches of the attention fan-out, counted once
    /// per receiving worker (what the pre-ADR-009 plane deep-copied).
    pub bytes_shared: u64,
    /// Coalesced `RunBatch` messages sent (ADR 009): exactly one per
    /// (layer wave, worker with assigned groups) — O(alive workers) per
    /// layer, not O(groups).
    pub ffn_messages: u64,
    /// Leader wall seconds spent blocked in FFN reply waits (ADR 010):
    /// the stall the wavefront overlaps with routing/dispatch of later
    /// micro-batches.
    pub leader_stall_s: f64,
    /// Router→combine wall seconds summed over layers — the window in
    /// which workers *could* be busy; the `worker_idle_frac` denominator.
    pub wavefront_window_s: f64,
    /// Fraction of the layer windows the worker fleet sat idle:
    /// `1 − Σ worker_busy_s / (wavefront_window_s × n_workers)`, clamped
    /// to [0, 1]. Computed in [`StageMetrics::finish`].
    pub worker_idle_frac: f64,
    /// Peak tile-pool buffers outstanding at once (sampled per layer from
    /// [`super::tile_pool::TilePool::take_peak`]) — bounds how far the
    /// wavefront's concurrent in-flight slabs balloon the arena.
    pub tile_peak: u64,
    skews: Vec<f64>,
    share_l1s: Vec<f64>,
}

impl StageMetrics {
    pub fn new(n_workers: usize) -> StageMetrics {
        StageMetrics {
            attention_s: 0.0,
            router_s: 0.0,
            ffn_wall_s: 0.0,
            n_slots: 0,
            worker_busy_s: vec![0.0; n_workers],
            worker_slots: vec![0; n_workers],
            upload_bytes: 0,
            hidden_upload_bytes: 0,
            exposed_upload_bytes: 0,
            hidden_transfer_s: 0.0,
            exposed_transfer_s: 0.0,
            routing_skew: 0.0,
            tile_allocs: 0,
            tile_reuses: 0,
            spec_dispatch_slots: 0,
            spec_repair_slots: 0,
            evictions: 0,
            refetch_upload_bytes: 0,
            resident_high_water_bytes: 0,
            pred_slots: 0,
            pred_tokens: 0,
            pred_topk_hits: 0,
            pred_top1_hits: 0,
            pred_share_l1: 0.0,
            pred_share_layers: 0,
            worker_deaths: 0,
            redispatched_slots: 0,
            retry_count: 0,
            prewarm_timeouts: 0,
            degraded: false,
            bytes_copied: 0,
            bytes_shared: 0,
            ffn_messages: 0,
            leader_stall_s: 0.0,
            wavefront_window_s: 0.0,
            worker_idle_frac: 0.0,
            tile_peak: 0,
            skews: Vec::new(),
            share_l1s: Vec::new(),
        }
    }

    fn finish(&mut self) {
        self.routing_skew = stats::mean(&self.skews);
        self.pred_share_layers = self.share_l1s.len();
        if !self.share_l1s.is_empty() {
            self.pred_share_l1 = stats::mean(&self.share_l1s);
        }
        // Fleet idle fraction over the router→combine windows (ADR 010).
        // Dead workers count as idle capacity on purpose: the configured
        // fleet, not the surviving one, is what the operator provisioned.
        let n_workers = self.worker_busy_s.len();
        if self.wavefront_window_s > 0.0 && n_workers > 0 {
            let busy: f64 = self.worker_busy_s.iter().sum();
            self.worker_idle_frac =
                (1.0 - busy / (self.wavefront_window_s * n_workers as f64)).clamp(0.0, 1.0);
        }
    }

    /// Both metric families share the pipeline's field names; one body
    /// serves both so a new stage metric cannot silently reach only one
    /// report family.
    fn apply_to(
        &self,
        attention_s: &mut f64,
        router_s: &mut f64,
        ffn_wall_s: &mut f64,
        n_slots: &mut usize,
        worker_busy_s: &mut [f64],
        worker_slots: &mut [usize],
        upload_bytes: &mut u64,
        hidden_upload_bytes: &mut u64,
        exposed_upload_bytes: &mut u64,
        hidden_transfer_s: &mut f64,
        exposed_transfer_s: &mut f64,
        routing_skew: &mut f64,
        tile_allocs: &mut u64,
        tile_reuses: &mut u64,
        spec_dispatch_slots: &mut usize,
        spec_repair_slots: &mut usize,
        evictions: &mut u64,
        refetch_upload_bytes: &mut u64,
        resident_high_water_bytes: &mut u64,
        pred_slots: &mut usize,
        pred_tokens: &mut usize,
        pred_topk_hits: &mut usize,
        pred_top1_hits: &mut usize,
        pred_share_l1: &mut f64,
        pred_share_layers: &mut usize,
        worker_deaths: &mut u64,
        redispatched_slots: &mut usize,
        retry_count: &mut u64,
        prewarm_timeouts: &mut u64,
        degraded: &mut bool,
        bytes_copied: &mut u64,
        bytes_shared: &mut u64,
        ffn_messages: &mut u64,
        leader_stall_s: &mut f64,
        wavefront_window_s: &mut f64,
        worker_idle_frac: &mut f64,
        tile_peak: &mut u64,
    ) {
        *attention_s += self.attention_s;
        *router_s += self.router_s;
        *ffn_wall_s += self.ffn_wall_s;
        *n_slots += self.n_slots;
        for (w, &b) in self.worker_busy_s.iter().enumerate() {
            worker_busy_s[w] += b;
        }
        for (w, &s) in self.worker_slots.iter().enumerate() {
            worker_slots[w] += s;
        }
        *upload_bytes += self.upload_bytes;
        *hidden_upload_bytes += self.hidden_upload_bytes;
        *exposed_upload_bytes += self.exposed_upload_bytes;
        *hidden_transfer_s += self.hidden_transfer_s;
        *exposed_transfer_s += self.exposed_transfer_s;
        *routing_skew = self.routing_skew;
        *tile_allocs += self.tile_allocs;
        *tile_reuses += self.tile_reuses;
        *spec_dispatch_slots += self.spec_dispatch_slots;
        *spec_repair_slots += self.spec_repair_slots;
        *evictions += self.evictions;
        *refetch_upload_bytes += self.refetch_upload_bytes;
        // A high-water mark is a peak, not a flow: max-assign.
        *resident_high_water_bytes =
            (*resident_high_water_bytes).max(self.resident_high_water_bytes);
        *pred_slots += self.pred_slots;
        *pred_tokens += self.pred_tokens;
        *pred_topk_hits += self.pred_topk_hits;
        *pred_top1_hits += self.pred_top1_hits;
        // Layer-weighted merge: applying a second stage to the same
        // metrics must not clobber the first stage's share error (the
        // calibrator weights this mean by `pred_share_layers`).
        let total_layers = *pred_share_layers + self.pred_share_layers;
        if total_layers > 0 {
            *pred_share_l1 = (*pred_share_l1 * *pred_share_layers as f64
                + self.pred_share_l1 * self.pred_share_layers as f64)
                / total_layers as f64;
        }
        *pred_share_layers = total_layers;
        *worker_deaths += self.worker_deaths;
        *redispatched_slots += self.redispatched_slots;
        *retry_count += self.retry_count;
        *prewarm_timeouts += self.prewarm_timeouts;
        // Degraded is a latch, not a flow: once any stage of the window
        // ran degraded, the whole window is degraded.
        *degraded |= self.degraded;
        *bytes_copied += self.bytes_copied;
        *bytes_shared += self.bytes_shared;
        *ffn_messages += self.ffn_messages;
        *leader_stall_s += self.leader_stall_s;
        *wavefront_window_s += self.wavefront_window_s;
        // Like routing_skew, the idle fraction is a per-stage ratio, not a
        // flow — but only a stage that actually measured a window may
        // overwrite it (an empty stage would zero a real reading).
        if self.wavefront_window_s > 0.0 {
            *worker_idle_frac = self.worker_idle_frac;
        }
        // A peak, not a flow: max-assign.
        *tile_peak = (*tile_peak).max(self.tile_peak);
    }

    pub fn apply_to_round(&self, m: &mut RoundMetrics) {
        self.apply_to(
            &mut m.attention_s,
            &mut m.router_s,
            &mut m.ffn_wall_s,
            &mut m.n_slots,
            &mut m.worker_busy_s,
            &mut m.worker_slots,
            &mut m.upload_bytes,
            &mut m.hidden_upload_bytes,
            &mut m.exposed_upload_bytes,
            &mut m.hidden_transfer_s,
            &mut m.exposed_transfer_s,
            &mut m.routing_skew,
            &mut m.tile_allocs,
            &mut m.tile_reuses,
            &mut m.spec_dispatch_slots,
            &mut m.spec_repair_slots,
            &mut m.evictions,
            &mut m.refetch_upload_bytes,
            &mut m.resident_high_water_bytes,
            &mut m.pred_slots,
            &mut m.pred_tokens,
            &mut m.pred_topk_hits,
            &mut m.pred_top1_hits,
            &mut m.pred_share_l1,
            &mut m.pred_share_layers,
            &mut m.worker_deaths,
            &mut m.redispatched_slots,
            &mut m.retry_count,
            &mut m.prewarm_timeouts,
            &mut m.degraded,
            &mut m.bytes_copied,
            &mut m.bytes_shared,
            &mut m.ffn_messages,
            &mut m.leader_stall_s,
            &mut m.wavefront_window_s,
            &mut m.worker_idle_frac,
            &mut m.tile_peak,
        );
    }

    pub fn apply_to_step(&self, m: &mut DecodeStepMetrics) {
        self.apply_to(
            &mut m.attention_s,
            &mut m.router_s,
            &mut m.ffn_wall_s,
            &mut m.n_slots,
            &mut m.worker_busy_s,
            &mut m.worker_slots,
            &mut m.upload_bytes,
            &mut m.hidden_upload_bytes,
            &mut m.exposed_upload_bytes,
            &mut m.hidden_transfer_s,
            &mut m.exposed_transfer_s,
            &mut m.routing_skew,
            &mut m.tile_allocs,
            &mut m.tile_reuses,
            &mut m.spec_dispatch_slots,
            &mut m.spec_repair_slots,
            &mut m.evictions,
            &mut m.refetch_upload_bytes,
            &mut m.resident_high_water_bytes,
            &mut m.pred_slots,
            &mut m.pred_tokens,
            &mut m.pred_topk_hits,
            &mut m.pred_top1_hits,
            &mut m.pred_share_l1,
            &mut m.pred_share_layers,
            &mut m.worker_deaths,
            &mut m.redispatched_slots,
            &mut m.retry_count,
            &mut m.prewarm_timeouts,
            &mut m.degraded,
            &mut m.bytes_copied,
            &mut m.bytes_shared,
            &mut m.ffn_messages,
            &mut m.leader_stall_s,
            &mut m.wavefront_window_s,
            &mut m.worker_idle_frac,
            &mut m.tile_peak,
        );
    }
}

/// Output of the shared predict → plan stage.
pub struct PlanStage {
    pub plans: Vec<LayerPlan>,
    /// Prediction time (the TEP predictor forward; 0 for the others).
    pub predictor_s: f64,
    /// Algorithm-1 planning time (was folded into `predictor_s` pre-ADR-002).
    pub plan_s: f64,
    /// Whether plans were rebuilt (always true outside the decode cadence).
    pub replanned: bool,
    pub replicas_added: usize,
    /// Replicas the previous round's plans hosted that these plans no
    /// longer do, per layer — under a memory cap they are evicted eagerly
    /// at plan time (ADR 004); without one the LRU keeps them warm.
    pub replicas_removed: usize,
    /// Ranked per-token top-k expert predictions,
    /// `[layer][seq][token][rank]` (TEP only) — what the speculative
    /// scatter confirms against actual routing. A slot confirms when its
    /// routed expert appears *anywhere* in the token's predicted top-k,
    /// not just the argmax (the ADR-003 follow-up).
    pub predicted_experts: Option<Vec<Vec<Vec<Vec<u8>>>>>,
}

/// How the attention stage runs — the one phase-specific part of the
/// per-layer loop.
pub(crate) enum AttentionMode<'a> {
    /// Whole-sequence attention via the `attention` op (prefill rounds);
    /// `parallel` fans sequences out to the workers (§Perf iteration 2).
    Full { parallel: bool },
    /// KV-cache attention (decode steps): `attention_prefill` seeds the
    /// cache for newly admitted sequences, `attention_step` extends it.
    Cached {
        sessions: &'a mut BTreeMap<u64, SeqSession>,
        workload: &'a [StepSeq],
    },
}

impl Coordinator {
    /// Stage: predict + plan, shared by every serving phase. `decode_step`
    /// engages the replan cadence for Distribution-Only (ADR 001); `None`
    /// (prefill) always replans.
    pub(crate) fn build_plans(
        &mut self,
        hidden: &[HostTensor],
        n_real: &[usize],
        decode_step: Option<usize>,
    ) -> Result<PlanStage> {
        let n_layers = self.dims.n_layers;
        let top_k = self.dims.top_k;
        let t0 = Instant::now();
        let mut predictor_s = 0.0;
        let mut replanned = true;
        let mut predicted_experts = None;
        let plans: Vec<LayerPlan> = match self.strategy {
            ServeStrategy::NoPrediction => {
                replanned = false;
                (0..n_layers).map(|_| self.placement.static_plan()).collect()
            }
            ServeStrategy::DistributionOnly => {
                let total_slots: usize = n_real.iter().map(|&n| n * top_k).sum();
                match decode_step {
                    Some(step) => {
                        replanned = self.placement.replans_at(step);
                        self.placement.decode_plans(step, total_slots)
                    }
                    None => (0..n_layers)
                        .map(|l| self.placement.plan_distribution_only(l, total_slots))
                        .collect(),
                }
            }
            ServeStrategy::TokenToExpert => {
                let tp = Instant::now();
                // The AOT TEP bridge (ADR 005): logits→ranked-top-k via
                // the shared predictor-layer kernel (`coordinator::predict`).
                let (counts, predictions) =
                    self.tep.predict(&mut self.leader, hidden, n_real)?;
                predictor_s = tp.elapsed().as_secs_f64();
                predicted_experts = Some(predictions);
                counts
                    .iter()
                    .map(|c| self.placement.plan_from_counts(c))
                    .collect()
            }
        };
        // Plan-shrink evictions (ADR 004): under a memory cap, replicas
        // the new plans dropped are evicted eagerly — the budget they held
        // frees before this round's prewarms need it. Without a cap the
        // LRU keeps them warm as a cross-request cache instead, and the
        // per-layer placement clone/diff is skipped entirely (uncapped
        // serving stays allocation-free here; `set_memory_cap` resets the
        // diff baseline when a cap is installed mid-run). Pins only live
        // inside `run_layers` — drop any left behind by a previous round
        // that aborted mid-layer, or `remove` would silently skip those
        // layers' shrink evictions.
        self.residency.clear_pins();
        let mut replicas_removed = 0usize;
        if self.residency.cap_bytes().is_some() {
            for (layer, plan) in plans.iter().enumerate() {
                for (expert, gpu) in self.placement.note_plan(layer, &plan.placement) {
                    if self.residency.remove(gpu, layer, expert) {
                        self.workers[gpu].send(WorkerMsg::Evict { layer, expert });
                        replicas_removed += 1;
                    }
                }
            }
        }
        Ok(PlanStage {
            replicas_added: plans.iter().map(|p| p.added.len()).sum(),
            replicas_removed,
            plans,
            predictor_s,
            plan_s: (t0.elapsed().as_secs_f64() - predictor_s).max(0.0),
            replanned,
            predicted_experts,
        })
    }

    /// The unified per-layer pipeline: attention → router → [settle
    /// prewarms] → dispatch/FFN/combine → observe, with next-layer
    /// prewarms issued ahead of attention when lookahead is on.
    pub(crate) fn run_layers(
        &mut self,
        mode: &mut AttentionMode<'_>,
        hidden: &mut [HostTensor],
        n_real: &[usize],
        plans: &[LayerPlan],
        predictions: Option<&[Vec<Vec<Vec<u8>>>]>,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        let n_layers = self.dims.n_layers;
        debug_assert_eq!(plans.len(), n_layers);
        // Residency counters span the whole layer loop (admissions happen
        // on both the prewarm and the dispatch path).
        let evictions0 = self.residency.evictions;
        let refetch_bytes0 = self.residency.refetch_bytes;
        // Speculative TEP scatter (§3.1 full contract, ADR 003): requires
        // per-token predictions (TEP) and the lookahead pipeline. Layer
        // 0's targets are built eagerly; later layers' targets are built
        // during earlier layers' FFN waits (see `ffn_stage`) — depth-k
        // speculation (ADR 006, closing the ADR-003 depth-1 follow-up):
        // the build window tracks the prewarm window (`lookahead` layers
        // deep), so on deep-lookahead configs target derivation for layer
        // L+k amortises over k FFN waits instead of crowding into one.
        // Targets are pure functions of (predictions, plan), so build
        // depth moves scheduling only — never values.
        let speculate = self.speculative && self.lookahead > 0 && predictions.is_some();
        let mut spec_cache: BTreeMap<usize, SpecTargets> = BTreeMap::new();
        if speculate {
            if let Some(p) = predictions {
                spec_cache.insert(0, SpecTargets::build(&p[0], &plans[0]));
            }
        }
        // With worker-offloaded attention the Attention messages share the
        // workers' serial queues: prewarms enqueued first would sit *ahead*
        // of attention work and put the transfer on the attention critical
        // path. Issue prewarms after the attention fan-out in that mode;
        // with leader attention (the default, and all decode steps) the
        // workers are idle during attention, which is exactly the window
        // the transfers should fill.
        let issue_before_attention =
            !matches!(mode, AttentionMode::Full { parallel: true });
        let depth = self.lookahead;
        let mut prewarmer = if depth > 0 { Some(Prewarmer::new()) } else { None };

        for layer in 0..n_layers {
            // Pin the active layer plus the in-flight prewarm window: their
            // replicas are never capacity-eviction victims (ADR 004).
            let window_end = (layer + depth).min(n_layers - 1);
            self.residency.pin_layers(layer..=window_end);

            // Stage: prewarm — fire replica uploads for every layer of the
            // lookahead window so they stream under this layer's
            // leader-side compute. Nearest layer first: when the per-step
            // transfer budget runs out, the deepest prewarms are the ones
            // dropped (re-attempted next layer, or uploaded cold).
            // Already-issued pairs are skipped via the residency view, so
            // in steady state only the window's new frontier transfers.
            if issue_before_attention {
                if let Some(pw) = prewarmer.as_mut() {
                    issue_prewarm_window(
                        pw,
                        &self.workers,
                        &mut self.residency,
                        &self.health,
                        plans,
                        layer..=window_end,
                        self.prewarm_budget_bytes,
                    );
                }
            }

            // Stage: attention.
            let t0 = Instant::now();
            let attn_s = {
                self.attention_stage(mode, layer, hidden, metrics)?;
                t0.elapsed().as_secs_f64()
            };
            metrics.attention_s += attn_s;

            // Parallel-attention mode: prewarm the window only now, so
            // transfers queue behind attention, not ahead.
            if !issue_before_attention {
                if let Some(pw) = prewarmer.as_mut() {
                    issue_prewarm_window(
                        pw,
                        &self.workers,
                        &mut self.residency,
                        &self.health,
                        plans,
                        layer..=window_end,
                        self.prewarm_budget_bytes,
                    );
                }
            }

            // Speculative-window bookkeeping, pulled ahead of routing so
            // the wavefront path can partition each micro-batch the moment
            // it routes. Targets are pure functions of (predictions,
            // plan), so the hoist moves scheduling only — never values.
            let spec_in = spec_cache.remove(&layer);
            let mut spec_built: Vec<(usize, SpecTargets)> = Vec::new();
            // Depth-k build window (ADR 006): derive targets for every
            // not-yet-cached layer of the lookahead window during this
            // layer's FFN wait, nearest first.
            let spec_next: Vec<(usize, &LayerPlan, &[Vec<Vec<u8>>])> = if speculate {
                predictions
                    .map(|p| {
                        (layer + 1..=window_end)
                            .filter(|l| !spec_cache.contains_key(l))
                            .map(|l| (l, &plans[l], p[l].as_slice()))
                            .collect()
                    })
                    .unwrap_or_default()
            } else {
                Vec::new()
            };

            // Stage: router + dispatch + expert FFN + combine. Serial
            // (router barrier, then `ffn_stage`) at `microbatch <= 1` —
            // literally the pre-ADR-010 path — or pipelined as a K-deep
            // micro-batch wavefront (`wavefront_layer`, ADR 010). Both
            // settle only the prewarms their dispatch actually needs, and
            // under speculation confirmed-prediction slots ship first
            // while the next layers' targets derive during the FFN waits.
            let window_t0 = Instant::now();
            let (slots, actual_counts) = if self.microbatch > 1 && hidden.len() > 1 {
                self.wavefront_layer(
                    layer,
                    &plans[layer],
                    hidden,
                    n_real,
                    prewarmer.as_mut(),
                    spec_in,
                    &spec_next,
                    &mut spec_built,
                    metrics,
                )?
            } else {
                let t0 = Instant::now();
                let (normed, slots) = self.router_stage(layer, hidden, n_real)?;
                let actual_counts = expert_counts(&slots, self.dims.n_experts);
                metrics.n_slots += slots.len();
                metrics.router_s += t0.elapsed().as_secs_f64();
                self.ffn_stage(
                    layer,
                    &plans[layer],
                    &slots,
                    &normed,
                    hidden,
                    prewarmer.as_mut(),
                    spec_in,
                    &spec_next,
                    &mut spec_built,
                    metrics,
                )?;
                (slots, actual_counts)
            };
            metrics.wavefront_window_s += window_t0.elapsed().as_secs_f64();
            metrics.tile_peak = metrics.tile_peak.max(self.tiles.take_peak());
            spec_cache.extend(spec_built);
            metrics.skews.push(stats::skewness_of_counts(&actual_counts));

            // Realized prediction quality (ADR 005): now that routing is
            // settled, score the plan's predicted shares (DOP + TEP) and
            // the per-token top-k sets (TEP) against what actually routed.
            // These flow into metrics and feed the online calibrator the
            // strategy controller re-decides from. (Scored after the FFN
            // stage since ADR 010 — pure accounting over the full slot
            // vec, identical values in either position.)
            if !plans[layer].predicted_counts.is_empty() {
                metrics
                    .share_l1s
                    .push(stats::l1_of_counts(&plans[layer].predicted_counts, &actual_counts));
            }
            if let Some(per_layer) = predictions {
                let pl = &per_layer[layer];
                // `slots` is emitted per sequence in token order, so a
                // token's top_k routed slots are contiguous — `last_tok`
                // counts each predicted token once (the top-1
                // denominator; a token's routed experts are distinct, so
                // at most one of its slots matches the argmax).
                let mut last_tok: Option<(usize, usize)> = None;
                for slot in &slots {
                    let Some(ranked) = pl
                        .get(slot.seq_idx)
                        .and_then(|seq| seq.get(slot.token_idx))
                    else {
                        continue;
                    };
                    metrics.pred_slots += 1;
                    if last_tok != Some((slot.seq_idx, slot.token_idx)) {
                        metrics.pred_tokens += 1;
                        last_tok = Some((slot.seq_idx, slot.token_idx));
                    }
                    if ranked.first() == Some(&slot.expert) {
                        metrics.pred_top1_hits += 1;
                    }
                    if ranked.contains(&slot.expert) {
                        metrics.pred_topk_hits += 1;
                    }
                }
            }

            // Stage: observe actual routing (the §3.2.1 moving average
            // keeps teaching the DOP estimators while serving).
            self.placement.observe(layer, &actual_counts);
        }
        // Drain stragglers so every transferred byte is accounted.
        if let Some(pw) = prewarmer.as_mut() {
            pw.finish(&mut self.residency, &self.health, metrics)?;
        }
        // The forward is over: release the pin window so plan-time shrink
        // eviction (and the next round's LRU pressure) can touch any layer,
        // fold the residency counters into the metrics, and advance the
        // tile pool's aging clock one round/step (ADR 004).
        self.residency.clear_pins();
        metrics.evictions += self.residency.evictions - evictions0;
        metrics.refetch_upload_bytes += self.residency.refetch_bytes - refetch_bytes0;
        metrics.resident_high_water_bytes = metrics
            .resident_high_water_bytes
            .max(self.residency.high_water_bytes());
        self.tiles.tick();
        metrics.finish();
        Ok(())
    }

    /// One layer of attention in either mode.
    fn attention_stage(
        &mut self,
        mode: &mut AttentionMode<'_>,
        layer: usize,
        hidden: &mut [HostTensor],
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        let attn_names = attn_weight_names(layer);
        match mode {
            AttentionMode::Full { parallel } => {
                // Sequences spread across the virtual GPUs (§Perf
                // iteration 2); single-sequence rounds stay on the leader
                // to avoid a round-trip.
                if !*parallel || hidden.len() == 1 {
                    for h in hidden.iter_mut() {
                        let out = self
                            .leader
                            .call(
                                "attention",
                                &[
                                    In::T(h),
                                    In::W(&attn_names[0]),
                                    In::W(&attn_names[1]),
                                    In::W(&attn_names[2]),
                                    In::W(&attn_names[3]),
                                    In::W(&attn_names[4]),
                                ],
                            )?
                            .remove(0);
                        *h = out;
                    }
                } else {
                    self.parallel_attention_stage(layer, hidden, metrics)?;
                }
            }
            AttentionMode::Cached { sessions, workload } => {
                // Full-sequence for prefill rows (seeding the KV cache),
                // incremental over the cache for decode rows. Decode
                // attention stays on the leader: single-row matvecs cost
                // less than a worker round-trip (§Perf iteration 2).
                for (i, ws) in workload.iter().enumerate() {
                    // Per-sequence faults (ADR 008): a missing session or
                    // KV cache condemns that sequence, not the whole run —
                    // the sentinel error lets `decode_step`'s caller evict
                    // just the offending sequence.
                    let Some(sess) = sessions.get_mut(&ws.id) else {
                        return Err(sequence_fault_err(ws.id, "session missing"));
                    };
                    if ws.prefill {
                        let mut out = self.leader.call(
                            "attention_prefill",
                            &[
                                In::T(&hidden[i]),
                                In::W(&attn_names[0]),
                                In::W(&attn_names[1]),
                                In::W(&attn_names[2]),
                                In::W(&attn_names[3]),
                                In::W(&attn_names[4]),
                            ],
                        )?;
                        let v = out.remove(2);
                        let k = out.remove(1);
                        hidden[i] = out.remove(0);
                        sess.kv[layer] = Some((k, v));
                    } else {
                        let Some((k_cache, v_cache)) = sess.kv[layer].as_ref() else {
                            return Err(sequence_fault_err(ws.id, "decode KV cache missing"));
                        };
                        let mut out = self.leader.call(
                            "attention_step",
                            &[
                                In::T(&hidden[i]),
                                In::T(k_cache),
                                In::T(v_cache),
                                In::W(&attn_names[0]),
                                In::W(&attn_names[1]),
                                In::W(&attn_names[2]),
                                In::W(&attn_names[3]),
                                In::W(&attn_names[4]),
                            ],
                        )?;
                        let v_new = out.remove(2);
                        let k_new = out.remove(1);
                        hidden[i] = out.remove(0);
                        let Some((k_cache, v_cache)) = sess.kv[layer].as_mut() else {
                            return Err(sequence_fault_err(ws.id, "decode KV cache missing"));
                        };
                        k_cache.append_rows(&k_new);
                        v_cache.append_rows(&v_new);
                    }
                }
            }
        }
        Ok(())
    }

    /// Parallel prefill attention with failover (ADR 008): sequences fan
    /// out round-robin over the *alive* workers, the coordinator holds
    /// its reply sender and collects under an escalating reply deadline.
    /// After `MAX_TIMEOUT_WAITS` consecutive timeouts every worker still
    /// owing a reply is declared dead and its rows are re-sent to
    /// survivors. Attention is a pure function of the row and the shared
    /// weights, so a redispatched row is bitwise identical to the
    /// original — late straggler duplicates are deduplicated per tag
    /// (first reply wins; both carry the same value).
    fn parallel_attention_stage(
        &mut self,
        layer: usize,
        hidden: &mut [HostTensor],
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        let alive: Vec<usize> = (0..self.workers.len())
            .filter(|&w| self.health.is_alive(w))
            .collect();
        if alive.is_empty() {
            return Err(all_workers_dead_err());
        }
        let (attn_tx, attn_rx) = mpsc::channel::<WorkerResult>();
        // Read-shared fan-out (ADR 009): each hidden batch moves into an
        // `Arc` once — every send (including straggler resends) clones the
        // pointer, never the rows. `hidden[i]` holds an allocation-free
        // placeholder until its reply rebuilds it from the worker output.
        let xs: Vec<Arc<HostTensor>> = hidden
            .iter_mut()
            .map(|h| Arc::new(std::mem::replace(h, HostTensor::empty())))
            .collect();
        let mut owner: Vec<usize> = Vec::with_capacity(hidden.len());
        for (seq_idx, x) in xs.iter().enumerate() {
            let worker = alive[seq_idx % alive.len()];
            owner.push(worker);
            metrics.bytes_shared += (x.data.len() * 4) as u64;
            self.workers[worker].send(WorkerMsg::Attention {
                tag: seq_idx as u64,
                layer,
                x: x.clone(),
                reply: attn_tx.clone(),
            });
        }
        // The coordinator keeps `attn_tx` alive: failure detection is
        // reply-deadline-driven, never disconnect-driven (ADR 008).
        let mut done = vec![false; hidden.len()];
        let mut received = 0usize;
        let mut waits = 0u32;
        while received < hidden.len() {
            match attn_rx.recv_timeout(self.health.deadline() * (1u32 << waits)) {
                Ok(r) => {
                    let tag = r.tag as usize;
                    if done[tag] {
                        continue; // straggler duplicate of a redispatched row
                    }
                    if let Some(err) = &r.error {
                        anyhow::bail!("attention on worker {} failed: {err}", r.worker);
                    }
                    done[tag] = true;
                    received += 1;
                    waits = 0;
                    self.health.observe_op(r.exec_s);
                    hidden[tag] = HostTensor::new(r.out, xs[tag].shape.clone());
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    metrics.retry_count += 1;
                    waits += 1;
                    if waits < MAX_TIMEOUT_WAITS {
                        continue;
                    }
                    // Deadline exhausted: every worker still owing a
                    // reply is unresponsive. Declare them dead and
                    // redispatch their rows to survivors.
                    waits = 0;
                    let stale: Vec<usize> =
                        (0..hidden.len()).filter(|&t| !done[t]).collect();
                    let dead: std::collections::BTreeSet<usize> =
                        stale.iter().map(|&t| owner[t]).collect();
                    for w in dead {
                        self.note_worker_death(w, metrics);
                    }
                    let alive: Vec<usize> = (0..self.workers.len())
                        .filter(|&w| self.health.is_alive(w))
                        .collect();
                    if alive.is_empty() {
                        return Err(all_workers_dead_err());
                    }
                    for (i, &tag) in stale.iter().enumerate() {
                        let worker = alive[i % alive.len()];
                        owner[tag] = worker;
                        metrics.redispatched_slots += 1;
                        // The resend shares the same `Arc` — `hidden[tag]`
                        // is still the placeholder until the reply lands.
                        metrics.bytes_shared += (xs[tag].data.len() * 4) as u64;
                        self.workers[worker].send(WorkerMsg::Attention {
                            tag: tag as u64,
                            layer,
                            x: xs[tag].clone(),
                            reply: attn_tx.clone(),
                        });
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("attention worker channel closed");
                }
            }
        }
        Ok(())
    }

    /// One layer of router + top-k: returns the normed activations and
    /// the routed slots (identical for both serving phases).
    fn router_stage(
        &mut self,
        layer: usize,
        hidden: &[HostTensor],
        n_real: &[usize],
    ) -> Result<(Vec<HostTensor>, Vec<Slot>)> {
        let e = self.dims.n_experts;
        let ln = format!("layers.{layer}.moe.ln");
        let wr = format!("layers.{layer}.moe.router");
        let mut normed: Vec<HostTensor> = Vec::with_capacity(hidden.len());
        let mut slots: Vec<Slot> = Vec::new();
        for (seq_idx, h) in hidden.iter().enumerate() {
            let mut out = self
                .leader
                .call("router", &[In::T(h), In::W(&ln), In::W(&wr)])?;
            let logits = out.remove(1);
            let xn = out.remove(0);
            slots.extend(route_sequence(
                seq_idx,
                &logits.data,
                e,
                n_real[seq_idx],
                self.dims.top_k,
            ));
            normed.push(xn);
        }
        Ok((normed, slots))
    }

    /// Coalesce every (worker, expert) group of one dispatch wave into a
    /// single [`WorkerMsg::RunBatch`] per worker (ADR 009): each group's
    /// slots gather into bucket-padded tiles laid back-to-back in one
    /// contiguous pooled arena slab, so the wave costs one channel send
    /// and one worker wakeup per *assigned worker* instead of one per
    /// group. `slot_src[si]` records (tag, group index, row) for every
    /// dispatched slot — the combine stage reads output rows through it,
    /// and a redispatch after a death simply overwrites it.
    #[allow(clippy::too_many_arguments)]
    fn send_ffn_batches(
        &mut self,
        layer: usize,
        groups: &BTreeMap<(usize, usize), Vec<usize>>,
        slots: &[Slot],
        normed: &[HostTensor],
        reply_tx: &mpsc::Sender<WorkerResult>,
        msg_tag: &mut u64,
        slot_src: &mut [(u64, usize, usize)],
        inflight: &mut BTreeMap<u64, (usize, Vec<(usize, Vec<usize>)>)>,
        outstanding: &mut usize,
        metrics: &mut StageMetrics,
    ) {
        let d = self.dims.d_model;
        // Regroup the (worker, expert)-keyed map per worker. BTreeMap
        // iteration keeps expert order deterministic within each batch.
        let mut by_worker: BTreeMap<usize, Vec<(usize, &[usize])>> = BTreeMap::new();
        for ((worker, expert), slot_indices) in groups {
            by_worker
                .entry(*worker)
                .or_default()
                .push((*expert, slot_indices.as_slice()));
        }
        for (worker, expert_groups) in by_worker {
            // Residency (ADR 004): dispatching makes (or keeps) every
            // batched (worker, layer, expert) replica resident — touch the
            // LRU stamps first, and enqueue any capacity evictions before
            // the batch so the FIFO worker frees memory before the cold
            // uploads the batch triggers.
            for &(expert, _) in &expert_groups {
                let admission = self.residency.admit(worker, layer, expert);
                for (victim_layer, victim_expert) in admission.evicted {
                    self.workers[worker].send(WorkerMsg::Evict {
                        layer: victim_layer,
                        expert: victim_expert,
                    });
                }
            }
            // Lay the batch out: oversized groups split across
            // bucket-sized chunks exactly as before coalescing, each chunk
            // becoming one bucket-padded tile at a fixed slab row offset.
            let mut batch_groups: Vec<BatchGroup> = Vec::new();
            let mut meta_groups: Vec<(usize, Vec<usize>)> = Vec::new();
            let mut total_rows = 0usize;
            for &(expert, slot_indices) in &expert_groups {
                let mut offset = 0usize;
                for (chunk, bucket) in split_into_buckets(&self.buckets, slot_indices.len()) {
                    batch_groups.push(BatchGroup {
                        expert,
                        row_offset: total_rows,
                        rows: bucket,
                        n_real: chunk,
                    });
                    meta_groups.push((expert, slot_indices[offset..offset + chunk].to_vec()));
                    total_rows += bucket;
                    offset += chunk;
                }
            }
            *msg_tag += 1;
            let tag = *msg_tag;
            // Gather each group's real rows into the slab, then zero-fill
            // its padding up to the bucket boundary — bitwise identical to
            // per-group fresh tiles (pooled buffers, ADR 003). This gather
            // is the data plane's only remaining deep copy (ADR 009).
            let mut slab = self.tiles.take(total_rows * d);
            for (gi, (bg, (_, chunk_slots))) in
                batch_groups.iter().zip(&meta_groups).enumerate()
            {
                for (row, &si) in chunk_slots.iter().enumerate() {
                    let slot = &slots[si];
                    slab.extend_from_slice(normed[slot.seq_idx].row(slot.token_idx));
                    slot_src[si] = (tag, gi, row);
                }
                slab.resize((bg.row_offset + bg.rows) * d, 0.0);
                metrics.bytes_copied += (bg.n_real * d * 4) as u64;
                metrics.worker_slots[worker] += bg.n_real;
            }
            inflight.insert(tag, (worker, meta_groups));
            metrics.ffn_messages += 1;
            self.workers[worker].send(WorkerMsg::RunBatch {
                tag,
                layer,
                xn: HostTensor::new(slab, vec![total_rows, d]),
                groups: batch_groups,
                reply: reply_tx.clone(),
            });
            *outstanding += 1;
        }
    }

    /// Dispatch routed slots to the virtual-GPU workers under `plan`, run
    /// the expert FFNs, and combine `gate · expert_out` into `hidden` in
    /// global slot order (see the module-level determinism contract).
    ///
    /// With `spec_in` (TEP + lookahead, ADR 003), slots whose routed
    /// expert matches the prediction made before attention ship on a fast
    /// path *before* the dispatcher runs, so workers compute confirmed
    /// tiles while the leader plans the misprediction-repair pass; the
    /// lookahead window's speculative targets (`spec_next` → `spec_out`,
    /// depth-k under ADR 006) are derived during this layer's FFN wait —
    /// pure §3.1: prediction happens ahead of the compute that would
    /// otherwise serialise dispatch.
    fn ffn_stage(
        &mut self,
        layer: usize,
        plan: &LayerPlan,
        slots: &[Slot],
        normed: &[HostTensor],
        hidden: &mut [HostTensor],
        mut prewarmer: Option<&mut Prewarmer>,
        spec_in: Option<SpecTargets>,
        spec_next: &[(usize, &LayerPlan, &[Vec<Vec<u8>>])],
        spec_out: &mut Vec<(usize, SpecTargets)>,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        let d = self.dims.d_model;
        if slots.is_empty() {
            for &(l, plan_next, preds_next) in spec_next {
                spec_out.push((l, SpecTargets::build(preds_next, plan_next)));
            }
            return Ok(());
        }

        let t0 = Instant::now();
        let (alloc0, reuse0) = (self.tiles.allocs, self.tiles.reuses);

        // Partition slots into confirmed speculative hits and the repair
        // set (everything, when speculation is off).
        let mut spec_groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        let mut repair_idx: Vec<usize> = Vec::new();
        match &spec_in {
            Some(targets) => {
                // Top-k-aware confirmation (ADR-003 follow-up): a slot
                // ships speculatively when its routed expert appears
                // anywhere in the token's predicted top-k set, not just
                // the predictor argmax — with k predictions per token, up
                // to all k of a token's routed slots can confirm.
                for (si, slot) in slots.iter().enumerate() {
                    match targets.target_for(
                        slot.seq_idx,
                        slot.token_idx,
                        slot.expert as usize,
                    ) {
                        Some(w) => {
                            spec_groups
                                .entry((w, slot.expert as usize))
                                .or_default()
                                .push(si);
                        }
                        None => repair_idx.push(si),
                    }
                }
                metrics.spec_dispatch_slots += slots.len() - repair_idx.len();
                metrics.spec_repair_slots += repair_idx.len();
            }
            None => repair_idx.extend(0..slots.len()),
        }

        let (reply_tx, reply_rx) = mpsc::channel::<WorkerResult>();
        let mut outstanding = 0usize;
        // Per-slot reply coordinates — `slot_src[si]` = (batch tag, group
        // index within the batch, row within the group) — written at send
        // time and overwritten by redispatch; the combine stage reads each
        // slot's output row through it (ADR 009). `inflight` maps each
        // outstanding batch tag to its worker and per-group slot lists —
        // the failover table the timeout path redispatches from (ADR 008).
        let mut slot_src: Vec<(u64, usize, usize)> = vec![(0, 0, 0); slots.len()];
        let mut inflight: BTreeMap<u64, (usize, Vec<(usize, Vec<usize>)>)> = BTreeMap::new();
        let mut msg_tag = 0u64;

        // Speculative fast path first: settle only these pairs' prewarms
        // and ship the confirmed tiles immediately (one coalesced batch
        // per assigned worker — the wave may be followed by a second,
        // repair-pass batch to the same worker below).
        let spec_groups = self.remap_dead_targets(spec_groups, &plan.placement)?;
        if !spec_groups.is_empty() {
            if let Some(pw) = prewarmer.as_deref_mut() {
                pw.settle_for(layer, &spec_groups, &mut self.residency, &self.health, metrics)?;
            }
            self.send_ffn_batches(
                layer,
                &spec_groups,
                slots,
                normed,
                &reply_tx,
                &mut msg_tag,
                &mut slot_src,
                &mut inflight,
                &mut outstanding,
                metrics,
            );
        }

        // Repair pass (the whole batch when speculation is off): quota
        // dispatch → runt merge → LPT placement, seeded with the padded
        // load the speculative tiles already put on each worker.
        if !repair_idx.is_empty() {
            let experts: Vec<u8> = repair_idx.iter().map(|&si| slots[si].expert).collect();
            let (assignment, _loads) = if plan.share.is_empty() {
                dispatch_tokens(&experts, &plan.placement)
            } else {
                dispatch_with_quota(&experts, &plan.placement, &plan.share)
            };
            let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
            for (pos, &w) in assignment.iter().enumerate() {
                let si = repair_idx[pos];
                groups
                    .entry((w as usize, slots[si].expert as usize))
                    .or_default()
                    .push(si);
            }
            merge_runt_groups(&mut groups, MIN_GROUP);
            let mut seed_load = vec![0usize; self.workers.len()];
            for ((w, _), v) in &spec_groups {
                seed_load[*w] += padded_rows(&self.buckets, v.len());
            }
            let placed =
                lpt_place_seeded(groups, plan, self.workers.len(), &self.buckets, &seed_load);
            let placed = self.remap_dead_targets(placed, &plan.placement)?;

            // Settle the prewarm acks this dispatch depends on (hidden vs
            // exposed); unneeded prewarms keep streaming in the background.
            if let Some(pw) = prewarmer.as_deref_mut() {
                pw.settle_for(layer, &placed, &mut self.residency, &self.health, metrics)?;
            }
            self.send_ffn_batches(
                layer,
                &placed,
                slots,
                normed,
                &reply_tx,
                &mut msg_tag,
                &mut slot_src,
                &mut inflight,
                &mut outstanding,
                metrics,
            );
        }
        // `reply_tx` stays alive for the whole collect loop: failure is
        // detected by reply deadline, never channel disconnect (ADR 008) —
        // the loop counts replies, so the healthy path is unchanged.

        // The workers are now busy with this layer's tiles — exactly the
        // window in which the lookahead window's speculative targets are
        // derivable from predictions + plan alone (no activations needed).
        // Depth-k (ADR 006): nearest layer first; each deeper layer's
        // build amortises over the FFN waits between here and its use.
        for &(l, plan_next, preds_next) in spec_next {
            spec_out.push((l, SpecTargets::build(preds_next, plan_next)));
        }

        // Collect every batch's per-group output buffers (keyed by tag) …
        let mut replies: BTreeMap<u64, Vec<Vec<f32>>> = BTreeMap::new();
        let mut received = 0usize;
        let mut abandoned: HashSet<u64> = HashSet::new();
        let mut waits = 0u32;
        while received < outstanding {
            let t_wait = Instant::now();
            let recv = reply_rx.recv_timeout(self.health.deadline() * (1u32 << waits));
            metrics.leader_stall_s += t_wait.elapsed().as_secs_f64();
            match recv {
                Ok(result) => {
                    // Any progress resets the straggler clock (abandoned
                    // straggler duplicates are recycled, not progress).
                    if self.absorb_ffn_reply(
                        result,
                        &mut abandoned,
                        &mut inflight,
                        &mut replies,
                        &mut received,
                        metrics,
                    )? {
                        waits = 0;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    metrics.retry_count += 1;
                    waits += 1;
                    if waits < MAX_TIMEOUT_WAITS {
                        continue; // straggler grace: back off and re-wait
                    }
                    waits = 0;
                    self.redispatch_stale_batches(
                        layer,
                        plan,
                        slots,
                        normed,
                        &reply_tx,
                        &mut msg_tag,
                        &mut slot_src,
                        &mut inflight,
                        &mut abandoned,
                        &mut outstanding,
                        prewarmer.as_deref_mut(),
                        metrics,
                    )?;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("worker channel closed");
                }
            }
        }
        // … then combine h += gate · out in global slot order, reading
        // each slot's row view straight out of its batch reply (no
        // intermediate scatter buffer, ADR 009) — numerics stay
        // independent of arrival order, grouping and strategy.
        for (si, slot) in slots.iter().enumerate() {
            let (tag, gi, row) = slot_src[si];
            let out = &replies[&tag][gi];
            let out_row = &out[row * d..(row + 1) * d];
            let h = &mut hidden[slot.seq_idx];
            let dst = &mut h.data[slot.token_idx * d..(slot.token_idx + 1) * d];
            for (a, &b) in dst.iter_mut().zip(out_row) {
                *a += slot.gate * b;
            }
        }
        // Zero-alloc recycling: every group's FFN output buffer returns
        // to the pool (the input slabs went back at reply time).
        for (_, outs) in replies {
            for out in outs {
                self.tiles.put(out);
            }
        }
        metrics.tile_allocs += self.tiles.allocs - alloc0;
        metrics.tile_reuses += self.tiles.reuses - reuse0;
        metrics.ffn_wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Absorb one FFN batch reply, shared by the serial collect loop and
    /// both wavefront drains (ADR 010): recycle straggler duplicates of
    /// redispatched batches, account exec time / uploads / health,
    /// recycle the input slab, and stash the output buffers for the
    /// combine. Returns `false` for an abandoned straggler (no progress).
    fn absorb_ffn_reply(
        &mut self,
        mut result: WorkerResult,
        abandoned: &mut HashSet<u64>,
        inflight: &mut BTreeMap<u64, (usize, Vec<(usize, Vec<usize>)>)>,
        replies: &mut BTreeMap<u64, Vec<Vec<f32>>>,
        received: &mut usize,
        metrics: &mut StageMetrics,
    ) -> Result<bool> {
        if abandoned.remove(&result.tag) {
            // Late straggler reply for a redispatched batch: the
            // redispatched copy owns these slots (the values are identical
            // either way) — just recycle the buffers. The slab's loss was
            // already written off (`note_lost`), so it re-enters the pool
            // via plain `put`.
            self.tiles.put(std::mem::take(&mut result.tile));
            for out in result.outs.drain(..) {
                self.tiles.put(out);
            }
            return Ok(false);
        }
        *received += 1;
        if let Some(err) = &result.error {
            anyhow::bail!("worker {} failed: {err}", result.worker);
        }
        self.health.observe_op(result.exec_s);
        metrics.worker_busy_s[result.worker] += result.exec_s;
        // Cold uploads at RunBatch time stall the FFN calls: exposed.
        metrics.upload_bytes += result.upload_bytes;
        metrics.exposed_upload_bytes += result.upload_bytes;
        if let Some((_, meta_groups)) = inflight.remove(&result.tag) {
            debug_assert_eq!(result.outs.len(), meta_groups.len());
            debug_assert_eq!(
                result.n_real,
                meta_groups.iter().map(|(_, v)| v.len()).sum::<usize>()
            );
        }
        // The input slab is done travelling: recycle it now (closing its
        // outstanding window). The output buffers stay alive until the
        // combine reads their rows, then recycle too.
        self.tiles.put_taken(std::mem::take(&mut result.tile));
        replies.insert(result.tag, std::mem::take(&mut result.outs));
        Ok(true)
    }

    /// Reply deadline exhausted with zero progress: every worker still
    /// owing a reply is unresponsive. Declare them dead and redispatch
    /// each lost batch's groups to surviving replicas of their experts —
    /// the duplication plan is the failover table (ADR 008). Shared by
    /// the serial and wavefront collect loops; each redispatched slab is
    /// one countable op on the failover ledger, exactly like the original.
    #[allow(clippy::too_many_arguments)]
    fn redispatch_stale_batches(
        &mut self,
        layer: usize,
        plan: &LayerPlan,
        slots: &[Slot],
        normed: &[HostTensor],
        reply_tx: &mpsc::Sender<WorkerResult>,
        msg_tag: &mut u64,
        slot_src: &mut [(u64, usize, usize)],
        inflight: &mut BTreeMap<u64, (usize, Vec<(usize, Vec<usize>)>)>,
        abandoned: &mut HashSet<u64>,
        outstanding: &mut usize,
        mut prewarmer: Option<&mut Prewarmer>,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        let stale: Vec<u64> = inflight.keys().copied().collect();
        let dead: std::collections::BTreeSet<usize> =
            inflight.values().map(|&(w, _)| w).collect();
        for w in dead {
            self.note_worker_death(w, metrics);
            if let Some(pw) = prewarmer.as_deref_mut() {
                metrics.prewarm_timeouts += pw.purge_worker(w) as u64;
            }
        }
        for tag in stale {
            // The slab shipped to the dead worker died with its thread;
            // redispatch re-gathers from `normed` into fresh pooled slabs
            // (one per failover target), overwriting the slots' `slot_src`.
            abandoned.insert(tag);
            let (_, meta_groups) = inflight.remove(&tag).expect("stale tag is inflight");
            *outstanding -= 1;
            self.tiles.note_lost();
            let mut regrouped: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
            for (expert, slot_indices) in meta_groups {
                metrics.redispatched_slots += slot_indices.len();
                let target = self.failover_for(&plan.placement, expert)?;
                regrouped
                    .entry((target, expert))
                    .or_default()
                    .extend(slot_indices);
            }
            self.send_ffn_batches(
                layer,
                &regrouped,
                slots,
                normed,
                reply_tx,
                msg_tag,
                slot_src,
                inflight,
                outstanding,
                metrics,
            );
        }
        Ok(())
    }

    /// One layer served as a K-deep micro-batch wavefront (ADR 010).
    ///
    /// The round's sequences split into up to `self.microbatch`
    /// deterministic contiguous chunks ([`microbatch_ranges`]); for each
    /// chunk the leader routes, partitions into speculative-confirm vs
    /// repair exactly like the serial path, settles the prewarms that
    /// chunk's dispatch needs, and ships its slabs — then drains any
    /// replies that already landed *without blocking* and combines every
    /// complete prefix chunk. While a chunk's FFN slabs are in flight the
    /// leader is routing the next chunk: the router/combine work that was
    /// a per-layer barrier now overlaps worker compute. The repair pass's
    /// LPT is seeded with the padded rows all earlier dispatches of the
    /// layer committed per worker, and the final blocking collect keeps
    /// the serial path's escalating-deadline failover (ADR 008) verbatim.
    ///
    /// Determinism: chunks are sequence-aligned, slots accumulate in
    /// global order across chunks, and chunk `m` combines only after
    /// chunks `0..m` — so the accumulation order per token row is exactly
    /// the serial combine's, and outputs are bitwise identical at every K.
    #[allow(clippy::too_many_arguments)]
    fn wavefront_layer(
        &mut self,
        layer: usize,
        plan: &LayerPlan,
        hidden: &mut [HostTensor],
        n_real: &[usize],
        mut prewarmer: Option<&mut Prewarmer>,
        spec_in: Option<SpecTargets>,
        spec_next: &[(usize, &LayerPlan, &[Vec<Vec<u8>>])],
        spec_out: &mut Vec<(usize, SpecTargets)>,
        metrics: &mut StageMetrics,
    ) -> Result<(Vec<Slot>, Vec<usize>)> {
        let e = self.dims.n_experts;
        let t_total = Instant::now();
        let mut router_s_local = 0.0f64;
        let (alloc0, reuse0) = (self.tiles.allocs, self.tiles.reuses);
        let ln = format!("layers.{layer}.moe.ln");
        let wr = format!("layers.{layer}.moe.router");

        let (reply_tx, reply_rx) = mpsc::channel::<WorkerResult>();
        // Shared across all chunks: slots/normed accumulate in global
        // sequence order, so `send_ffn_batches` and the failover path work
        // on global indices unchanged.
        let mut normed: Vec<HostTensor> = Vec::with_capacity(hidden.len());
        let mut slots: Vec<Slot> = Vec::new();
        let mut slot_src: Vec<(u64, usize, usize)> = Vec::new();
        let mut inflight: BTreeMap<u64, (usize, Vec<(usize, Vec<usize>)>)> = BTreeMap::new();
        let mut replies: BTreeMap<u64, Vec<Vec<f32>>> = BTreeMap::new();
        let mut abandoned: HashSet<u64> = HashSet::new();
        let mut msg_tag = 0u64;
        let mut outstanding = 0usize;
        let mut received = 0usize;
        // Not-yet-combined chunks as slot ranges, oldest first.
        let mut chunks: std::collections::VecDeque<(usize, usize)> =
            std::collections::VecDeque::new();
        // Padded rows committed per worker so far this layer — the LPT
        // seed, so later chunks' repair work avoids already-busy hosts.
        let mut layer_load = vec![0usize; self.workers.len()];

        for range in microbatch_ranges(hidden.len(), self.microbatch) {
            // Route this chunk (global sequence indices).
            let t0 = Instant::now();
            let chunk_start = slots.len();
            for seq_idx in range {
                let mut out = self.leader.call(
                    "router",
                    &[In::T(&hidden[seq_idx]), In::W(&ln), In::W(&wr)],
                )?;
                let logits = out.remove(1);
                let xn = out.remove(0);
                slots.extend(route_sequence(
                    seq_idx,
                    &logits.data,
                    e,
                    n_real[seq_idx],
                    self.dims.top_k,
                ));
                normed.push(xn);
            }
            router_s_local += t0.elapsed().as_secs_f64();
            slot_src.resize(slots.len(), (0, 0, 0));

            // Partition the chunk's slots into confirmed speculative hits
            // and the repair set (everything, when speculation is off) —
            // the serial `ffn_stage` partition, applied chunk-wise.
            let mut spec_groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
            let mut repair_idx: Vec<usize> = Vec::new();
            match &spec_in {
                Some(targets) => {
                    for si in chunk_start..slots.len() {
                        let slot = &slots[si];
                        match targets.target_for(
                            slot.seq_idx,
                            slot.token_idx,
                            slot.expert as usize,
                        ) {
                            Some(w) => {
                                spec_groups
                                    .entry((w, slot.expert as usize))
                                    .or_default()
                                    .push(si);
                            }
                            None => repair_idx.push(si),
                        }
                    }
                    metrics.spec_dispatch_slots +=
                        slots.len() - chunk_start - repair_idx.len();
                    metrics.spec_repair_slots += repair_idx.len();
                }
                None => repair_idx.extend(chunk_start..slots.len()),
            }

            // Speculative fast path for the chunk.
            let spec_groups = self.remap_dead_targets(spec_groups, &plan.placement)?;
            if !spec_groups.is_empty() {
                if let Some(pw) = prewarmer.as_deref_mut() {
                    pw.settle_for(
                        layer,
                        &spec_groups,
                        &mut self.residency,
                        &self.health,
                        metrics,
                    )?;
                }
                for ((w, _), v) in &spec_groups {
                    layer_load[*w] += padded_rows(&self.buckets, v.len());
                }
                self.send_ffn_batches(
                    layer,
                    &spec_groups,
                    &slots,
                    &normed,
                    &reply_tx,
                    &mut msg_tag,
                    &mut slot_src,
                    &mut inflight,
                    &mut outstanding,
                    metrics,
                );
            }

            // Repair pass for the chunk: quota dispatch → runt merge →
            // LPT seeded with everything already committed this layer.
            if !repair_idx.is_empty() {
                let experts: Vec<u8> =
                    repair_idx.iter().map(|&si| slots[si].expert).collect();
                let (assignment, _loads) = if plan.share.is_empty() {
                    dispatch_tokens(&experts, &plan.placement)
                } else {
                    dispatch_with_quota(&experts, &plan.placement, &plan.share)
                };
                let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
                for (pos, &w) in assignment.iter().enumerate() {
                    let si = repair_idx[pos];
                    groups
                        .entry((w as usize, slots[si].expert as usize))
                        .or_default()
                        .push(si);
                }
                merge_runt_groups(&mut groups, MIN_GROUP);
                let placed = lpt_place_seeded(
                    groups,
                    plan,
                    self.workers.len(),
                    &self.buckets,
                    &layer_load,
                );
                let placed = self.remap_dead_targets(placed, &plan.placement)?;
                if let Some(pw) = prewarmer.as_deref_mut() {
                    pw.settle_for(
                        layer,
                        &placed,
                        &mut self.residency,
                        &self.health,
                        metrics,
                    )?;
                }
                for ((w, _), v) in &placed {
                    layer_load[*w] += padded_rows(&self.buckets, v.len());
                }
                self.send_ffn_batches(
                    layer,
                    &placed,
                    &slots,
                    &normed,
                    &reply_tx,
                    &mut msg_tag,
                    &mut slot_src,
                    &mut inflight,
                    &mut outstanding,
                    metrics,
                );
            }
            chunks.push_back((chunk_start, slots.len()));

            // Opportunistic drain: absorb whatever already landed without
            // blocking, then combine every complete prefix chunk — the
            // leader moves straight on to routing the next chunk.
            loop {
                match reply_rx.try_recv() {
                    Ok(result) => {
                        self.absorb_ffn_reply(
                            result,
                            &mut abandoned,
                            &mut inflight,
                            &mut replies,
                            &mut received,
                            metrics,
                        )?;
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        anyhow::bail!("worker channel closed")
                    }
                }
            }
            self.combine_ready_chunks(&mut chunks, &slots, &slot_src, &mut replies, hidden);
        }

        // Every chunk is dispatched; the workers are busy — the window in
        // which the lookahead layers' speculative targets derive from
        // predictions + plan alone (depth-k, ADR 006).
        for &(l, plan_next, preds_next) in spec_next {
            spec_out.push((l, SpecTargets::build(preds_next, plan_next)));
        }

        // Final blocking collect: identical straggler-grace / death /
        // failover ladder to the serial path (ADR 008).
        let mut waits = 0u32;
        while received < outstanding {
            let t_wait = Instant::now();
            let recv = reply_rx.recv_timeout(self.health.deadline() * (1u32 << waits));
            metrics.leader_stall_s += t_wait.elapsed().as_secs_f64();
            match recv {
                Ok(result) => {
                    if self.absorb_ffn_reply(
                        result,
                        &mut abandoned,
                        &mut inflight,
                        &mut replies,
                        &mut received,
                        metrics,
                    )? {
                        waits = 0;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    metrics.retry_count += 1;
                    waits += 1;
                    if waits < MAX_TIMEOUT_WAITS {
                        continue;
                    }
                    waits = 0;
                    self.redispatch_stale_batches(
                        layer,
                        plan,
                        &slots,
                        &normed,
                        &reply_tx,
                        &mut msg_tag,
                        &mut slot_src,
                        &mut inflight,
                        &mut abandoned,
                        &mut outstanding,
                        prewarmer.as_deref_mut(),
                        metrics,
                    )?;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("worker channel closed");
                }
            }
        }
        self.combine_ready_chunks(&mut chunks, &slots, &slot_src, &mut replies, hidden);
        debug_assert!(chunks.is_empty(), "all chunks combined after collect");
        debug_assert!(replies.is_empty(), "all reply buffers recycled");

        let actual_counts = expert_counts(&slots, e);
        metrics.n_slots += slots.len();
        metrics.router_s += router_s_local;
        metrics.ffn_wall_s += (t_total.elapsed().as_secs_f64() - router_s_local).max(0.0);
        metrics.tile_allocs += self.tiles.allocs - alloc0;
        metrics.tile_reuses += self.tiles.reuses - reuse0;
        Ok((slots, actual_counts))
    }

    /// Combine every *ready* prefix micro-batch (ADR 010): a chunk is
    /// ready when all its slots' batches have replied. Chunks combine
    /// strictly oldest-first — sequence-aligned chunks make per-chunk
    /// slot-order accumulation identical to the serial global-slot-order
    /// combine — and a fully combined chunk recycles its reply buffers
    /// immediately, bounding live slabs to the in-flight window.
    fn combine_ready_chunks(
        &mut self,
        chunks: &mut std::collections::VecDeque<(usize, usize)>,
        slots: &[Slot],
        slot_src: &[(u64, usize, usize)],
        replies: &mut BTreeMap<u64, Vec<Vec<f32>>>,
        hidden: &mut [HostTensor],
    ) {
        let d = self.dims.d_model;
        while let Some(&(s0, s1)) = chunks.front() {
            let ready =
                (s0..s1).all(|si| replies.contains_key(&slot_src[si].0));
            if !ready {
                return;
            }
            let mut used: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
            for si in s0..s1 {
                let (tag, gi, row) = slot_src[si];
                let slot = &slots[si];
                let out = &replies[&tag][gi];
                let out_row = &out[row * d..(row + 1) * d];
                let h = &mut hidden[slot.seq_idx];
                let dst = &mut h.data[slot.token_idx * d..(slot.token_idx + 1) * d];
                for (a, &b) in dst.iter_mut().zip(out_row) {
                    *a += slot.gate * b;
                }
                used.insert(tag);
            }
            // Batch tags never span chunks (dispatch and failover both
            // regroup within one chunk), so the chunk's buffers recycle
            // as soon as it combines.
            for tag in used {
                if let Some(outs) = replies.remove(&tag) {
                    for out in outs {
                        self.tiles.put(out);
                    }
                }
            }
            chunks.pop_front();
        }
    }

    /// The surviving host an expert's lost group fails over to (ADR 008):
    /// the lowest-indexed *alive* replica under the layer's duplication
    /// plan — the plan is the redundancy table — falling back to the
    /// lowest-indexed alive worker (the weights upload cold on demand
    /// there; weights are identical on every worker, so a fallback host
    /// changes transfer bytes, never values). `Err(all workers dead)`
    /// when no worker survives.
    fn failover_for(&self, placement: &Placement, expert: usize) -> Result<usize> {
        if let Some(w) = placement
            .gpus_of(expert)
            .into_iter()
            .find(|&g| self.health.is_alive(g))
        {
            return Ok(w);
        }
        (0..self.workers.len())
            .find(|&w| self.health.is_alive(w))
            .ok_or_else(all_workers_dead_err)
    }

    /// Re-home dispatch groups that target a dead worker before sending
    /// (the plan can lag a death until the degraded replan lands). A
    /// no-op returning the groups untouched while the fleet is whole, so
    /// the healthy dispatch path is byte-for-byte the pre-ADR-008 one.
    fn remap_dead_targets(
        &self,
        groups: BTreeMap<(usize, usize), Vec<usize>>,
        placement: &Placement,
    ) -> Result<BTreeMap<(usize, usize), Vec<usize>>> {
        if self.health.alive_count() == self.workers.len() {
            return Ok(groups);
        }
        let mut out: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for ((worker, expert), slot_indices) in groups {
            let w = if self.health.is_alive(worker) {
                worker
            } else {
                self.failover_for(placement, expert)?
            };
            out.entry((w, expert)).or_default().extend(slot_indices);
        }
        Ok(out)
    }
}

/// Per-token speculative dispatch targets for one layer: token
/// `(seq_idx, token_idx)` → for each of its ranked top-k predicted
/// experts, the worker its §3.1 prediction routes it to under that
/// layer's duplication plan. Built from predictions + plan alone — no
/// activations — which is what lets the pipeline derive layer L+1's
/// targets during layer L's FFN phase. A routed slot confirms when its
/// expert appears *anywhere* in the token's predicted set (top-k-aware
/// confirmation, the ADR-003 follow-up), so up to all k of a token's
/// slots can ship on the fast path.
pub(crate) struct SpecTargets {
    /// `(seq, tok)` → `[(worker, expert)]`, one entry per predicted rank.
    targets: std::collections::HashMap<(usize, usize), Vec<(usize, usize)>>,
}

impl SpecTargets {
    /// `preds[seq][token]` = the token's ranked top-k predicted experts
    /// for this layer (rank 0 = predictor argmax). Replicated experts
    /// spread their predicted tokens over the hosts following the plan's
    /// per-(expert, gpu) quota (`share[e][g]`, built from these same
    /// predicted counts): each (token, rank) goes to the replica with the
    /// lowest *filled fraction* of its quota, so speculative load tracks
    /// the balance the plan computed from the first token on — a uniform
    /// rotation would undo exactly the skew-aware split the quota
    /// encodes. Experts with no quota (shareless plans) fall back to
    /// round-robin. Deterministic: assignment follows (seq, token, rank)
    /// order with lowest-gpu tie-breaks.
    fn build(preds: &[Vec<Vec<u8>>], plan: &LayerPlan) -> SpecTargets {
        let mut given: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        let mut rr: BTreeMap<usize, usize> = BTreeMap::new();
        let total: usize = preds.iter().map(Vec::len).sum();
        let mut targets: std::collections::HashMap<(usize, usize), Vec<(usize, usize)>> =
            std::collections::HashMap::with_capacity(total);
        for (seq, toks) in preds.iter().enumerate() {
            for (tok, ranked) in toks.iter().enumerate() {
                for &expert in ranked {
                    let expert = expert as usize;
                    let hosts = plan.placement.gpus_of(expert);
                    if hosts.is_empty() {
                        continue;
                    }
                    // Lowest filled-fraction host among those with quota
                    // (`given/quota` compared by cross-multiplication to
                    // stay in integers); ties prefer the lower gpu id.
                    let mut best: Option<(usize, usize, usize)> = None; // (g, given, quota)
                    for g in hosts.iter().copied() {
                        let quota = plan
                            .share
                            .get(expert)
                            .and_then(|row| row.get(g))
                            .copied()
                            .unwrap_or(0);
                        if quota == 0 {
                            continue;
                        }
                        let giv = given.get(&(expert, g)).copied().unwrap_or(0);
                        best = match best {
                            None => Some((g, giv, quota)),
                            Some((bg, bgiv, bq)) => {
                                let lhs = giv * bq;
                                let rhs = bgiv * quota;
                                if lhs < rhs || (lhs == rhs && g < bg) {
                                    Some((g, giv, quota))
                                } else {
                                    Some((bg, bgiv, bq))
                                }
                            }
                        };
                    }
                    let worker = match best {
                        Some((g, _, _)) => g,
                        None => {
                            // No quota anywhere for this expert: spread
                            // round-robin over its hosts.
                            let turn = rr.entry(expert).or_insert(0);
                            let w = hosts[*turn % hosts.len()];
                            *turn += 1;
                            w
                        }
                    };
                    *given.entry((expert, worker)).or_insert(0) += 1;
                    targets
                        .entry((seq, tok))
                        .or_default()
                        .push((worker, expert));
                }
            }
        }
        SpecTargets { targets }
    }

    /// The worker a routed slot ships to speculatively, if its expert was
    /// among the token's predicted top-k (first matching rank wins).
    fn target_for(&self, seq: usize, tok: usize, expert: usize) -> Option<usize> {
        self.targets.get(&(seq, tok)).and_then(|ranked| {
            ranked
                .iter()
                .find(|&&(_, e)| e == expert)
                .map(|&(w, _)| w)
        })
    }
}

/// Issue one layer step's prewarm window (ADR 004): walk the window
/// nearest layer first under a fresh per-step byte budget, stopping at
/// the depth where the budget runs out — so the deepest prewarms are the
/// first dropped, and both attention-ordering modes share one behaviour.
fn issue_prewarm_window(
    pw: &mut Prewarmer,
    workers: &[WorkerHandle],
    residency: &mut ResidencyManager,
    health: &WorkerHealth,
    plans: &[LayerPlan],
    window: std::ops::RangeInclusive<usize>,
    budget_init: Option<u64>,
) {
    let mut budget = budget_init;
    for target in window {
        if pw.issue(workers, residency, health, target, &plans[target], &mut budget) {
            break; // budget exhausted at this depth
        }
    }
}

/// In-flight lookahead prewarms: issued per layer ahead of that layer's
/// compute, settled selectively just before the FFN phase dispatches.
///
/// Settling only blocks on the (worker, expert) pairs the layer's
/// dispatch actually routed work to — prewarms of experts that received
/// no tokens this layer keep streaming in the background and are drained
/// (as hidden) whenever their acks show up, so warming the whole
/// placement never barriers the pipeline.
struct Prewarmer {
    tx: mpsc::Sender<WorkerResult>,
    rx: mpsc::Receiver<WorkerResult>,
    /// In-flight (worker, layer, expert) prewarms not yet acked.
    pending: std::collections::HashSet<(usize, usize, usize)>,
}

/// The Prewarmer keeps its own `tx` alive (it clones it per message), so
/// a dead worker cannot surface as a channel disconnect here. Blocking
/// waits use the cost-model reply deadline with the same escalation as
/// the FFN collector, capped at this ceiling; when even that expires the
/// still-pending prewarms are *abandoned* — counted as
/// `prewarm_timeouts` and marked residency-unknown so the next dispatch
/// re-uploads cold — rather than erroring the round (ADR 008: a lost
/// prewarm ack must never pin residency, or stall serving, forever).
const PREWARM_ACK_TIMEOUT: Duration = Duration::from_secs(30);

impl Prewarmer {
    fn new() -> Prewarmer {
        let (tx, rx) = mpsc::channel();
        Prewarmer {
            tx,
            rx,
            pending: std::collections::HashSet::new(),
        }
    }

    fn ack_deadline(health: &WorkerHealth, waits: u32) -> Duration {
        (health.deadline() * (1u32 << waits)).min(PREWARM_ACK_TIMEOUT)
    }

    /// Drop pending prewarms owned by a worker just declared dead; its
    /// residency was already reclaimed wholesale, so only the ack
    /// bookkeeping needs clearing. Returns how many were purged (each is
    /// a `prewarm_timeouts` tick at the caller).
    fn purge_worker(&mut self, worker: usize) -> usize {
        let before = self.pending.len();
        self.pending.retain(|&(w, _, _)| w != worker);
        before - self.pending.len()
    }

    /// Drop pending prewarms owned by any dead worker (deaths detected
    /// outside the FFN path — e.g. during attention — reach the
    /// Prewarmer here, at the next settle point).
    fn purge_dead(&mut self, health: &WorkerHealth, metrics: &mut StageMetrics) {
        let before = self.pending.len();
        self.pending.retain(|&(w, _, _)| health.is_alive(w));
        metrics.prewarm_timeouts += (before - self.pending.len()) as u64;
    }

    /// Abandon every still-pending prewarm after the ack deadline
    /// exhausted: count each, and mark it residency-unknown so a later
    /// dispatch re-uploads cold instead of trusting the phantom replica.
    fn abandon_pending(
        &mut self,
        residency: &mut ResidencyManager,
        metrics: &mut StageMetrics,
    ) {
        for (w, l, e) in std::mem::take(&mut self.pending) {
            residency.invalidate(w, l, e);
            metrics.prewarm_timeouts += 1;
        }
    }

    /// Fire non-blocking prewarms for every (expert, worker) of the plan
    /// not already resident on that worker; the coordinator-side
    /// [`ResidencyManager`] gates re-sends, admits each new replica into
    /// the LRU (emitting capacity evictions ahead of the prewarm on the
    /// same FIFO queue) and `budget` bounds the bytes issued at this
    /// layer step. Dead workers are skipped (ADR 008). Returns true when
    /// the budget ran out — the caller stops descending into deeper
    /// lookahead layers (ADR 004).
    fn issue(
        &mut self,
        workers: &[WorkerHandle],
        residency: &mut ResidencyManager,
        health: &WorkerHealth,
        layer: usize,
        plan: &LayerPlan,
        budget: &mut Option<u64>,
    ) -> bool {
        let replica_bytes = residency.replica_bytes();
        for &(expert, gpu) in plan.placement.pairs() {
            if !health.is_alive(gpu) {
                continue;
            }
            if residency.contains(gpu, layer, expert) {
                continue;
            }
            if let Some(left) = budget {
                if *left < replica_bytes {
                    return true; // deeper prewarms wait for the next step
                }
                *left -= replica_bytes;
            }
            let admission = residency.admit(gpu, layer, expert);
            debug_assert!(admission.newly_resident);
            for (victim_layer, victim_expert) in admission.evicted {
                workers[gpu].send(WorkerMsg::Evict {
                    layer: victim_layer,
                    expert: victim_expert,
                });
            }
            workers[gpu].send(WorkerMsg::Prewarm {
                tag: layer as u64,
                layer,
                expert,
                reply: self.tx.clone(),
            });
            self.pending.insert((gpu, layer, expert));
        }
        false
    }

    /// Account acks before the FFN phase dispatches: everything already in
    /// the channel was fully overlapped (hidden); acks for pairs this
    /// layer's dispatch *needs* are blocked on (exposed bytes + stall
    /// time), while unneeded in-flight prewarms are left streaming. Acks
    /// that never arrive (worker died, message lost) are abandoned after
    /// the escalated deadline rather than erroring the round.
    fn settle_for(
        &mut self,
        layer: usize,
        needed: &BTreeMap<(usize, usize), Vec<usize>>,
        residency: &mut ResidencyManager,
        health: &WorkerHealth,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        while let Ok(ack) = self.rx.try_recv() {
            self.absorb(ack, true, metrics)?;
        }
        self.purge_dead(health, metrics);
        let still_needed = |pending: &std::collections::HashSet<(usize, usize, usize)>| {
            needed
                .keys()
                .any(|&(worker, expert)| pending.contains(&(worker, layer, expert)))
        };
        let mut waits = 0u32;
        while still_needed(&self.pending) {
            let t0 = Instant::now();
            match self.rx.recv_timeout(Self::ack_deadline(health, waits)) {
                Ok(ack) => {
                    waits = 0;
                    metrics.exposed_transfer_s += t0.elapsed().as_secs_f64();
                    // Only the transfers this dispatch had to have are
                    // exposed; anything else that lands during the stall
                    // still beat its own point of use.
                    let hidden = ack.layer != layer
                        || !needed.contains_key(&(ack.worker, ack.expert));
                    self.absorb(ack, hidden, metrics)?;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    waits += 1;
                    if waits < MAX_TIMEOUT_WAITS {
                        continue;
                    }
                    // Deadline exhausted: a prewarm is not worth a death
                    // verdict (the FFN path decides those) — abandon the
                    // laggards and let dispatch re-upload cold.
                    self.abandon_pending(residency, metrics);
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("prewarm channel closed");
                }
            }
        }
        Ok(())
    }

    /// Drain every remaining in-flight ack (end of the layer loop), so no
    /// transferred byte escapes the accounting. These prewarms were never
    /// waited on by any dispatch — their bytes are hidden — but the drain
    /// itself delays the round tail, so its wall time is charged exposed.
    /// Like [`Prewarmer::settle_for`], lost acks are abandoned after the
    /// escalated deadline instead of hanging or erroring the round.
    fn finish(
        &mut self,
        residency: &mut ResidencyManager,
        health: &WorkerHealth,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        self.purge_dead(health, metrics);
        let mut waits = 0u32;
        while !self.pending.is_empty() {
            let t0 = Instant::now();
            match self.rx.recv_timeout(Self::ack_deadline(health, waits)) {
                Ok(ack) => {
                    waits = 0;
                    metrics.exposed_transfer_s += t0.elapsed().as_secs_f64();
                    self.absorb(ack, true, metrics)?;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    waits += 1;
                    if waits < MAX_TIMEOUT_WAITS {
                        continue;
                    }
                    self.abandon_pending(residency, metrics);
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("prewarm channel closed");
                }
            }
        }
        Ok(())
    }

    fn absorb(
        &mut self,
        ack: WorkerResult,
        hidden: bool,
        metrics: &mut StageMetrics,
    ) -> Result<()> {
        if let Some(err) = &ack.error {
            anyhow::bail!("prewarm on worker {} failed: {err}", ack.worker);
        }
        self.pending.remove(&(ack.worker, ack.layer, ack.expert));
        metrics.upload_bytes += ack.upload_bytes;
        if hidden {
            metrics.hidden_upload_bytes += ack.upload_bytes;
            metrics.hidden_transfer_s += ack.exec_s;
        } else {
            metrics.exposed_upload_bytes += ack.upload_bytes;
        }
        Ok(())
    }
}

pub(crate) fn attn_weight_names(layer: usize) -> [String; 5] {
    [
        format!("layers.{layer}.attn.ln"),
        format!("layers.{layer}.attn.wq"),
        format!("layers.{layer}.attn.wk"),
        format!("layers.{layer}.attn.wv"),
        format!("layers.{layer}.attn.wo"),
    ]
}

/// Group slot indices per (dispatch worker, expert) — the unit the FFN
/// phase pads, merges and places.
pub fn group_slots_by_assignment(
    assignment: &[u32],
    slots: &[Slot],
) -> BTreeMap<(usize, usize), Vec<usize>> {
    let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for (slot_idx, (&slot_worker, slot)) in assignment.iter().zip(slots).enumerate() {
        groups
            .entry((slot_worker as usize, slot.expert as usize))
            .or_default()
            .push(slot_idx);
    }
    groups
}

/// §Perf iteration 1: fold any group smaller than `min_group` into the
/// largest group of the same expert (splitting an expert across workers
/// for a handful of slots costs a whole padded-bucket FFN call — and
/// possibly a weight transfer — for negligible balance gain).
pub fn merge_runt_groups(groups: &mut BTreeMap<(usize, usize), Vec<usize>>, min_group: usize) {
    let expert_ids: Vec<usize> = groups
        .keys()
        .map(|&(_, e)| e)
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for expert in expert_ids {
        let mut keys: Vec<(usize, usize)> = groups
            .keys()
            .filter(|&&(_, ge)| ge == expert)
            .cloned()
            .collect();
        if keys.len() < 2 {
            continue;
        }
        keys.sort_by_key(|k| groups[k].len());
        let Some(&biggest) = keys.last() else {
            continue;
        };
        for key in &keys[..keys.len() - 1] {
            if groups.get(key).map_or(usize::MAX, Vec::len) < min_group {
                if let Some(moved) = groups.remove(key) {
                    groups.entry(biggest).or_default().extend(moved);
                }
            }
        }
    }
}

/// Total padded rows a group of `n` slots costs under the bucket ladder.
pub fn padded_rows(buckets: &[usize], n: usize) -> usize {
    split_into_buckets(buckets, n).iter().map(|&(_, b)| b).sum()
}

/// The ADR 010 micro-batch split rule: partition `n` sequences into at
/// most `k` deterministic contiguous chunks, chunk `m` covering
/// `[⌊m·n/k⌋, ⌊(m+1)·n/k⌋)`. Empty chunks are skipped, so `k > n`
/// degenerates to one sequence per chunk and `k <= 1` to the whole set.
/// Pure arithmetic on (n, k) — the wavefront's chunking (and therefore
/// its dispatch schedule) never depends on timing.
pub fn microbatch_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    let k = k.max(1);
    let mut out = Vec::with_capacity(k.min(n));
    for m in 0..k {
        let start = m * n / k;
        let end = (m + 1) * n / k;
        if end > start {
            out.push(start..end);
        }
    }
    out
}

/// §Perf iteration 3: greedy LPT placement of merged groups. The
/// dispatcher's slot-level least-loaded choice ignores bucket padding — a
/// 3-slot and a 14-slot group cost the same padded FFN call, and on
/// decode-scale batches the padded call count per worker IS the critical
/// path. Re-assign each group to the least-loaded worker hosting a
/// replica (largest group first, load measured in padded rows; ties
/// prefer the original worker, whose weights are more likely resident).
/// Without replicas (baseline) every expert has one host and this is the
/// identity — the invariant `tests/lpt_placement.rs` pins down.
pub fn lpt_place(
    groups: BTreeMap<(usize, usize), Vec<usize>>,
    plan: &LayerPlan,
    n_workers: usize,
    buckets: &[usize],
) -> BTreeMap<(usize, usize), Vec<usize>> {
    lpt_place_seeded(groups, plan, n_workers, buckets, &vec![0; n_workers])
}

/// [`lpt_place`] with pre-existing per-worker padded-row load — the
/// speculative fast path's tiles are already committed to their predicted
/// hosts when the repair pass places, so LPT must see that load or it
/// would stack repair work onto the busiest workers (ADR 003).
pub fn lpt_place_seeded(
    groups: BTreeMap<(usize, usize), Vec<usize>>,
    plan: &LayerPlan,
    n_workers: usize,
    buckets: &[usize],
    initial_load: &[usize],
) -> BTreeMap<(usize, usize), Vec<usize>> {
    debug_assert_eq!(initial_load.len(), n_workers);
    let mut items: Vec<((usize, usize), Vec<usize>)> = groups.into_iter().collect();
    items.sort_by_key(|(key, v)| (std::cmp::Reverse(v.len()), *key));
    let mut lpt_load = initial_load.to_vec();
    let mut placed: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    for ((orig_worker, expert), slot_indices) in items {
        let padded = padded_rows(buckets, slot_indices.len());
        let hosts = plan.placement.gpus_of(expert);
        let target = hosts
            .iter()
            .copied()
            .min_by_key(|&g| (lpt_load[g], (g != orig_worker) as usize, g))
            .unwrap_or(orig_worker);
        lpt_load[target] += padded;
        placed.entry((target, expert)).or_default().extend(slot_indices);
    }
    placed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement_mgr::PlacementManager;

    fn slot(expert: u8) -> Slot {
        Slot {
            seq_idx: 0,
            token_idx: 0,
            expert,
            gate: 1.0,
        }
    }

    #[test]
    fn grouping_partitions_slots() {
        let slots: Vec<Slot> = [0u8, 1, 0, 2, 1, 0].iter().map(|&e| slot(e)).collect();
        let assignment = vec![0u32, 1, 0, 2, 1, 3];
        let groups = group_slots_by_assignment(&assignment, &slots);
        let mut all: Vec<usize> = groups.values().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(groups[&(0, 0)], vec![0, 2]);
        assert_eq!(groups[&(3, 0)], vec![5]);
    }

    #[test]
    fn runt_groups_fold_into_biggest_of_same_expert() {
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        groups.insert((0, 7), (0..20).collect());
        groups.insert((1, 7), vec![20, 21]); // runt, same expert
        groups.insert((2, 3), vec![22]); // sole group of its expert: kept
        merge_runt_groups(&mut groups, 16);
        assert!(!groups.contains_key(&(1, 7)));
        assert_eq!(groups[&(0, 7)].len(), 22);
        assert_eq!(groups[&(2, 3)], vec![22]);
    }

    #[test]
    fn padded_rows_monotone_and_exact_on_buckets() {
        let buckets = [8usize, 16, 32, 64];
        let mut prev = 0usize;
        for n in 0..300 {
            let p = padded_rows(&buckets, n);
            assert!(p >= n, "padded {p} < n {n}");
            assert!(p >= prev, "padded rows must be monotone: {prev} -> {p}");
            prev = p;
        }
        assert_eq!(padded_rows(&buckets, 64), 64);
        assert_eq!(padded_rows(&buckets, 65), 64 + 8);
    }

    #[test]
    fn lpt_static_plan_is_identity() {
        let mgr = PlacementManager::new(8, 4, 2, 8, 4);
        let plan = mgr.static_plan();
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        // Experts 0..8 homed two-per-gpu; groups at their home workers.
        for e in 0..8usize {
            let home = plan.placement.gpus_of(e)[0];
            groups.insert((home, e), vec![e * 10, e * 10 + 1]);
        }
        let placed = lpt_place(groups.clone(), &plan, 4, &[8, 16, 32, 64]);
        assert_eq!(placed, groups);
    }

    #[test]
    fn lpt_spreads_replicated_hot_expert() {
        let mgr = PlacementManager::new(8, 4, 2, 8, 4);
        let plan = mgr.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        assert!(plan.placement.copies(0) > 1);
        // Two equally big groups of the hot expert: the second must land
        // on a different replica host than the first (its padded load is
        // visible to the least-loaded choice).
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        groups.insert((0, 0), (0..40).collect());
        groups.insert((1, 0), (40..80).collect());
        let placed = lpt_place(groups, &plan, 4, &[8, 16, 32, 64]);
        let total: usize = placed.values().map(Vec::len).sum();
        assert_eq!(total, 80, "slots conserved");
        for &(w, e) in placed.keys() {
            assert_eq!(e, 0);
            assert!(plan.placement.hosts(e, w), "host {w} lacks expert {e}");
        }
        assert_eq!(placed.len(), 2, "groups must spread over two hosts");
    }

    #[test]
    fn stage_metrics_apply_to_both_metric_kinds() {
        let mut s = StageMetrics::new(2);
        s.attention_s = 1.0;
        s.router_s = 0.5;
        s.ffn_wall_s = 2.0;
        s.n_slots = 10;
        s.worker_busy_s = vec![1.0, 2.0];
        s.worker_slots = vec![4, 6];
        s.upload_bytes = 100;
        s.hidden_upload_bytes = 70;
        s.exposed_upload_bytes = 30;
        s.tile_allocs = 2;
        s.tile_reuses = 5;
        s.spec_dispatch_slots = 6;
        s.spec_repair_slots = 4;
        s.evictions = 3;
        s.refetch_upload_bytes = 40;
        s.resident_high_water_bytes = 900;
        s.pred_slots = 12;
        s.pred_tokens = 6;
        s.pred_topk_hits = 9;
        s.pred_top1_hits = 5;
        s.share_l1s.push(0.2);
        s.share_l1s.push(0.4);
        s.skews.push(1.5);
        s.worker_deaths = 1;
        s.redispatched_slots = 3;
        s.retry_count = 2;
        s.prewarm_timeouts = 1;
        s.degraded = true;
        s.bytes_copied = 640;
        s.bytes_shared = 4096;
        s.ffn_messages = 7;
        s.leader_stall_s = 0.25;
        s.wavefront_window_s = 4.0;
        s.tile_peak = 9;
        s.finish();
        assert_eq!(s.pred_share_layers, 2);
        assert!((s.pred_share_l1 - 0.3).abs() < 1e-12);
        // finish() derives the idle fraction from busy vs window × fleet:
        // 1 − (1 + 2) / (4 × 2) = 0.625.
        assert!((s.worker_idle_frac - 0.625).abs() < 1e-12);
        let mut round = RoundMetrics {
            worker_busy_s: vec![0.0; 2],
            worker_slots: vec![0; 2],
            ..Default::default()
        };
        s.apply_to_round(&mut round);
        assert_eq!(round.n_slots, 10);
        assert_eq!(round.upload_bytes, 100);
        assert_eq!(round.hidden_upload_bytes, 70);
        assert_eq!(round.worker_slots, vec![4, 6]);
        assert_eq!(round.tile_allocs, 2);
        assert_eq!(round.tile_reuses, 5);
        assert_eq!(round.spec_dispatch_slots, 6);
        assert_eq!(round.spec_repair_slots, 4);
        assert_eq!(round.evictions, 3);
        assert_eq!(round.refetch_upload_bytes, 40);
        assert_eq!(round.resident_high_water_bytes, 900);
        assert_eq!(round.pred_slots, 12);
        assert_eq!(round.pred_tokens, 6);
        assert_eq!(round.pred_topk_hits, 9);
        assert_eq!(round.pred_top1_hits, 5);
        assert_eq!(round.pred_share_layers, 2);
        assert!((round.pred_share_l1 - 0.3).abs() < 1e-12);
        assert_eq!(round.worker_deaths, 1);
        assert_eq!(round.redispatched_slots, 3);
        assert_eq!(round.retry_count, 2);
        assert_eq!(round.prewarm_timeouts, 1);
        assert!(round.degraded);
        assert_eq!(round.bytes_copied, 640);
        assert_eq!(round.bytes_shared, 4096);
        assert_eq!(round.ffn_messages, 7);
        assert!((round.leader_stall_s - 0.25).abs() < 1e-12);
        assert!((round.wavefront_window_s - 4.0).abs() < 1e-12);
        assert!((round.worker_idle_frac - 0.625).abs() < 1e-12);
        assert_eq!(round.tile_peak, 9);
        // High-water is max-assigned, not summed: a second application
        // with a lower peak must not move it — and a stage that measured
        // no window must not clobber the idle fraction.
        let mut lower = StageMetrics::new(2);
        lower.resident_high_water_bytes = 100;
        lower.tile_peak = 3;
        lower.finish();
        lower.apply_to_round(&mut round);
        assert_eq!(round.resident_high_water_bytes, 900);
        assert_eq!(round.tile_peak, 9);
        assert!((round.worker_idle_frac - 0.625).abs() < 1e-12);
        // Degraded is a latch: a healthy stage must not clear it.
        assert!(round.degraded);
        assert!((round.routing_skew - 1.5).abs() < 1e-12);
        // A second stage with no share samples must not clobber the
        // layer-weighted share error (latent-aggregation guard).
        assert_eq!(round.pred_share_layers, 2);
        assert!((round.pred_share_l1 - 0.3).abs() < 1e-12);
        let mut more = StageMetrics::new(2);
        more.share_l1s.push(0.6);
        more.share_l1s.push(0.6);
        more.finish();
        more.apply_to_round(&mut round);
        assert_eq!(round.pred_share_layers, 4);
        assert!((round.pred_share_l1 - 0.45).abs() < 1e-12, "weighted merge");
        let mut step = DecodeStepMetrics {
            worker_busy_s: vec![0.0; 2],
            worker_slots: vec![0; 2],
            ..Default::default()
        };
        s.apply_to_step(&mut step);
        assert_eq!(step.n_slots, 10);
        assert_eq!(step.exposed_upload_bytes, 30);
        assert_eq!(step.worker_busy_s, vec![1.0, 2.0]);
        assert_eq!(step.tile_allocs, 2);
        assert_eq!(step.tile_reuses, 5);
        assert_eq!(step.spec_dispatch_slots, 6);
        assert_eq!(step.spec_repair_slots, 4);
        assert_eq!(step.evictions, 3);
        assert_eq!(step.refetch_upload_bytes, 40);
        assert_eq!(step.resident_high_water_bytes, 900);
        assert_eq!(step.pred_slots, 12);
        assert_eq!(step.pred_tokens, 6);
        assert_eq!(step.pred_topk_hits, 9);
        assert_eq!(step.pred_top1_hits, 5);
        assert_eq!(step.pred_share_layers, 2);
        assert!((step.pred_share_l1 - 0.3).abs() < 1e-12);
        assert_eq!(step.worker_deaths, 1);
        assert_eq!(step.redispatched_slots, 3);
        assert_eq!(step.retry_count, 2);
        assert_eq!(step.prewarm_timeouts, 1);
        assert!(step.degraded);
        assert_eq!(step.bytes_copied, 640);
        assert_eq!(step.bytes_shared, 4096);
        assert_eq!(step.ffn_messages, 7);
        assert!((step.leader_stall_s - 0.25).abs() < 1e-12);
        assert!((step.wavefront_window_s - 4.0).abs() < 1e-12);
        assert!((step.worker_idle_frac - 0.625).abs() < 1e-12);
        assert_eq!(step.tile_peak, 9);
    }

    #[test]
    fn microbatch_ranges_cover_and_are_contiguous() {
        for n in 0..12usize {
            for k in 1..8usize {
                let ranges = microbatch_ranges(n, k);
                // Concatenated ranges reproduce 0..n exactly, in order.
                let flat: Vec<usize> = ranges.iter().cloned().flatten().collect();
                assert_eq!(flat, (0..n).collect::<Vec<_>>(), "n={n} k={k}");
                assert!(ranges.len() <= k.min(n.max(1)), "n={n} k={k}");
                // Near-equal: chunk sizes differ by at most one.
                if let (Some(min), Some(max)) = (
                    ranges.iter().map(|r| r.len()).min(),
                    ranges.iter().map(|r| r.len()).max(),
                ) {
                    assert!(max - min <= 1, "n={n} k={k}: {min}..{max}");
                }
            }
        }
        assert_eq!(microbatch_ranges(6, 1), vec![0..6]);
        assert_eq!(microbatch_ranges(3, 8).len(), 3, "k > n: one seq per chunk");
        assert_eq!(microbatch_ranges(0, 4), Vec::<std::ops::Range<usize>>::new());
    }

    #[test]
    fn lpt_seeded_avoids_preloaded_worker() {
        let mgr = PlacementManager::new(8, 4, 2, 8, 4);
        let plan = mgr.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        let hosts = plan.placement.gpus_of(0);
        assert!(hosts.len() >= 2);
        // One group of the replicated hot expert; host 0 already carries
        // speculative load, so the group must land on another replica.
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        groups.insert((hosts[0], 0), (0..8).collect());
        let mut seed = vec![0usize; 4];
        seed[hosts[0]] = 1000;
        let placed = lpt_place_seeded(groups, &plan, 4, &[8, 16, 32, 64], &seed);
        assert_eq!(placed.len(), 1);
        let (&(w, e), v) = placed.iter().next().unwrap();
        assert_eq!(e, 0);
        assert_ne!(w, hosts[0], "seeded load must steer the group away");
        assert!(plan.placement.hosts(e, w));
        assert_eq!(v.len(), 8);
    }

    #[test]
    fn spec_targets_confirm_anywhere_in_predicted_topk() {
        let mgr = PlacementManager::new(8, 4, 2, 8, 4);
        let plan = mgr.static_plan();
        // Two sequences; each token predicts a ranked top-2 expert set.
        let preds: Vec<Vec<Vec<u8>>> =
            vec![vec![vec![2, 7], vec![2, 3], vec![5, 2]], vec![vec![2, 6]]];
        let st = SpecTargets::build(&preds, &plan);
        let home = |e: usize| plan.placement.gpus_of(e)[0];
        // Rank-0 predictions confirm…
        assert_eq!(st.target_for(0, 0, 2), Some(home(2)));
        assert_eq!(st.target_for(0, 2, 5), Some(home(5)));
        // …and so do rank-1 predictions (the top-k-aware follow-up).
        assert_eq!(st.target_for(0, 0, 7), Some(home(7)));
        assert_eq!(st.target_for(0, 1, 3), Some(home(3)));
        assert_eq!(st.target_for(1, 0, 6), Some(home(6)));
        // Unpredicted experts, tokens and sequences have no target.
        assert_eq!(st.target_for(0, 0, 4), None);
        assert_eq!(st.target_for(0, 3, 2), None, "unknown token");
        assert_eq!(st.target_for(2, 0, 2), None, "unknown sequence");
    }

    #[test]
    fn spec_targets_spread_over_replicas_following_quota() {
        let mgr = PlacementManager::new(8, 4, 2, 8, 4);
        let plan = mgr.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        let hosts = plan.placement.gpus_of(0);
        assert!(hosts.len() >= 2, "hot expert must replicate");
        assert!(!plan.share.is_empty(), "counts plan carries quotas");
        let preds: Vec<Vec<Vec<u8>>> = vec![vec![vec![0]; 6]];
        let st = SpecTargets::build(&preds, &plan);
        let mut used: Vec<usize> = (0..6)
            .map(|t| st.target_for(0, t, 0).unwrap())
            .collect();
        // Every chosen host must hold positive quota for the expert (the
        // plan's balance is respected, not undone by a uniform rotation).
        for &w in &used {
            assert!(
                plan.share[0][w] > 0,
                "speculative target {w} has no quota for expert 0"
            );
        }
        used.sort_unstable();
        used.dedup();
        assert!(used.len() >= 2, "predicted tokens must spread over replicas");
    }
}
