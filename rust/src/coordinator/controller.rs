//! The online strategy controller (ADR 005): closes the GPS loop by
//! re-making the DOP/TEP/speculative decision *while serving*, from
//! measured metrics instead of launch-time assumptions.
//!
//! MoE-GPS's whole point is picking the optimal predictor design for a
//! system configuration — but expert-load distributions drift over a
//! serving lifetime, so a decision frozen at startup rots. Under
//! `serve --adaptive` the coordinator consults this controller at every
//! **replan boundary** (between prefill rounds; at the decode replan
//! cadence): the rolling [`OnlineCalibrator`] fits the last window of
//! `RoundMetrics`/`DecodeStepMetrics` into [`MeasuredConstants`], the
//! controller re-prices the strategies through the *same*
//! `gps::select::strategy_savings_in` path the static `advise` map uses
//! (measured skew, measured effective bandwidth, measured share error),
//! and — behind hysteresis, so a single noisy window never flips the
//! serving engine — switches DOP↔TEP, toggles the speculative scatter,
//! and adjusts the lookahead depth.
//!
//! **Determinism contract**: switches land only at layer-0 boundaries
//! (never mid-forward), so given the realized decision trace the run is
//! bitwise reproducible — and a controller whose decisions are pinned
//! ([`ControllerConfig::pinned`]) serves bitwise identically to the fixed
//! strategy (`tests/adaptive_gps.rs`). Every boundary's evaluation is
//! recorded as a [`DecisionRecord`] whether or not it switched, so the
//! decision trace in the report replays the whole control history.

use crate::gps::calibrate::{calibrate_all, WorkloadCalibration};
use crate::gps::online::{MeasuredConstants, OnlineCalibrator, WindowSample};
use crate::gps::select::{recommend, Recommendation, Regime, ServePhase};
use crate::model::ModelConfig;
use crate::sim::hardware::SystemSpec;
use crate::util::json::Value;

use super::metrics::{DecodeStepMetrics, RoundMetrics};
use super::server::ServeStrategy;

/// Knobs for the control loop (`serve --adaptive`).
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Consecutive boundaries a candidate strategy must win (with margin)
    /// before the switch lands — the hysteresis that keeps one noisy
    /// window from thrashing the engine.
    pub hysteresis: usize,
    /// Minimum relative saving margin (vs the current strategy, as a
    /// fraction of baseline latency) a challenger needs to count as a
    /// win at a boundary.
    pub margin_frac: f64,
    /// Samples the calibrator window must hold before the first decision.
    pub min_window: usize,
    /// Rolling-window capacity (samples).
    pub window: usize,
    /// Record decisions but never apply them — the parity configuration
    /// (adaptive-with-pinned-decision ≡ fixed-strategy, bitwise).
    pub pinned: bool,
    /// Sim model the decisions are priced on.
    pub model: ModelConfig,
    /// Baseline system spec; the measured effective bandwidth overrides
    /// its interconnect when the window moved replica bytes.
    pub system: SystemSpec,
    /// Which phase's cost model prices the decision.
    pub phase: ServePhase,
    /// Workload shape handed to the pricing (batch, seq-or-context).
    pub batch: usize,
    pub seq_or_ctx: usize,
    /// Realized top-k hit rate above which the speculative scatter is
    /// worth its repair traffic (TEP only); below `spec_off_below` it is
    /// switched back off.
    pub spec_on_above: f64,
    pub spec_off_below: f64,
    /// Lookahead depth bounds the controller may move within. Depth goes
    /// up when exposed transfer dominates the duplication traffic (the
    /// window is too small), down when a shallower window already hides
    /// everything. `min_lookahead` of 0 lets the controller leave a
    /// launched no-overlap configuration alone until measurements argue
    /// for prewarming; the CLI sets `max_lookahead` from `--lookahead`
    /// so a user-chosen deeper window is never silently cut.
    pub min_lookahead: usize,
    pub max_lookahead: usize,
    /// Launched proactive forecast horizon (`serve --horizon`, ADR 006);
    /// 0 = reactive replanning. Recorded so the decision trace shows what
    /// the fallback gave up.
    pub horizon: usize,
    /// Realized forecast L1 error above which the controller falls back
    /// to reactive replanning (horizon 0). One-way within a run: at
    /// horizon 0 no forecasts mature, so no error signal exists to argue
    /// for re-raising (ADR 006).
    pub forecast_error_max: f64,
    /// Seed for the offline calibration priors.
    pub seed: u64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            hysteresis: 2,
            margin_frac: 0.01,
            min_window: 4,
            window: 32,
            pinned: false,
            model: ModelConfig::mixtral_8x7b(),
            system: SystemSpec::four_a100_nvlink(),
            phase: ServePhase::Prefill,
            batch: 1,
            seq_or_ctx: 512,
            spec_on_above: 0.5,
            spec_off_below: 0.3,
            min_lookahead: 0,
            max_lookahead: 2,
            horizon: 0,
            forecast_error_max: 0.5,
            seed: 7,
        }
    }
}

/// What the coordinator applies at a boundary when the controller
/// switches: the full engine configuration, not a delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub strategy: ServeStrategy,
    pub speculative: bool,
    pub lookahead: usize,
    /// Proactive forecast horizon (0 = reactive — ADR 006).
    pub horizon: usize,
}

/// One boundary's evaluation — recorded whether or not it switched, so
/// the report's decision trace replays the whole control history.
#[derive(Clone, Debug)]
pub struct DecisionRecord {
    /// Round index (prefill) or step index (decode) of the boundary.
    pub boundary: usize,
    pub from: ServeStrategy,
    pub to: ServeStrategy,
    pub speculative: bool,
    pub lookahead: usize,
    /// Forecast horizon in force after this boundary (ADR 006).
    pub horizon: usize,
    pub switched: bool,
    /// The calibrated constants the decision was priced on.
    pub measured: MeasuredConstants,
    pub baseline_s: f64,
    pub dop_saving_s: f64,
    pub tep_saving_s: f64,
    pub reason: String,
}

impl DecisionRecord {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("boundary", Value::Num(self.boundary as f64))
            .set("from", Value::Str(self.from.name().into()))
            .set("to", Value::Str(self.to.name().into()))
            .set("speculative", Value::Bool(self.speculative))
            .set("lookahead", Value::Num(self.lookahead as f64))
            .set("horizon", Value::Num(self.horizon as f64))
            .set("switched", Value::Bool(self.switched))
            .set("measured", self.measured.to_json())
            .set("baseline_s", Value::Num(self.baseline_s))
            .set("dop_saving_s", Value::Num(self.dop_saving_s))
            .set("tep_saving_s", Value::Num(self.tep_saving_s))
            .set("reason", Value::Str(self.reason.clone()));
        v
    }
}

/// The controller's contribution to the serve report: the decision trace
/// plus the final calibrated constants.
#[derive(Clone, Debug, Default)]
pub struct ControllerReport {
    pub decisions: Vec<DecisionRecord>,
    pub final_strategy: String,
    pub calibrated: Option<MeasuredConstants>,
}

impl ControllerReport {
    pub fn switch_count(&self) -> usize {
        self.decisions.iter().filter(|d| d.switched).count()
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set(
            "decisions",
            Value::Arr(self.decisions.iter().map(DecisionRecord::to_json).collect()),
        )
        .set("final_strategy", Value::Str(self.final_strategy.clone()))
        .set(
            "calibrated",
            match &self.calibrated {
                Some(c) => c.to_json(),
                None => Value::Null,
            },
        )
        .set("switches", Value::Num(self.switch_count() as f64));
        v
    }
}

/// The online controller itself. Owns the rolling calibrator and the
/// offline calibration priors (predictor accuracy↔overhead fits, which
/// measurement cannot re-derive online — the measured constants override
/// everything the live loop *can* observe: skew, bandwidth, share error).
pub struct StrategyController {
    pub cfg: ControllerConfig,
    calibrator: OnlineCalibrator,
    cals: Vec<WorkloadCalibration>,
    /// Challenger strategy + how many consecutive boundaries it has won.
    pending: Option<(ServeStrategy, usize)>,
    decisions: Vec<DecisionRecord>,
}

impl StrategyController {
    /// Build a controller; runs the fast offline calibration once to get
    /// the accuracy↔overhead priors the measured constants refine.
    pub fn new(cfg: ControllerConfig) -> StrategyController {
        let cals = calibrate_all(&cfg.model, &cfg.system, true, cfg.seed);
        StrategyController::with_cals(cfg, cals)
    }

    /// Build with precomputed calibration priors (tests, repeated runs).
    pub fn with_cals(
        cfg: ControllerConfig,
        cals: Vec<WorkloadCalibration>,
    ) -> StrategyController {
        StrategyController {
            calibrator: OnlineCalibrator::new(cfg.window),
            cfg,
            cals,
            pending: None,
            decisions: Vec::new(),
        }
    }

    /// Feed one prefill round's metrics into the window.
    pub fn observe_round(&mut self, m: &RoundMetrics) {
        self.calibrator.push(WindowSample::from(m));
    }

    /// Feed one decode step's metrics into the window.
    pub fn observe_step(&mut self, m: &DecodeStepMetrics) {
        self.calibrator.push(WindowSample::from(m));
    }

    /// Feed a raw sample (tests, replayed traces).
    pub fn observe_sample(&mut self, s: WindowSample) {
        self.calibrator.push(s);
    }

    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.decisions
    }

    /// The report block for `metrics.rs`.
    pub fn report(&self, final_strategy: ServeStrategy) -> ControllerReport {
        ControllerReport {
            decisions: self.decisions.clone(),
            final_strategy: final_strategy.name().to_string(),
            calibrated: self.calibrator.constants(),
        }
    }

    /// Evaluate one replan boundary. Returns the decision the coordinator
    /// should apply, or `None` while the window is too thin, the winner
    /// is already serving, hysteresis is still counting, or the
    /// controller is pinned. Always appends a [`DecisionRecord`] once the
    /// window is thick enough, so the trace shows every evaluation.
    pub fn decide(
        &mut self,
        boundary: usize,
        current: ServeStrategy,
        speculative: bool,
        lookahead: usize,
        regime: Regime,
    ) -> Option<Decision> {
        if self.calibrator.len() < self.cfg.min_window {
            return None;
        }
        let measured = self.calibrator.constants()?;
        let cmp = measured.savings(
            self.cfg.phase,
            &self.cfg.model,
            &self.cfg.system,
            &self.cals,
            self.cfg.batch,
            self.cfg.seq_or_ctx,
            regime,
        );
        let winner = match recommend(&cmp) {
            Recommendation::DistributionOnly => ServeStrategy::DistributionOnly,
            Recommendation::TokenToExpert => ServeStrategy::TokenToExpert,
            Recommendation::NoPrediction => ServeStrategy::NoPrediction,
        };
        let saving_of = |s: ServeStrategy| match s {
            ServeStrategy::NoPrediction => 0.0,
            ServeStrategy::DistributionOnly => cmp.dop_saving_s,
            ServeStrategy::TokenToExpert => cmp.tep_best_saving_s,
        };
        let margin = (saving_of(winner) - saving_of(current)) / cmp.baseline_s.max(1e-12);
        let challenger = winner != current && margin >= self.cfg.margin_frac;

        // Hysteresis: the same challenger must win `hysteresis`
        // consecutive boundaries before the switch lands.
        let streak = match (&self.pending, challenger) {
            (Some((cand, n)), true) if *cand == winner => n + 1,
            (_, true) => 1,
            (_, false) => 0,
        };
        self.pending = if challenger { Some((winner, streak)) } else { None };
        let switch = challenger && streak >= self.cfg.hysteresis && !self.cfg.pinned;

        let strategy = if switch { winner } else { current };
        // Speculation rides TEP + lookahead; gate it on the *realized*
        // top-k hit rate so a predictor that stopped confirming stops
        // paying repair traffic.
        let new_spec = if strategy == ServeStrategy::TokenToExpert {
            match measured.tep_topk_hit {
                Some(hit) if hit >= self.cfg.spec_on_above => true,
                Some(hit) if hit < self.cfg.spec_off_below => false,
                _ => speculative,
            }
        } else {
            false
        };
        // Lookahead depth: deepen while exposed transfer dominates the
        // duplication traffic; never leave the configured bounds. Only
        // strategies that duplicate (and therefore transfer) care — the
        // baseline keeps whatever depth it was launched with.
        let mut new_lookahead = lookahead;
        if strategy != ServeStrategy::NoPrediction {
            new_lookahead =
                new_lookahead.clamp(self.cfg.min_lookahead, self.cfg.max_lookahead);
            // `upload_bytes > 0` rather than a measured bandwidth: a
            // no-lookahead window moves bytes only as cold uploads inside
            // `RunBatch`, which carry no transfer-stall seconds — exactly the
            // case where deepening helps most.
            if measured.upload_bytes > 0.0
                && measured.hidden_frac < 0.5
                && new_lookahead < self.cfg.max_lookahead
            {
                new_lookahead += 1;
            } else if measured.upload_bytes > 0.0
                && measured.hidden_frac > 0.95
                && new_lookahead > self.cfg.min_lookahead
            {
                new_lookahead -= 1;
            }
        }
        if new_spec {
            new_lookahead = new_lookahead.max(1);
        }

        // Forecast-error fallback (ADR 006): when the realized horizon
        // forecast error breaches the threshold, the forecast is hurting
        // more than a stale plan would — drop to reactive replanning.
        // One-way within a run: at horizon 0 no forecasts mature, so no
        // error signal exists to argue for re-raising.
        let cur_horizon = regime.horizon;
        let mut new_horizon = cur_horizon;
        let mut horizon_note = String::new();
        if cur_horizon > 0 {
            if let Some(err) = measured.forecast_error {
                if err > self.cfg.forecast_error_max {
                    new_horizon = 0;
                    horizon_note = format!(
                        "; forecast L1 {:.2} > {:.2} — falling back to \
                         reactive replanning (horizon {cur_horizon} -> 0)",
                        err, self.cfg.forecast_error_max
                    );
                }
            }
        }

        let changed = switch
            || (!self.cfg.pinned
                && (new_spec != speculative
                    || new_lookahead != lookahead
                    || new_horizon != cur_horizon));
        let (to, spec_out, depth_out, horizon_out) = if self.cfg.pinned {
            (current, speculative, lookahead, cur_horizon)
        } else {
            (strategy, new_spec, new_lookahead, new_horizon)
        };
        let base_reason = if switch {
            format!(
                "{} wins by {:.1}% of baseline at measured skew {:.2} \
                 (streak {streak}/{})",
                winner.name(),
                margin * 100.0,
                cmp.skewness,
                self.cfg.hysteresis
            )
        } else if challenger {
            format!(
                "{} challenging ({}/{} boundaries, margin {:.1}%)",
                winner.name(),
                streak,
                self.cfg.hysteresis,
                margin * 100.0
            )
        } else {
            format!("{} holds (margin {:.1}%)", current.name(), margin * 100.0)
        };
        self.decisions.push(DecisionRecord {
            boundary,
            from: current,
            to,
            speculative: spec_out,
            lookahead: depth_out,
            horizon: horizon_out,
            switched: switch,
            measured,
            baseline_s: cmp.baseline_s,
            dop_saving_s: cmp.dop_saving_s,
            tep_saving_s: cmp.tep_best_saving_s,
            reason: format!("{base_reason}{horizon_note}"),
        });
        if changed {
            Some(Decision {
                strategy: to,
                speculative: spec_out,
                lookahead: depth_out,
                horizon: horizon_out,
            })
        } else {
            None
        }
    }

    /// A worker died (ADR 008). The measured window that justified the
    /// current configuration described a fleet that no longer exists, so
    /// rather than wait for hysteresis to re-learn it: cancel any pending
    /// challenger streak and shed the optimistic extras — speculative
    /// scatter and lookahead prewarming both spend work on workers that
    /// may be the next to go, and the degraded replan needs the slots.
    /// The strategy itself is kept (the duplication plan *is* the
    /// failover table — dropping DOP/TEP now would shrink redundancy).
    /// Records a `WorkerLost` decision and returns the degraded
    /// configuration to apply, or `None` when nothing changes (already
    /// degraded, or the controller is pinned).
    pub fn note_worker_lost(
        &mut self,
        boundary: usize,
        current: ServeStrategy,
        speculative: bool,
        lookahead: usize,
        regime: Regime,
    ) -> Option<Decision> {
        self.pending = None;
        let new_spec = false;
        let new_lookahead = lookahead.min(self.cfg.min_lookahead);
        let changed =
            !self.cfg.pinned && (new_spec != speculative || new_lookahead != lookahead);
        let (spec_out, depth_out) = if self.cfg.pinned {
            (speculative, lookahead)
        } else {
            (new_spec, new_lookahead)
        };
        let measured = self.calibrator.constants().unwrap_or(MeasuredConstants {
            samples: 0,
            tokens: 0.0,
            tokens_per_s: 0.0,
            per_token_s: 0.0,
            mean_skew: 0.0,
            upload_bytes: 0.0,
            effective_bandwidth_gbs: None,
            dop_error: None,
            tep_topk_hit: None,
            tep_top1: None,
            hidden_frac: 0.0,
            refetch_frac: 0.0,
            predictor_frac: 0.0,
            forecast_error: None,
        });
        self.decisions.push(DecisionRecord {
            boundary,
            from: current,
            to: current,
            speculative: spec_out,
            lookahead: depth_out,
            horizon: regime.horizon,
            switched: false,
            measured,
            baseline_s: 0.0,
            dop_saving_s: 0.0,
            tep_saving_s: 0.0,
            reason: format!(
                "WorkerLost: fleet degraded — {} speculation and lookahead \
                 while survivors absorb the redispatched load",
                if changed { "shedding" } else { "holding" }
            ),
        });
        if changed {
            Some(Decision {
                strategy: current,
                speculative: spec_out,
                lookahead: depth_out,
                horizon: regime.horizon,
            })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gps::online::WindowSample;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            min_window: 2,
            hysteresis: 2,
            margin_frac: 0.0,
            ..Default::default()
        }
    }

    fn skew_sample(skew: f64) -> WindowSample {
        WindowSample {
            tokens: 64.0,
            total_s: 0.5,
            routing_skew: skew,
            pred_share_l1: 0.02,
            pred_share_layers: 2.0,
            ..Default::default()
        }
    }

    /// Cheap priors so unit tests never run the full calibration.
    fn test_controller(cfg: ControllerConfig) -> StrategyController {
        let cals = crate::gps::calibrate::calibrate_all(
            &cfg.model,
            &cfg.system,
            true,
            cfg.seed,
        );
        StrategyController::with_cals(cfg, cals)
    }

    #[test]
    fn no_decision_below_min_window() {
        let mut c = test_controller(cfg());
        c.observe_sample(skew_sample(2.0));
        assert!(c
            .decide(
                1,
                ServeStrategy::DistributionOnly,
                false,
                1,
                Regime::default()
            )
            .is_none());
        assert!(c.decisions().is_empty(), "thin window records nothing");
    }

    #[test]
    fn pinned_controller_never_switches_but_records() {
        let mut c = test_controller(ControllerConfig {
            pinned: true,
            ..cfg()
        });
        for _ in 0..6 {
            c.observe_sample(skew_sample(4.0));
        }
        for b in 1..4 {
            let d = c.decide(
                b,
                ServeStrategy::NoPrediction,
                false,
                0,
                Regime::default(),
            );
            assert!(d.is_none(), "pinned must never ask for a change");
        }
        assert_eq!(c.decisions().len(), 3, "every boundary recorded");
        assert!(c.decisions().iter().all(|d| !d.switched));
        assert!(c
            .decisions()
            .iter()
            .all(|d| d.to == ServeStrategy::NoPrediction));
    }

    #[test]
    fn hysteresis_delays_the_flip() {
        // High measured skew on NVLink: prediction strongly beats the
        // no-prediction baseline, so the controller wants to switch away
        // from NoPrediction — but only after `hysteresis` boundaries.
        let mut c = test_controller(cfg());
        for _ in 0..4 {
            c.observe_sample(skew_sample(3.0));
        }
        let first = c.decide(
            1,
            ServeStrategy::NoPrediction,
            false,
            1,
            Regime::default(),
        );
        assert!(first.is_none(), "streak 1 < hysteresis 2");
        let second = c.decide(
            2,
            ServeStrategy::NoPrediction,
            false,
            1,
            Regime::default(),
        );
        let d = second.expect("streak reached hysteresis");
        assert_ne!(d.strategy, ServeStrategy::NoPrediction);
        assert_eq!(c.decisions().len(), 2);
        assert!(!c.decisions()[0].switched);
        assert!(c.decisions()[1].switched);
    }

    #[test]
    fn forecast_error_breach_falls_back_to_reactive() {
        // Adversarial load: realized forecast L1 far above the threshold.
        let mut c = test_controller(cfg());
        for _ in 0..4 {
            c.observe_sample(WindowSample {
                forecast_l1: 1.2,
                forecast_layers: 2.0,
                ..skew_sample(1.0)
            });
        }
        let regime = Regime {
            horizon: 4,
            ..Regime::default()
        };
        let d = c
            .decide(1, ServeStrategy::DistributionOnly, false, 1, regime)
            .expect("horizon fallback must be applied");
        assert_eq!(d.horizon, 0, "breach must fall back to reactive");
        let rec = c.decisions().last().unwrap();
        assert_eq!(rec.horizon, 0);
        assert!(
            rec.reason.contains("falling back to reactive"),
            "fallback must be logged in the decision trace: {}",
            rec.reason
        );

        // Healthy forecasts keep the launched horizon.
        let mut ok = test_controller(cfg());
        for _ in 0..4 {
            ok.observe_sample(WindowSample {
                forecast_l1: 0.05,
                forecast_layers: 2.0,
                ..skew_sample(1.0)
            });
        }
        if let Some(d) = ok.decide(
            1,
            ServeStrategy::DistributionOnly,
            false,
            1,
            Regime {
                horizon: 4,
                ..Regime::default()
            },
        ) {
            assert_eq!(d.horizon, 4, "healthy forecast must not fall back");
        }
        assert_eq!(ok.decisions().last().unwrap().horizon, 4);
    }

    #[test]
    fn worker_loss_sheds_speculation_and_lookahead() {
        let mut c = test_controller(cfg());
        // Works even before the window is thick enough for `decide`.
        let d = c
            .note_worker_lost(3, ServeStrategy::TokenToExpert, true, 2, Regime::default())
            .expect("degrading from spec+lookahead must produce a decision");
        assert_eq!(d.strategy, ServeStrategy::TokenToExpert, "strategy kept");
        assert!(!d.speculative);
        assert_eq!(d.lookahead, 0);
        let rec = c.decisions().last().unwrap();
        assert!(rec.reason.contains("WorkerLost"), "{}", rec.reason);
        assert!(!rec.switched);
        // Already degraded: recorded again, but nothing to apply.
        assert!(c
            .note_worker_lost(4, ServeStrategy::TokenToExpert, false, 0, Regime::default())
            .is_none());
        assert_eq!(c.decisions().len(), 2);
    }

    #[test]
    fn pinned_controller_records_worker_loss_without_change() {
        let mut c = test_controller(ControllerConfig {
            pinned: true,
            ..cfg()
        });
        assert!(c
            .note_worker_lost(1, ServeStrategy::TokenToExpert, true, 2, Regime::default())
            .is_none());
        let rec = c.decisions().last().unwrap();
        assert!(rec.speculative, "pinned keeps the launched configuration");
        assert_eq!(rec.lookahead, 2);
    }

    #[test]
    fn report_carries_trace_and_constants() {
        let mut c = test_controller(cfg());
        for _ in 0..3 {
            c.observe_sample(skew_sample(2.0));
        }
        c.decide(
            1,
            ServeStrategy::DistributionOnly,
            false,
            1,
            Regime::default(),
        );
        let rep = c.report(ServeStrategy::DistributionOnly);
        assert_eq!(rep.decisions.len(), 1);
        assert_eq!(rep.final_strategy, "distribution-only");
        assert!(rep.calibrated.is_some());
        let json = rep.to_json();
        assert!(json.get("decisions").is_some());
        assert!(json.get("switches").is_some());
    }
}
