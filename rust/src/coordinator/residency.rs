//! The memory-budgeted residency subsystem (ADR 004): a per-worker,
//! capacity-bounded LRU over `(layer, expert)` replica weights.
//!
//! Before this module, coordinator-side residency was a grow-only set
//! (`worker::ResidentSets`) and `WorkerMsg::Evict` was never sent on the
//! serve path — duplication could only *add* weights, so sustained serving
//! under dynamic plans grew device memory without bound. The
//! [`ResidencyManager`] is that set refactored into a real cache:
//!
//! * **Admission** ([`ResidencyManager::admit`]) — marking a replica
//!   resident (prewarm issue or FFN dispatch to a cold pair) touches its
//!   LRU stamp and, when the per-worker byte cap is exceeded, selects
//!   least-recently-used *unpinned* victims. The caller (the pipeline)
//!   turns each victim into a [`super::worker::WorkerMsg::Evict`], which
//!   frees the engine-side weights, so the coordinator view and the
//!   engine view stay in lockstep (worker queues are FIFO).
//! * **Pinning** ([`ResidencyManager::pin_layers`]) — the active layer and
//!   every layer inside the in-flight prewarm window are pinned; their
//!   entries are never victims, so an eviction can never race a dispatch
//!   or an outstanding prewarm. If every resident entry is pinned the
//!   admission proceeds anyway (weights must be resident to compute —
//!   correctness over the cap) and `cap_overflows` records the breach.
//! * **Accounting** — evictions, refetches (re-admission of a previously
//!   evicted replica: the bytes the cap forced back onto the wire), and
//!   the per-worker resident-bytes high-water mark, all surfaced through
//!   `metrics.rs` per round/step.
//!
//! Determinism: residency moves bytes, never values — an evicted replica
//! re-uploads the identical weights on next use, so serving under any cap
//! is bitwise identical to unbounded serving (`tests/residency.rs`).
//!
//! The micro-batch wavefront (ADR 010) keeps several micro-batches of the
//! *same* layer in flight at once. That concurrency is invisible here by
//! construction: the active layer and the prewarm window stay pinned for
//! the whole wavefront window (pins are per layer, not per chunk), so a
//! later chunk's admission can never evict a replica an earlier chunk's
//! in-flight batch still computes against.

use std::collections::{BTreeSet, HashMap, HashSet};

/// Outcome of one [`ResidencyManager::admit`] call.
#[derive(Debug, Default)]
pub struct Admission {
    /// True when the replica was not previously resident on the worker
    /// (the caller owes a prewarm/upload for it).
    pub newly_resident: bool,
    /// `(layer, expert)` victims the cap forced out of this worker, in
    /// eviction order; the caller must send `WorkerMsg::Evict` for each.
    pub evicted: Vec<(usize, usize)>,
}

#[derive(Debug, Default)]
struct WorkerResidency {
    /// Resident `(layer, expert)` replicas with their last-used LRU stamp.
    last_used: HashMap<(usize, usize), u64>,
    /// Replicas this worker evicted at least once (refetch detection).
    ever_evicted: HashSet<(usize, usize)>,
    resident_bytes: u64,
    peak_bytes: u64,
}

/// Per-worker capacity-bounded LRU over `(layer, expert)` replica weights
/// (see the module docs for the full contract).
#[derive(Debug, Default)]
pub struct ResidencyManager {
    workers: Vec<WorkerResidency>,
    /// Per-worker byte budget for expert replica weights; `None` =
    /// unbounded (the pre-ADR-004 behaviour).
    cap_bytes: Option<u64>,
    /// Bytes of one `(layer, expert)` replica (the three FFN matrices).
    replica_bytes: u64,
    /// Monotone LRU clock, bumped on every touch.
    clock: u64,
    /// Layers whose entries are currently exempt from eviction.
    pinned_layers: BTreeSet<usize>,
    /// Replicas evicted to hold the cap (admission + plan-shrink).
    pub evictions: u64,
    /// Re-admissions of previously evicted replicas.
    pub refetches: u64,
    /// Bytes those refetches forced back onto the wire.
    pub refetch_bytes: u64,
    /// Admissions that exceeded the cap with every resident entry pinned.
    pub cap_overflows: u64,
}

impl ResidencyManager {
    pub fn new(n_workers: usize, replica_bytes: u64) -> ResidencyManager {
        ResidencyManager {
            workers: (0..n_workers).map(|_| WorkerResidency::default()).collect(),
            replica_bytes: replica_bytes.max(1),
            ..ResidencyManager::default()
        }
    }

    /// Set (or clear) the per-worker byte cap. Takes effect on the next
    /// admission; already-resident entries are not proactively evicted,
    /// but the high-water marks restart from current residency so the
    /// reported peak measures the new regime, not a pre-cap lifetime max
    /// (the `hwm ≤ cap` acceptance check must not false-fail after a cap
    /// is installed mid-run).
    pub fn set_cap(&mut self, cap_bytes: Option<u64>) {
        self.cap_bytes = cap_bytes;
        for w in &mut self.workers {
            w.peak_bytes = w.resident_bytes;
        }
    }

    pub fn cap_bytes(&self) -> Option<u64> {
        self.cap_bytes
    }

    pub fn replica_bytes(&self) -> u64 {
        self.replica_bytes
    }

    /// Pin a window of layers (the active layer plus the in-flight
    /// prewarm window); replaces the previous pin set.
    pub fn pin_layers(&mut self, layers: impl IntoIterator<Item = usize>) {
        self.pinned_layers = layers.into_iter().collect();
    }

    pub fn clear_pins(&mut self) {
        self.pinned_layers.clear();
    }

    pub fn contains(&self, worker: usize, layer: usize, expert: usize) -> bool {
        self.workers[worker].last_used.contains_key(&(layer, expert))
    }

    /// Refresh a resident replica's LRU stamp without the admission
    /// bookkeeping — [`Self::admit`]'s resident branch does this on the
    /// serve path; kept private so every external mutation pairs with the
    /// matching worker message.
    #[cfg(test)]
    fn touch(&mut self, worker: usize, layer: usize, expert: usize) {
        self.clock += 1;
        let clock = self.clock;
        if let Some(stamp) = self.workers[worker].last_used.get_mut(&(layer, expert)) {
            *stamp = clock;
        }
    }

    /// Make a replica resident on a worker (or refresh it), evicting LRU
    /// unpinned entries while the cap is exceeded. See [`Admission`].
    pub fn admit(&mut self, worker: usize, layer: usize, expert: usize) -> Admission {
        self.clock += 1;
        let clock = self.clock;
        let replica_bytes = self.replica_bytes;
        let cap = self.cap_bytes;
        let pinned = &self.pinned_layers;
        let w = &mut self.workers[worker];
        let mut outcome = Admission::default();
        if let Some(stamp) = w.last_used.get_mut(&(layer, expert)) {
            *stamp = clock;
            return outcome;
        }
        outcome.newly_resident = true;
        w.last_used.insert((layer, expert), clock);
        w.resident_bytes += replica_bytes;
        if w.ever_evicted.contains(&(layer, expert)) {
            self.refetches += 1;
            self.refetch_bytes += replica_bytes;
        }
        if let Some(cap) = cap {
            while w.resident_bytes > cap {
                // LRU victim among unpinned layers; ties break on the
                // smaller (layer, expert) key for determinism. The entry
                // being admitted is never its own victim — evicting it
                // would desync the caller's Evict-then-upload message
                // order from the coordinator view.
                let victim = w
                    .last_used
                    .iter()
                    .filter(|(&key, _)| {
                        key != (layer, expert) && !pinned.contains(&key.0)
                    })
                    .min_by_key(|(&key, &stamp)| (stamp, key))
                    .map(|(&key, _)| key);
                match victim {
                    Some(key) => {
                        w.last_used.remove(&key);
                        w.ever_evicted.insert(key);
                        w.resident_bytes -= replica_bytes;
                        self.evictions += 1;
                        outcome.evicted.push(key);
                    }
                    None => {
                        // Everything resident is pinned: correctness
                        // requires the weights, so breach the cap and
                        // record it.
                        self.cap_overflows += 1;
                        break;
                    }
                }
            }
        }
        w.peak_bytes = w.peak_bytes.max(w.resident_bytes);
        outcome
    }

    /// Drop a replica from the coordinator view (plan shrink); the caller
    /// owes the matching `WorkerMsg::Evict`. Pinned layers are refused.
    /// Returns whether the entry was resident (and is now gone).
    pub fn remove(&mut self, worker: usize, layer: usize, expert: usize) -> bool {
        if self.pinned_layers.contains(&layer) {
            return false;
        }
        let w = &mut self.workers[worker];
        if w.last_used.remove(&(layer, expert)).is_some() {
            w.ever_evicted.insert((layer, expert));
            w.resident_bytes -= self.replica_bytes;
            self.evictions += 1;
            true
        } else {
            false
        }
    }

    /// Mark a replica resident-unknown (ADR 008): a prewarm ack never
    /// arrived, so the coordinator no longer trusts that the weights are
    /// on the device. Unlike [`Self::remove`] this ignores pins (the
    /// entry may be in the active window — trusting a phantom residency
    /// is worse than re-uploading), counts no eviction (nothing was
    /// displaced by policy) and does not mark `ever_evicted` (a later
    /// upload is a cold transfer, not a refetch of something the cap
    /// pushed out). Returns whether the entry was tracked.
    pub fn invalidate(&mut self, worker: usize, layer: usize, expert: usize) -> bool {
        let Some(w) = self.workers.get_mut(worker) else {
            return false;
        };
        if w.last_used.remove(&(layer, expert)).is_some() {
            w.resident_bytes = w.resident_bytes.saturating_sub(self.replica_bytes);
            true
        } else {
            false
        }
    }

    /// A worker died (ADR 008): clear its entire coordinator-side view.
    /// No `WorkerMsg::Evict` is owed (the engine is gone with the
    /// thread), no evictions are counted, and `ever_evicted` history is
    /// dropped — the worker will never serve again, so refetch
    /// classification for it is meaningless. Returns the bytes
    /// reclaimed.
    pub fn reclaim_worker(&mut self, worker: usize) -> u64 {
        let Some(w) = self.workers.get_mut(worker) else {
            return 0;
        };
        let freed = w.resident_bytes;
        w.last_used.clear();
        w.ever_evicted.clear();
        w.resident_bytes = 0;
        freed
    }

    /// Resident experts of one worker for one layer (sorted).
    pub fn layer_experts(&self, worker: usize, layer: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self.workers[worker]
            .last_used
            .keys()
            .filter(|&&(l, _)| l == layer)
            .map(|&(_, e)| e)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn resident_bytes(&self, worker: usize) -> u64 {
        self.workers[worker].resident_bytes
    }

    pub fn resident_replicas(&self, worker: usize) -> usize {
        self.workers[worker].last_used.len()
    }

    /// Highest resident-bytes any worker ever reached.
    pub fn high_water_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.peak_bytes).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_per_layer_like_resident_sets() {
        // The grow-only ResidentSets contract this LRU absorbed (ADR 004).
        let mut r = ResidencyManager::new(2, 10);
        assert!(!r.contains(0, 1, 3));
        assert!(r.admit(0, 1, 3).newly_resident);
        assert!(!r.admit(0, 1, 3).newly_resident, "second admit is a touch");
        assert!(r.contains(0, 1, 3));
        assert!(!r.contains(1, 1, 3), "workers are independent");
        r.admit(0, 1, 1);
        r.admit(0, 2, 5);
        assert_eq!(r.layer_experts(0, 1), vec![1, 3]);
        assert_eq!(r.layer_experts(0, 2), vec![5]);
        assert!(r.remove(0, 1, 3));
        assert!(!r.contains(0, 1, 3));
        assert!(!r.remove(0, 1, 3), "double remove is a no-op");
    }

    #[test]
    fn unbounded_manager_never_evicts() {
        let mut r = ResidencyManager::new(1, 100);
        for layer in 0..10 {
            for expert in 0..8 {
                assert!(r.admit(0, layer, expert).evicted.is_empty());
            }
        }
        assert_eq!(r.evictions, 0);
        assert_eq!(r.resident_replicas(0), 80);
        assert_eq!(r.high_water_bytes(), 8000);
    }

    #[test]
    fn cap_evicts_lru_first() {
        let mut r = ResidencyManager::new(1, 100);
        r.set_cap(Some(250)); // room for 2 replicas
        r.admit(0, 0, 0);
        r.admit(0, 0, 1);
        r.touch(0, 0, 0); // expert 1 is now the LRU entry
        let out = r.admit(0, 1, 0);
        assert_eq!(out.evicted, vec![(0, 1)], "LRU victim must go first");
        assert!(r.contains(0, 0, 0) && r.contains(0, 1, 0));
        assert_eq!(r.resident_bytes(0), 200);
        assert_eq!(r.evictions, 1);
        assert!(r.high_water_bytes() <= 300, "one transient admit over cap");
    }

    #[test]
    fn pinned_layers_are_never_victims() {
        let mut r = ResidencyManager::new(1, 100);
        r.set_cap(Some(250));
        r.admit(0, 0, 0);
        r.admit(0, 1, 0);
        r.pin_layers([0, 1]);
        // Both residents pinned: the admission must breach the cap rather
        // than evict (correctness over cap) and record the overflow.
        let out = r.admit(0, 1, 1);
        assert!(out.evicted.is_empty());
        assert_eq!(r.cap_overflows, 1);
        assert_eq!(r.resident_replicas(0), 3);
        // Unpin layer 0: the next admission reclaims down to the cap.
        r.pin_layers([1, 2]);
        let out = r.admit(0, 2, 0);
        assert_eq!(out.evicted, vec![(0, 0)]);
        assert!(r.resident_bytes(0) > 250, "still over: layer-1 pins hold");
        r.clear_pins();
        let out = r.admit(0, 2, 1);
        assert_eq!(out.evicted.len(), 2, "unpinned now reclaims to cap");
        assert!(r.resident_bytes(0) <= 250);
    }

    #[test]
    fn refetch_accounting_counts_readmissions() {
        let mut r = ResidencyManager::new(1, 100);
        r.set_cap(Some(150));
        r.admit(0, 0, 0);
        r.admit(0, 0, 1); // evicts (0,0)
        assert_eq!(r.evictions, 1);
        assert_eq!(r.refetches, 0);
        r.admit(0, 0, 0); // refetch of the evicted replica (evicts (0,1))
        assert_eq!(r.refetches, 1);
        assert_eq!(r.refetch_bytes, 100);
        assert_eq!(r.evictions, 2);
    }

    #[test]
    fn invalidate_is_not_an_eviction() {
        let mut r = ResidencyManager::new(1, 100);
        r.admit(0, 0, 0);
        r.pin_layers([0]);
        // Pins don't protect phantom residency: invalidate still clears.
        assert!(r.invalidate(0, 0, 0));
        assert!(!r.contains(0, 0, 0));
        assert_eq!(r.resident_bytes(0), 0);
        assert_eq!(r.evictions, 0, "no policy eviction happened");
        assert!(!r.invalidate(0, 0, 0), "second invalidate is a no-op");
        r.clear_pins();
        // Re-admission after invalidation is a cold upload, not a refetch.
        assert!(r.admit(0, 0, 0).newly_resident);
        assert_eq!(r.refetches, 0);
        assert!(!r.invalidate(9, 0, 0), "out-of-range worker tolerated");
    }

    #[test]
    fn reclaim_worker_clears_everything_without_evictions() {
        let mut r = ResidencyManager::new(2, 100);
        r.admit(0, 0, 0);
        r.admit(0, 1, 2);
        r.admit(1, 0, 0);
        assert_eq!(r.reclaim_worker(0), 200);
        assert_eq!(r.resident_replicas(0), 0);
        assert_eq!(r.resident_bytes(0), 0);
        assert_eq!(r.evictions, 0);
        assert!(r.contains(1, 0, 0), "other workers untouched");
        assert_eq!(r.reclaim_worker(0), 0, "already empty");
        assert_eq!(r.reclaim_worker(9), 0, "out-of-range worker tolerated");
    }

    #[test]
    fn conservation_inserts_equal_resident_plus_evictions() {
        // Deterministic pseudo-random workload over 2 workers, 4 layers,
        // 8 experts: every insert either stays resident or was evicted
        // (cap victim or explicit remove — both count as evictions), so
        // inserts == resident + evictions at every point.
        let mut r = ResidencyManager::new(2, 10);
        r.set_cap(Some(55)); // 5 replicas per worker
        let mut inserts = 0u64;
        for i in 0..200usize {
            let worker = i % 2;
            let layer = (i * 7) % 4;
            let expert = (i * 13) % 8;
            r.pin_layers([layer]);
            if i % 11 == 0 {
                let victim_layer = (layer + 1) % 4;
                r.remove(worker, victim_layer, expert);
            } else if r.admit(worker, layer, expert).newly_resident {
                inserts += 1;
            }
            let resident: u64 = (0..2).map(|w| r.resident_replicas(w) as u64).sum();
            assert_eq!(inserts, resident + r.evictions, "step {i}");
            assert!(
                r.resident_bytes(worker) <= 55 + r.replica_bytes(),
                "at most one transient replica over cap while pinned"
            );
        }
        assert!(r.evictions > 0, "the cap must have bitten");
        assert!(r.refetches > 0, "the cycle must re-admit evicted replicas");
    }
}
