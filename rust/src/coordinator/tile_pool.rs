//! Reusable tile-buffer arena for the FFN dispatch path (ADR 003).
//!
//! `pipeline.rs::ffn_stage` gathers routed activations into bucket-padded
//! tiles, ships them to the virtual-GPU workers, and scatters the padded
//! outputs back — before this pool, every (worker, expert) group on every
//! layer of every step heap-allocated its gather tile, its padded copy
//! and its scatter buffer. The pool recycles those buffers across layers
//! and steps: `take` hands out a cleared buffer with enough capacity
//! (reuse) or allocates one (alloc), and the worker reply path returns
//! both the input tile and the FFN output buffer via [`TilePool::put`].
//! In steady state (stable routing → stable bucket mix) the dispatch path
//! performs **zero** per-layer heap allocation for tiles — the invariant
//! `tests/zero_alloc_dispatch.rs` pins down via the alloc/reuse counters
//! that `metrics.rs` reports.
//!
//! **Aging** (ADR 004): free buffers are stamped with the pool clock when
//! returned; [`TilePool::tick`] — called by the pipeline once per serving
//! round/step, the same cadence the residency LRU ages on — drops buffers
//! that sat unused for [`MAX_FREE_AGE`] ticks. A bucket-mix shift (batch
//! shrink, routing drift) therefore releases its stranded capacity
//! classes instead of holding them for the process lifetime.
//!
//! Determinism: the pool only changes *where* bytes live, never their
//! values — `take` clears the buffer and callers rewrite every row (real
//! rows copied, padding explicitly zero-filled), so the pooled path is
//! bitwise identical to fresh allocation.

use std::collections::BTreeMap;

/// Keep at most this many free buffers per capacity class; beyond it,
/// returned buffers are dropped (bounds pool memory under bucket churn).
const MAX_FREE_PER_CLASS: usize = 64;

/// Free buffers untouched for this many [`TilePool::tick`]s are dropped.
/// One tick per serving round/step, so a capacity class the bucket mix
/// stopped producing is released within ~this many rounds.
pub const MAX_FREE_AGE: u64 = 32;

/// A capacity-keyed free list of `Vec<f32>` buffers with alloc/reuse
/// accounting and clock-based aging.
#[derive(Debug, Default)]
pub struct TilePool {
    /// Free buffers keyed by their capacity, each stamped with the tick
    /// it was returned on.
    free: BTreeMap<usize, Vec<(u64, Vec<f32>)>>,
    /// Aging clock; one tick per serving round/step.
    clock: u64,
    /// Buffers handed out that had to be freshly allocated.
    pub allocs: u64,
    /// Buffers handed out from the free list.
    pub reuses: u64,
    /// Free buffers dropped by aging (idle > [`MAX_FREE_AGE`] ticks).
    pub aged_out: u64,
    /// Buffers abandoned with a dead worker (ADR 008): shipped in a
    /// dispatch whose reply never came back, so they can't be recycled.
    pub lost: u64,
    /// Buffers currently checked out via [`TilePool::take`] and not yet
    /// returned ([`TilePool::put_taken`]) or written off
    /// ([`TilePool::note_lost`]) — the live-slab gauge the wavefront's
    /// concurrent micro-batches move (ADR 010).
    pub outstanding: u64,
    /// High-water mark of `outstanding` since the last
    /// [`TilePool::take_peak`]: how many slabs were in flight at once.
    /// Without this the wavefront could balloon the arena silently — the
    /// pipeline samples it per layer into `tile_peak` on the metrics.
    pub peak_outstanding: u64,
}

impl TilePool {
    pub fn new() -> TilePool {
        TilePool::default()
    }

    /// An empty buffer with capacity ≥ `cap`: the smallest pooled buffer
    /// that fits, else a fresh allocation. The returned buffer has
    /// `len() == 0`; callers fill it and hand it back via [`Self::put`].
    pub fn take(&mut self, cap: usize) -> Vec<f32> {
        let key = self
            .free
            .range(cap..)
            .find(|(_, list)| !list.is_empty())
            .map(|(&k, _)| k);
        self.outstanding += 1;
        self.peak_outstanding = self.peak_outstanding.max(self.outstanding);
        if let Some(k) = key {
            let list = self.free.get_mut(&k).expect("key just found");
            let (_, mut buf) = list.pop().expect("non-empty list");
            if list.is_empty() {
                self.free.remove(&k);
            }
            buf.clear();
            self.reuses += 1;
            return buf;
        }
        self.allocs += 1;
        Vec::with_capacity(cap)
    }

    /// Return a buffer that was checked out via [`Self::take`]: decrements
    /// the outstanding gauge, then pools it like [`Self::put`]. Buffers
    /// that entered the data plane elsewhere (the workers allocate their
    /// own FFN output buffers) go back through plain [`Self::put`], which
    /// leaves the gauge alone.
    pub fn put_taken(&mut self, buf: Vec<f32>) {
        self.outstanding = self.outstanding.saturating_sub(1);
        self.put(buf);
    }

    /// A taken buffer died with its worker (ADR 008): count the loss and
    /// drop it from the outstanding gauge. If the straggler reply shows up
    /// after all, its tile re-enters the pool via plain [`Self::put`] so
    /// the write-off is never double-counted.
    pub fn note_lost(&mut self) {
        self.lost += 1;
        self.outstanding = self.outstanding.saturating_sub(1);
    }

    /// Read-and-rearm the outstanding high-water mark: returns the peak
    /// since the previous call and resets it to the *current* outstanding
    /// count. The pipeline samples this once per layer into `tile_peak`.
    pub fn take_peak(&mut self) -> u64 {
        let peak = self.peak_outstanding;
        self.peak_outstanding = self.outstanding;
        peak
    }

    /// Return a buffer to the pool, keyed by its capacity and stamped with
    /// the current tick. Zero-capacity buffers (e.g. error-path
    /// placeholders) are dropped.
    pub fn put(&mut self, buf: Vec<f32>) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        let list = self.free.entry(cap).or_default();
        if list.len() < MAX_FREE_PER_CLASS {
            list.push((self.clock, buf));
        }
    }

    /// Advance the aging clock one round/step and drop free buffers that
    /// have sat idle longer than `max_age` ticks.
    pub fn tick_with_age(&mut self, max_age: u64) {
        self.clock += 1;
        let clock = self.clock;
        let mut aged = 0u64;
        self.free.retain(|_, list| {
            let before = list.len();
            list.retain(|&(stamp, _)| clock.saturating_sub(stamp) <= max_age);
            aged += (before - list.len()) as u64;
            !list.is_empty()
        });
        self.aged_out += aged;
    }

    /// [`Self::tick_with_age`] at the default [`MAX_FREE_AGE`].
    pub fn tick(&mut self) {
        self.tick_with_age(MAX_FREE_AGE);
    }

    /// Free buffers currently pooled (across all capacity classes).
    pub fn pooled(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_prefers_reuse_and_counts() {
        let mut pool = TilePool::new();
        let mut a = pool.take(128);
        assert_eq!(pool.allocs, 1);
        a.resize(128, 1.0);
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.take(64); // smaller request still reuses the buffer
        assert_eq!(pool.reuses, 1);
        assert_eq!(b.len(), 0, "reused buffers come back cleared");
        assert!(b.capacity() >= cap.min(128));
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn take_allocates_when_nothing_fits() {
        let mut pool = TilePool::new();
        let a = pool.take(16);
        pool.put(a);
        let b = pool.take(1024); // pooled 16-cap buffer does not fit
        assert!(b.capacity() >= 1024);
        assert_eq!(pool.allocs, 2);
        assert_eq!(pool.reuses, 0);
        assert_eq!(pool.pooled(), 1, "small buffer stays pooled");
    }

    #[test]
    fn put_drops_empty_and_bounds_classes() {
        let mut pool = TilePool::new();
        pool.put(Vec::new());
        assert_eq!(pool.pooled(), 0);
        for _ in 0..(MAX_FREE_PER_CLASS + 10) {
            pool.put(Vec::with_capacity(8));
        }
        assert!(pool.pooled() <= MAX_FREE_PER_CLASS);
    }

    #[test]
    fn aging_drops_idle_buffers_but_keeps_fresh_ones() {
        let mut pool = TilePool::new();
        pool.put(Vec::with_capacity(8)); // stamped at tick 0
        for _ in 0..3 {
            pool.tick_with_age(3);
        }
        assert_eq!(pool.pooled(), 1, "within max_age the buffer survives");
        pool.put(Vec::with_capacity(16)); // stamped at tick 3
        pool.tick_with_age(3); // tick 4: the tick-0 buffer ages out
        assert_eq!(pool.pooled(), 1, "only the fresh buffer survives");
        assert_eq!(pool.aged_out, 1);
        assert!(pool.take(16).capacity() >= 16, "fresh buffer still usable");
        assert_eq!(pool.reuses, 1);
    }

    #[test]
    fn outstanding_gauge_tracks_takes_returns_and_losses() {
        let mut pool = TilePool::new();
        let a = pool.take(8);
        let b = pool.take(8);
        let c = pool.take(8);
        assert_eq!(pool.outstanding, 3);
        assert_eq!(pool.take_peak(), 3, "peak reports the high-water mark");
        pool.put_taken(a);
        assert_eq!(pool.outstanding, 2);
        // A worker-allocated output buffer returned via plain put leaves
        // the gauge alone — only slab takes are outstanding.
        pool.put(Vec::with_capacity(8));
        assert_eq!(pool.outstanding, 2);
        pool.note_lost(); // b died with its worker
        assert_eq!(pool.lost, 1);
        assert_eq!(pool.outstanding, 1);
        drop(b);
        pool.put_taken(c);
        assert_eq!(pool.outstanding, 0);
        // The first take_peak re-armed the mark at the then-current 3;
        // nothing exceeded it since, so the next read still reports 3.
        assert_eq!(pool.take_peak(), 3);
    }

    #[test]
    fn take_peak_rearms_to_current_outstanding() {
        let mut pool = TilePool::new();
        let a = pool.take(8);
        let _b = pool.take(8);
        pool.put_taken(a);
        assert_eq!(pool.take_peak(), 2);
        // One buffer still out: the re-armed peak starts there, and a
        // single further take peaks at 2 again, not 3.
        let _c = pool.take(8);
        assert_eq!(pool.take_peak(), 2);
    }

    #[test]
    fn reuse_refreshes_the_age_stamp() {
        let mut pool = TilePool::new();
        pool.put(Vec::with_capacity(8));
        pool.tick_with_age(2);
        // Take + return: the buffer's stamp moves to the current tick.
        let b = pool.take(8);
        pool.put(b);
        pool.tick_with_age(2);
        pool.tick_with_age(2);
        assert_eq!(pool.pooled(), 1, "refreshed stamp keeps it alive");
        pool.tick_with_age(2);
        assert_eq!(pool.pooled(), 0, "idle again long enough: dropped");
        assert_eq!(pool.aged_out, 1);
    }
}
