//! The serving coordinator (Layer 3): a leader thread driving embed /
//! attention / routing through PJRT, plus N "virtual GPU" worker threads
//! each owning their own PJRT engine and executing expert-FFN artifacts
//! under Expert Parallelism. The paper's machinery — prediction, dynamic
//! expert duplication (Algorithm 1), quota dispatch — runs on the batch
//! hot path in [`placement_mgr`] and [`server`].
//!
//! Python never appears here: every tensor op goes through AOT-compiled
//! HLO (see `runtime`).

pub mod batcher;
pub mod metrics;
pub mod placement_mgr;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::Batcher;
pub use metrics::{RoundMetrics, ServeReport};
pub use request::Request;
pub use server::{Coordinator, ServeStrategy};
