//! The serving coordinator (Layer 3): a leader thread driving embed /
//! attention / routing through the runtime engine, plus N "virtual GPU"
//! worker threads each owning their own engine and executing expert-FFN
//! artifacts under Expert Parallelism. The paper's machinery — prediction,
//! dynamic expert duplication (Algorithm 1), quota dispatch — runs on the
//! batch hot path in [`placement_mgr`] and [`server`].
//!
//! Two serving modes (DESIGN.md §4) over one stage-based layer engine
//! ([`pipeline`], ADR 002 — including the lookahead overlap that hides
//! duplication transfers and next-layer planning under compute):
//!
//! * **prefill rounds** — [`Batcher`] closes rounds of whole sequences;
//!   one `serve_round` call runs everything once (the paper's Figure-3
//!   setting);
//! * **continuous-batching decode** — [`scheduler::Scheduler`] admits and
//!   evicts requests per step; `serve_decode` generates one token per
//!   active sequence per step over per-sequence KV caches, with per-step
//!   Distribution-Only estimator updates and cadenced replanning
//!   (`docs/adr/001-decode-prediction-cadence.md`).
//!
//! Python never appears here: every tensor op goes through the runtime
//! engine (AOT-compiled HLO under `--features pjrt`, the pure-rust
//! reference backend otherwise — see `runtime`).

pub mod batcher;
pub mod controller;
pub mod faults;
pub mod metrics;
pub mod pipeline;
pub mod placement_mgr;
pub mod predict;
pub mod request;
pub mod residency;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod tile_pool;
pub mod worker;

pub use batcher::Batcher;
pub use controller::{
    ControllerConfig, ControllerReport, Decision, DecisionRecord, StrategyController,
};
pub use faults::{FaultPlan, WorkerHealth};
pub use metrics::{
    CopyStats, DecodeReport, DecodeStepMetrics, RoundMetrics, ServeReport, WavefrontStats,
};
pub use request::Request;
pub use residency::ResidencyManager;
pub use scheduler::Scheduler;
pub use server::{Coordinator, DecodeOptions, ServeStrategy};
