//! Dynamic batcher: groups queued requests into serving rounds.
//!
//! A round is up to `max_seqs` sequences processed together — attention
//! runs per sequence, but all sequences' routed tokens share one expert
//! dispatch (bigger FFN batches, better bucket utilisation — the batching
//! benefit EP serving actually gets). Rounds close on size or deadline,
//! vLLM-style.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_seqs: usize,
    pub max_wait: Duration,
    oldest_enqueue: Option<Instant>,
}

impl Batcher {
    pub fn new(max_seqs: usize, max_wait: Duration) -> Batcher {
        Batcher {
            queue: VecDeque::new(),
            max_seqs,
            max_wait,
            oldest_enqueue: None,
        }
    }

    pub fn push(&mut self, req: Request) {
        if self.queue.is_empty() {
            self.oldest_enqueue = Some(Instant::now());
        }
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a round should close now.
    pub fn ready(&self) -> bool {
        if self.queue.len() >= self.max_seqs {
            return true;
        }
        match self.oldest_enqueue {
            Some(t) => !self.queue.is_empty() && t.elapsed() >= self.max_wait,
            None => false,
        }
    }

    /// Pop the next round (up to `max_seqs` requests, FIFO — arrival order
    /// is preserved within and across rounds).
    pub fn next_round(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.max_seqs);
        let round: Vec<Request> = self.queue.drain(..n).collect();
        self.oldest_enqueue = if self.queue.is_empty() {
            None
        } else {
            Some(Instant::now())
        };
        round
    }

    /// Drain everything in FIFO rounds (offline/driver mode).
    pub fn drain_rounds(&mut self) -> Vec<Vec<Request>> {
        let mut rounds = Vec::new();
        while !self.queue.is_empty() {
            rounds.push(self.next_round());
        }
        rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3])
    }

    #[test]
    fn closes_on_size() {
        let mut b = Batcher::new(2, Duration::from_secs(100));
        b.push(req(0));
        assert!(!b.ready());
        b.push(req(1));
        assert!(b.ready());
        let round = b.next_round();
        assert_eq!(round.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn closes_on_deadline() {
        let mut b = Batcher::new(100, Duration::from_millis(1));
        b.push(req(0));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..5 {
            b.push(req(i));
        }
        let rounds = b.drain_rounds();
        assert_eq!(rounds.len(), 3);
        let order: Vec<u64> = rounds.iter().flatten().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }
}
