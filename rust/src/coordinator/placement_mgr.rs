//! Predictor-driven expert placement: the paper's mechanism on the live
//! serving path.
//!
//! Before each round's FFN phases, the manager produces a per-layer
//! duplication plan from whichever prediction strategy is active:
//!
//! * **NoPrediction** — the static initial placement; dispatch follows the
//!   expert's home GPU (the baseline whose load imbalance the paper
//!   quantifies).
//! * **DistributionOnly** — a multinomial-MLE estimate of each layer's
//!   expert distribution (updated online from every observed batch — the
//!   "moving average" of §3.2.1) feeds Algorithm 1 with *expected* counts.
//! * **TokenToExpert** — the AOT-compiled FFN predictor (trained in
//!   python, executed through PJRT) predicts every token's expert per
//!   layer *before attention runs* (§3.1), giving Algorithm 1 exact
//!   predicted counts and the dispatcher per-(expert, GPU) quotas.

use super::predict::expected_counts;
use crate::duplication::algorithm::{balance, BalanceResult};
use crate::duplication::placement::Placement;
use crate::predictor::distribution::DistributionEstimator;
use crate::predictor::forecast::LoadForecaster;
use crate::predictor::Predictor;
use crate::util::stats;

/// A forecast issued at plan time, waiting for reality to catch up: when
/// `due_in` more observations have arrived for its layer, the forecast
/// shares are scored (L1) against the actually routed distribution —
/// the *realized* forecast error the controller's reactive fallback and
/// the online calibrator consume (ADR 006).
#[derive(Clone, Debug)]
struct PendingForecast {
    shares: Vec<f64>,
    due_in: usize,
}

/// Per-layer plan for one round.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub placement: Placement,
    /// Per-(expert, gpu) token quotas (empty for NoPrediction).
    pub share: Vec<Vec<usize>>,
    /// Predicted per-expert counts the plan was built from.
    pub predicted_counts: Vec<usize>,
    /// Replicas added vs the static placement (duplication transfers).
    pub added: Vec<(usize, usize)>,
}

pub struct PlacementManager {
    pub n_experts: usize,
    pub n_workers: usize,
    /// Expert-slot capacity per worker (memory constraint M_g).
    pub capacity: usize,
    /// Maximum copies per expert (C_max).
    pub max_copies: usize,
    /// Online estimators, one per layer (Distribution-Only state).
    pub estimators: Vec<DistributionEstimator>,
    /// Load-trajectory forecasters, one per layer (ADR 006) — fed from
    /// the same `observe` stream as the estimators, consulted instead of
    /// them when `horizon > 0`.
    pub forecasters: Vec<LoadForecaster>,
    /// Proactive replanning horizon in observe-steps (ADR 006): 0 (the
    /// default) plans reactively from the current estimate — bitwise
    /// identical to pre-forecasting serving; `h > 0` plans for the
    /// forecast distribution `h` steps ahead, so replicas for
    /// predicted-hot experts are in the plan (and prewarmed by the
    /// lookahead machinery) *before* their load peaks.
    pub horizon: usize,
    /// Per-layer forecast awaiting its realization (scored in `observe`).
    pending_forecasts: Vec<Option<PendingForecast>>,
    /// Realized forecast L1 errors since the last drain.
    realized_forecast_l1s: Vec<f64>,
    static_placement: Placement,
    /// Decode-phase replan cadence: rebuild the Algorithm-1 plans every
    /// `replan_interval` steps and reuse them in between, amortising the
    /// planning cost and the duplication transfers it triggers (expert
    /// load is near-stationary across decode iterations — see
    /// `docs/adr/001-decode-prediction-cadence.md`). 1 = replan per step.
    pub replan_interval: usize,
    /// Cached decode plans: (step they were built at, per-layer plans).
    cached_decode_plans: Option<(usize, Vec<LayerPlan>)>,
    /// Last placement handed to the pipeline, per layer — the baseline
    /// [`PlacementManager::note_plan`] diffs against to detect replicas a
    /// new plan dropped (plan-shrink evictions, ADR 004).
    last_placements: Vec<Option<Placement>>,
}

impl PlacementManager {
    pub fn new(
        n_experts: usize,
        n_workers: usize,
        n_layers: usize,
        capacity: usize,
        max_copies: usize,
    ) -> PlacementManager {
        PlacementManager {
            n_experts,
            n_workers,
            capacity,
            max_copies,
            estimators: (0..n_layers)
                .map(|_| DistributionEstimator::new(n_experts))
                .collect(),
            forecasters: (0..n_layers)
                .map(|_| LoadForecaster::new(n_experts))
                .collect(),
            horizon: 0,
            pending_forecasts: (0..n_layers).map(|_| None).collect(),
            realized_forecast_l1s: Vec::new(),
            static_placement: Placement::initial(n_experts, n_workers, capacity, max_copies),
            replan_interval: 1,
            cached_decode_plans: None,
            last_placements: (0..n_layers).map(|_| None).collect(),
        }
    }

    pub fn static_plan(&self) -> LayerPlan {
        LayerPlan {
            placement: self.static_placement.clone(),
            share: Vec::new(),
            predicted_counts: Vec::new(),
            added: Vec::new(),
        }
    }

    /// Plan from predicted per-expert counts (both strategies reduce to
    /// this: DOP converts its probability estimate into expected counts,
    /// TEP counts its per-token predictions).
    pub fn plan_from_counts(&self, counts: &[usize]) -> LayerPlan {
        let result: BalanceResult = balance(counts, &self.static_placement);
        LayerPlan {
            added: self.static_placement.added_replicas(&result.placement),
            placement: result.placement,
            share: result.share,
            predicted_counts: counts.to_vec(),
            // `loads`/`iterations` are derivable; keep the plan lean.
        }
    }

    /// DOP plan for a layer: expected counts = p̂ · total_slots, via the
    /// unified predictor surface (`predict_distribution` + the shared
    /// share→counts conversion in `coordinator::predict`, ADR 005).
    ///
    /// With `horizon > 0` (ADR 006) the shares come from the layer's
    /// load-trajectory forecaster instead — the plan is built for the
    /// *forecast* distribution `horizon` observe-steps ahead (proactive
    /// replanning), and the forecast is parked for realized-error scoring
    /// when reality catches up (`observe` → `drain_forecast_errors`).
    /// `horizon == 0` takes the exact pre-forecasting estimator path, so
    /// reactive serving stays bitwise identical.
    pub fn plan_distribution_only(&mut self, layer: usize, total_slots: usize) -> LayerPlan {
        let probs = if self.horizon == 0 {
            self.estimators[layer].predict_distribution()
        } else {
            let shares = self.forecasters[layer].predict_horizon(self.horizon);
            // One in-flight forecast per layer: when replanning outpaces
            // the horizon (e.g. prefill replans every round), the parked
            // forecast rides to maturity and the next one parks after it
            // scores — never overwritten, or horizon ≥ 2 would go
            // unmeasured.
            if self.pending_forecasts[layer].is_none() {
                self.pending_forecasts[layer] = Some(PendingForecast {
                    shares: shares.clone(),
                    due_in: self.horizon,
                });
            }
            shares
        };
        self.plan_from_counts(&expected_counts(&probs, total_slots))
    }

    /// Feed observed routing back into the estimators (the moving average
    /// keeps improving while serving — §3.2.1) through the trait's
    /// `observe` hook, fed from the pipeline's router-settle stage. The
    /// forecasters ride the same stream (warm even while `horizon == 0`,
    /// so the controller can raise the horizon mid-run), and a pending
    /// forecast whose target step has arrived is scored here: the L1
    /// between what was forecast at plan time and what actually routed —
    /// the *realized* forecast error (ADR 006).
    pub fn observe(&mut self, layer: usize, actual_counts: &[usize]) {
        self.estimators[layer].observe(actual_counts);
        self.forecasters[layer].observe(actual_counts);
        if let Some(p) = self.pending_forecasts[layer].as_mut() {
            if p.due_in <= 1 {
                let total: usize = actual_counts.iter().sum();
                if total > 0 {
                    let actual: Vec<f64> = actual_counts
                        .iter()
                        .map(|&c| c as f64 / total as f64)
                        .collect();
                    self.realized_forecast_l1s
                        .push(stats::l1_distance(&p.shares, &actual));
                }
                self.pending_forecasts[layer] = None;
            } else {
                p.due_in -= 1;
            }
        }
    }

    /// Mean realized forecast L1 error and the number of scored layer
    /// forecasts since the last drain (cleared on read). The caller folds
    /// these into the round/step metrics; `(0.0, 0)` = nothing matured.
    pub fn drain_forecast_errors(&mut self) -> (f64, usize) {
        let n = self.realized_forecast_l1s.len();
        if n == 0 {
            return (0.0, 0);
        }
        let mean = stats::mean(&self.realized_forecast_l1s);
        self.realized_forecast_l1s.clear();
        (mean, n)
    }

    /// Whether the decode cadence rebuilds plans at `step`.
    pub fn replans_at(&self, step: usize) -> bool {
        match &self.cached_decode_plans {
            None => true,
            Some((built_at, _)) => step >= built_at + self.replan_interval.max(1),
        }
    }

    /// Distribution-Only plans for one decode step, under the replan
    /// cadence: every `replan_interval` steps the per-layer plans are
    /// rebuilt from the current estimators; in between the cached plans are
    /// reused (their quotas scale by least-loaded overflow in dispatch, so
    /// a slightly stale `total_slots` only softens the quota split).
    pub fn decode_plans(&mut self, step: usize, total_slots: usize) -> Vec<LayerPlan> {
        if !self.replans_at(step) {
            if let Some((_, plans)) = &self.cached_decode_plans {
                return plans.clone();
            }
        }
        let plans: Vec<LayerPlan> = (0..self.estimators.len())
            .map(|l| self.plan_distribution_only(l, total_slots))
            .collect();
        self.cached_decode_plans = Some((step, plans.clone()));
        plans
    }

    /// Drop cached decode plans (start of a new serving run).
    pub fn reset_decode_plans(&mut self) {
        self.cached_decode_plans = None;
    }

    /// Forget the plan-diff baseline (all layers). Called when a memory
    /// cap is installed mid-run, so the first capped round diffs against
    /// nothing instead of against placements noted under different rules.
    pub fn reset_plan_baseline(&mut self) {
        for slot in &mut self.last_placements {
            *slot = None;
        }
    }

    /// A worker died (ADR 008): remove it from the host set every future
    /// plan is balanced from — its capacity drops to zero so no replica
    /// is ever placed there again, and experts it sole-hosted are
    /// re-homed onto survivors (their canonical copy uploads cold on
    /// first use). Cached decode plans are dropped so the very next step
    /// replans out-of-cadence, re-replicating orphaned hot experts onto
    /// the surviving workers, and the plan-diff baseline is reset so the
    /// degraded plans are not diffed against pre-death placements.
    /// Returns the re-homed `(expert, gpu)` pairs.
    pub fn note_worker_death(&mut self, worker: usize) -> Vec<(usize, usize)> {
        let rehomed = self.static_placement.fail_gpu(worker);
        self.cached_decode_plans = None;
        self.reset_plan_baseline();
        rehomed
    }

    /// Record the placement a layer is about to serve under and return the
    /// `(expert, gpu)` replicas the *previous* plan hosted that this one no
    /// longer does — the plan-shrink eviction set (ADR 004). Only called
    /// while a memory cap is active (uncapped serving skips the clone).
    /// Under memory pressure the coordinator turns each into a
    /// `WorkerMsg::Evict`; without a cap the residency LRU keeps dropped
    /// replicas warm as cache instead.
    pub fn note_plan(&mut self, layer: usize, placement: &Placement) -> Vec<(usize, usize)> {
        // Steady state (cached decode plans, static placements) re-notes
        // an identical placement every step: skip the clone entirely.
        if self.last_placements[layer].as_ref() == Some(placement) {
            return Vec::new();
        }
        let removed = match &self.last_placements[layer] {
            Some(prev) => prev
                .pairs()
                .filter(|&&(expert, gpu)| !placement.hosts(expert, gpu))
                .copied()
                .collect(),
            None => Vec::new(),
        };
        self.last_placements[layer] = Some(placement.clone());
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> PlacementManager {
        PlacementManager::new(8, 4, 4, 8, 4)
    }

    #[test]
    fn static_plan_has_no_duplicates() {
        let m = mgr();
        let plan = m.static_plan();
        for e in 0..8 {
            assert_eq!(plan.placement.copies(e), 1);
        }
        assert!(plan.added.is_empty());
    }

    #[test]
    fn skewed_counts_trigger_duplication() {
        let m = mgr();
        let plan = m.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        assert!(plan.placement.copies(0) > 1, "hot expert must replicate");
        assert!(!plan.added.is_empty());
        // Quotas conserve tokens.
        let total: usize = plan.share.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(total, 880);
        plan.placement.check_invariants().unwrap();
    }

    #[test]
    fn dop_plan_tracks_estimator() {
        let mut m = mgr();
        // Feed a heavy skew toward expert 2 for layer 1.
        for _ in 0..20 {
            m.observe(1, &[10, 10, 300, 10, 10, 10, 10, 10]);
        }
        let plan = m.plan_distribution_only(1, 512);
        assert_eq!(plan.predicted_counts.iter().sum::<usize>(), 512);
        let max_idx = plan
            .predicted_counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        assert_eq!(max_idx, 2);
        assert!(plan.placement.copies(2) > 1);
    }

    #[test]
    fn fresh_estimator_plans_uniform() {
        let mut m = mgr();
        let plan = m.plan_distribution_only(0, 512);
        assert_eq!(plan.predicted_counts.iter().sum::<usize>(), 512);
        assert!(plan.added.is_empty(), "uniform estimate needs no replicas");
    }

    #[test]
    fn decode_cadence_reuses_plans_between_replans() {
        let mut m = mgr();
        m.replan_interval = 4;
        for layer in 0..4 {
            m.observe(layer, &[200, 10, 10, 10, 10, 10, 10, 10]);
        }
        assert!(m.replans_at(0));
        let p0 = m.decode_plans(0, 64);
        assert_eq!(p0.len(), 4);
        // Drift the estimators hard between steps; cached plans must not
        // move until the next replan boundary.
        for layer in 0..4 {
            for _ in 0..50 {
                m.observe(layer, &[10, 10, 10, 10, 10, 10, 10, 400]);
            }
        }
        for step in 1..4 {
            assert!(!m.replans_at(step));
            let p = m.decode_plans(step, 64);
            assert_eq!(p[0].predicted_counts, p0[0].predicted_counts);
        }
        assert!(m.replans_at(4));
        let p4 = m.decode_plans(4, 64);
        assert_ne!(
            p4[0].predicted_counts, p0[0].predicted_counts,
            "replan must pick up the drifted estimate"
        );
        let hot = p4[0]
            .predicted_counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        assert_eq!(hot, 7);
    }

    #[test]
    fn note_plan_diffs_shrunk_replicas() {
        let mut m = mgr();
        let fat = m.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        assert!(fat.placement.copies(0) > 1);
        // First observation: nothing to diff against.
        assert!(m.note_plan(1, &fat.placement).is_empty());
        // Shrinking back to the static placement drops the added replicas.
        let lean = m.static_plan();
        let removed = m.note_plan(1, &lean.placement);
        assert_eq!(removed.len(), fat.added.len());
        for &(expert, gpu) in &removed {
            assert!(fat.placement.hosts(expert, gpu));
            assert!(!lean.placement.hosts(expert, gpu));
        }
        // Same plan again: no further shrink.
        assert!(m.note_plan(1, &lean.placement).is_empty());
        // Other layers are independent.
        assert!(m.note_plan(0, &lean.placement).is_empty());
    }

    #[test]
    fn worker_death_excludes_gpu_and_forces_replan() {
        let mut m = mgr();
        m.replan_interval = 100;
        for layer in 0..4 {
            m.observe(layer, &[300, 10, 10, 10, 10, 10, 10, 10]);
        }
        m.decode_plans(0, 64);
        assert!(!m.replans_at(1), "cadence would normally hold the plans");
        m.note_worker_death(1);
        assert!(m.replans_at(1), "death replans out of cadence");
        let plans = m.decode_plans(1, 64);
        for plan in &plans {
            assert!(
                plan.placement.experts_on(1).is_empty(),
                "degraded plans must not place on the dead worker"
            );
            plan.placement.check_invariants().unwrap();
        }
        // The static baseline also excludes the dead worker.
        assert!(m.static_plan().placement.experts_on(1).is_empty());
    }

    #[test]
    fn reset_forces_replan() {
        let mut m = mgr();
        m.replan_interval = 100;
        m.decode_plans(0, 64);
        assert!(!m.replans_at(1));
        m.reset_decode_plans();
        assert!(m.replans_at(1));
    }

    #[test]
    fn horizon_zero_plans_match_reactive_exactly() {
        let mut reactive = mgr();
        let mut forecasting = mgr();
        forecasting.horizon = 0; // explicit: the default
        for t in 0..6usize {
            let counts = [40 + 20 * t, 40, 40, 40, 40, 40, 40, 40];
            reactive.observe(2, &counts);
            forecasting.observe(2, &counts);
        }
        let a = reactive.plan_distribution_only(2, 512);
        let b = forecasting.plan_distribution_only(2, 512);
        assert_eq!(a.predicted_counts, b.predicted_counts);
        assert_eq!(a.placement, b.placement);
    }

    #[test]
    fn proactive_plan_replicates_ramping_expert_before_reactive_does() {
        // Expert 0 ramps linearly; by the horizon target it is hot enough
        // to deserve a replica, but the *current* estimate is still too
        // cool — the proactive plan must carry the replica first.
        let mut m = mgr();
        m.horizon = 4;
        for t in 0..8usize {
            m.observe(0, &[40 + 30 * t, 40, 40, 40, 40, 40, 40, 40]);
        }
        let proactive = m.plan_distribution_only(0, 512);
        let mut reactive = mgr();
        for t in 0..8usize {
            reactive.observe(0, &[40 + 30 * t, 40, 40, 40, 40, 40, 40, 40]);
        }
        let now = reactive.plan_distribution_only(0, 512);
        assert!(
            proactive.predicted_counts[0] > now.predicted_counts[0],
            "forecast must extrapolate the ramp: {} <= {}",
            proactive.predicted_counts[0],
            now.predicted_counts[0]
        );
        assert!(
            proactive.placement.copies(0) >= now.placement.copies(0),
            "proactive plan must not carry fewer replicas of the ramping expert"
        );
    }

    #[test]
    fn realized_forecast_error_scores_when_reality_arrives() {
        let mut m = mgr();
        m.horizon = 2;
        // Constant load: the matured forecast should be near-perfect.
        for _ in 0..6 {
            m.observe(1, &[100, 100, 100, 100, 100, 100, 100, 100]);
        }
        let _plan = m.plan_distribution_only(1, 512);
        assert_eq!(m.drain_forecast_errors(), (0.0, 0), "not matured yet");
        m.observe(1, &[100, 100, 100, 100, 100, 100, 100, 100]);
        assert_eq!(m.drain_forecast_errors().1, 0, "one step short");
        m.observe(1, &[100, 100, 100, 100, 100, 100, 100, 100]);
        let (err, n) = m.drain_forecast_errors();
        assert_eq!(n, 1, "horizon-2 forecast matures on the second observe");
        assert!(err < 1e-9, "constant load forecast error must vanish: {err}");
        // Drained: a second read is empty.
        assert_eq!(m.drain_forecast_errors(), (0.0, 0));
        // An adversarial alternating trace realizes a large error.
        let mut adv = mgr();
        adv.horizon = 1;
        for t in 0..10usize {
            let counts = if t % 2 == 0 {
                [400, 10, 10, 10, 10, 10, 10, 10]
            } else {
                [10, 400, 10, 10, 10, 10, 10, 10]
            };
            adv.observe(3, &counts);
            if t == 8 {
                let _ = adv.plan_distribution_only(3, 512);
            }
        }
        let (err, n) = adv.drain_forecast_errors();
        assert_eq!(n, 1);
        assert!(err > 0.5, "alternating load must realize a large error: {err}");
    }
}
