//! Predictor-driven expert placement: the paper's mechanism on the live
//! serving path.
//!
//! Before each round's FFN phases, the manager produces a per-layer
//! duplication plan from whichever prediction strategy is active:
//!
//! * **NoPrediction** — the static initial placement; dispatch follows the
//!   expert's home GPU (the baseline whose load imbalance the paper
//!   quantifies).
//! * **DistributionOnly** — a multinomial-MLE estimate of each layer's
//!   expert distribution (updated online from every observed batch — the
//!   "moving average" of §3.2.1) feeds Algorithm 1 with *expected* counts.
//! * **TokenToExpert** — the AOT-compiled FFN predictor (trained in
//!   python, executed through PJRT) predicts every token's expert per
//!   layer *before attention runs* (§3.1), giving Algorithm 1 exact
//!   predicted counts and the dispatcher per-(expert, GPU) quotas.

use crate::duplication::algorithm::{balance, BalanceResult};
use crate::duplication::placement::Placement;
use crate::predictor::distribution::DistributionEstimator;

/// Per-layer plan for one round.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub placement: Placement,
    /// Per-(expert, gpu) token quotas (empty for NoPrediction).
    pub share: Vec<Vec<usize>>,
    /// Predicted per-expert counts the plan was built from.
    pub predicted_counts: Vec<usize>,
    /// Replicas added vs the static placement (duplication transfers).
    pub added: Vec<(usize, usize)>,
}

pub struct PlacementManager {
    pub n_experts: usize,
    pub n_workers: usize,
    /// Expert-slot capacity per worker (memory constraint M_g).
    pub capacity: usize,
    /// Maximum copies per expert (C_max).
    pub max_copies: usize,
    /// Online estimators, one per layer (Distribution-Only state).
    pub estimators: Vec<DistributionEstimator>,
    static_placement: Placement,
}

impl PlacementManager {
    pub fn new(
        n_experts: usize,
        n_workers: usize,
        n_layers: usize,
        capacity: usize,
        max_copies: usize,
    ) -> PlacementManager {
        PlacementManager {
            n_experts,
            n_workers,
            capacity,
            max_copies,
            estimators: (0..n_layers)
                .map(|_| DistributionEstimator::new(n_experts))
                .collect(),
            static_placement: Placement::initial(n_experts, n_workers, capacity, max_copies),
        }
    }

    pub fn static_plan(&self) -> LayerPlan {
        LayerPlan {
            placement: self.static_placement.clone(),
            share: Vec::new(),
            predicted_counts: Vec::new(),
            added: Vec::new(),
        }
    }

    /// Plan from predicted per-expert counts (both strategies reduce to
    /// this: DOP converts its probability estimate into expected counts,
    /// TEP counts its per-token predictions).
    pub fn plan_from_counts(&self, counts: &[usize]) -> LayerPlan {
        let result: BalanceResult = balance(counts, &self.static_placement);
        LayerPlan {
            added: self.static_placement.added_replicas(&result.placement),
            placement: result.placement,
            share: result.share,
            predicted_counts: counts.to_vec(),
            // `loads`/`iterations` are derivable; keep the plan lean.
        }
    }

    /// DOP plan for a layer: expected counts = p̂ · total_slots.
    pub fn plan_distribution_only(&self, layer: usize, total_slots: usize) -> LayerPlan {
        let probs = self.estimators[layer].mle();
        let mut counts: Vec<usize> = probs
            .iter()
            .map(|p| (p * total_slots as f64).round() as usize)
            .collect();
        // Fix rounding so counts sum to total_slots (conservation).
        let mut diff = total_slots as i64 - counts.iter().sum::<usize>() as i64;
        let mut i = 0;
        while diff != 0 && !counts.is_empty() {
            let idx = i % counts.len();
            if diff > 0 {
                counts[idx] += 1;
                diff -= 1;
            } else if counts[idx] > 0 {
                counts[idx] -= 1;
                diff += 1;
            }
            i += 1;
        }
        self.plan_from_counts(&counts)
    }

    /// Feed observed routing back into the estimators (the moving average
    /// keeps improving while serving — §3.2.1).
    pub fn observe(&mut self, layer: usize, actual_counts: &[usize]) {
        self.estimators[layer].update(actual_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> PlacementManager {
        PlacementManager::new(8, 4, 4, 8, 4)
    }

    #[test]
    fn static_plan_has_no_duplicates() {
        let m = mgr();
        let plan = m.static_plan();
        for e in 0..8 {
            assert_eq!(plan.placement.copies(e), 1);
        }
        assert!(plan.added.is_empty());
    }

    #[test]
    fn skewed_counts_trigger_duplication() {
        let m = mgr();
        let plan = m.plan_from_counts(&[600, 40, 40, 40, 40, 40, 40, 40]);
        assert!(plan.placement.copies(0) > 1, "hot expert must replicate");
        assert!(!plan.added.is_empty());
        // Quotas conserve tokens.
        let total: usize = plan.share.iter().flat_map(|r| r.iter()).sum();
        assert_eq!(total, 880);
        plan.placement.check_invariants().unwrap();
    }

    #[test]
    fn dop_plan_tracks_estimator() {
        let mut m = mgr();
        // Feed a heavy skew toward expert 2 for layer 1.
        for _ in 0..20 {
            m.observe(1, &[10, 10, 300, 10, 10, 10, 10, 10]);
        }
        let plan = m.plan_distribution_only(1, 512);
        assert_eq!(plan.predicted_counts.iter().sum::<usize>(), 512);
        let max_idx = plan
            .predicted_counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .unwrap()
            .0;
        assert_eq!(max_idx, 2);
        assert!(plan.placement.copies(2) > 1);
    }

    #[test]
    fn fresh_estimator_plans_uniform() {
        let m = mgr();
        let plan = m.plan_distribution_only(0, 512);
        assert_eq!(plan.predicted_counts.iter().sum::<usize>(), 512);
        assert!(plan.added.is_empty(), "uniform estimate needs no replicas");
    }
}
